"""Packed vs dequantize-then-einsum matmul: the decode-path perf tracker.

    PYTHONPATH=src python -m benchmarks.matmul_bench [--smoke] [--out PATH]

Sweeps the serving matmul shapes — bucketed decode M in {1, 2, 4, 8}
(memory-bound GEMV) and prefill/training M in {32, 128} — and reports, per
(M, K, N):

  * correctness: max |kernel - reference| in interpret mode on CPU (the
    same Pallas program a TPU runs, executed by the interpreter);
  * roofline model: weight/activation HBM bytes and time for (a) the
    simulated path (bf16 weight traffic + dequant materialization) and
    (b) the packed path (4-bit codes + codebooks streamed through the
    fused kernel), against TPU peak FLOPs / HBM bandwidth from
    launch.mesh. On the M <= 8 shapes both paths are deep in the memory
    regime, so the modelled speedup is the byte ratio (~2.6x);
  * CPU wall time of the two jnp paths (jit-warmed). Reported for honesty:
    at CPU smoke scale both paths dequantize in registers, so this number
    is dispatch-bound and NOT the headline — the roofline column is what
    the fused kernel buys on hardware.

Emits BENCH_matmul.json (``--out``) so the packed-path perf trajectory is
tracked from this PR on; ``--smoke`` shrinks K/N for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _roofline(m, k, n, scale_bytes):
    """Bytes and modelled time per path. Returns dict of terms."""
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
    flops = 2.0 * m * k * n
    act = (m * k + m * n) * 2.0                       # bf16 in/out
    w_bf16 = k * n * 2.0
    w_packed = k * n / 2.0 + (k * n / 64.0) * 8.0 * scale_bytes
    t_comp = flops / PEAK_FLOPS_BF16
    # simulated path additionally writes + re-reads the dequantized bf16
    # weights (the materialization `dense()` used to do); counting only the
    # read (best case for the baseline) keeps the comparison conservative.
    t_sim = max(t_comp, (act + w_bf16) / HBM_BW)
    t_packed = max(t_comp, (act + w_packed) / HBM_BW)
    return {
        "flops": flops,
        "bytes_simulated": act + w_bf16,
        "bytes_packed": act + w_packed,
        "weight_bits_per_weight_packed": round(w_packed * 8 / (k * n), 2),
        "t_simulated_us": round(t_sim * 1e6, 3),
        "t_packed_us": round(t_packed * 1e6, 3),
        "modeled_speedup": round(t_sim / t_packed, 2),
        "memory_bound": t_comp < (act + w_packed) / HBM_BW,
    }


def _wall(fn, iters=20):
    fn()                                              # warm the jit cache
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def bench_shape(rng, m, k, n, scale_dtype, interpret_correctness=True):
    import jax.numpy as jnp
    import numpy as np
    import jax

    from repro.core import pack_qtensor, quantize_blockwise
    from repro.kernels.msb_matmul.msb_matmul import pick_blocks
    from repro.kernels.msb_matmul.ops import packed_matmul

    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    q = quantize_blockwise(w, bits=4, block=64, solver="kmeans",
                           scale_dtype=scale_dtype)
    pq = pack_qtensor(q)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)

    entry = {"m": m, "k": k, "n": n,
             "scales": str(jnp.dtype(scale_dtype).name),
             "blocks_mkn": pick_blocks(m, k, n)}

    if interpret_correctness:
        y_kernel = packed_matmul(x, pq, use_kernel=True, interpret=True)
        y_ref = packed_matmul(x, pq, use_kernel=False)
        entry["max_abs_err"] = float(
            np.max(np.abs(np.asarray(y_kernel, np.float64)
                          - np.asarray(y_ref, np.float64))))

    simulated = jax.jit(lambda x, q: x @ q.dequantize().astype(x.dtype))
    packed = jax.jit(lambda x, p: packed_matmul(x, p, use_kernel=False))
    entry["cpu_wall_simulated_us"] = round(
        _wall(lambda: jax.block_until_ready(simulated(x, q))) * 1e6, 1)
    entry["cpu_wall_packed_us"] = round(
        _wall(lambda: jax.block_until_ready(packed(x, pq))) * 1e6, 1)

    scale_bytes = jnp.dtype(scale_dtype).itemsize
    entry["roofline"] = _roofline(m, k, n, scale_bytes)
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + skip slow cases (CI)")
    ap.add_argument("--out", default="BENCH_matmul.json")
    args = ap.parse_args()

    import numpy as np
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    if args.smoke:
        kns = [(256, 512)]
        ms = [1, 8, 32]
    else:
        kns = [(1024, 1024), (1024, 4096)]
        ms = [1, 2, 4, 8, 32, 128]

    report = {"backend": "cpu-interpret+roofline", "shapes": []}
    for k, n in kns:
        for m in ms:
            scale_dtype = jnp.bfloat16 if (m, k, n) != (1, kns[0][0], kns[0][1]) \
                else jnp.float32      # cover both scale dtypes in the sweep
            e = bench_shape(rng, m, k, n, scale_dtype)
            report["shapes"].append(e)
            r = e["roofline"]
            print(f"[matmul_bench] M={m:4d} K={k} N={n} "
                  f"err={e.get('max_abs_err', float('nan')):.2e} "
                  f"roofline x{r['modeled_speedup']:.2f} "
                  f"({r['weight_bits_per_weight_packed']} bits/w) "
                  f"cpu wall sim {e['cpu_wall_simulated_us']:.0f}us "
                  f"vs packed {e['cpu_wall_packed_us']:.0f}us")

    decode = [e for e in report["shapes"] if e["m"] <= 8]
    report["summary"] = {
        "decode_min_modeled_speedup": min(
            e["roofline"]["modeled_speedup"] for e in decode),
        "decode_all_memory_bound": all(
            e["roofline"]["memory_bound"] for e in decode),
        "decode_packed_beats_simulated": all(
            e["roofline"]["modeled_speedup"] > 1.0 for e in decode),
        "max_abs_err": max(e.get("max_abs_err", 0.0)
                           for e in report["shapes"]),
    }
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[matmul_bench] wrote {args.out}")
    print(json.dumps(report["summary"]))
    assert report["summary"]["decode_packed_beats_simulated"], \
        "packed path must beat dequantize-then-einsum on M<=8 shapes"
    # the actual kernel-regression gate: interpret-mode kernel vs reference.
    # Observed worst case is ~1e-4 (f32 tile accumulation at K=1024); a
    # layout/kernel bug produces O(1)+ errors.
    assert report["summary"]["max_abs_err"] < 1e-2, \
        f"kernel/reference parity broke: {report['summary']['max_abs_err']}"


if __name__ == "__main__":
    main()
