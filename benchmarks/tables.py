"""Benchmark harness — one function per paper table/figure.

No HF checkpoints or eval datasets exist in this offline environment, so
weight tensors are synthetic with LLM-realistic heavy tails (student-t,
df=4 — LLM weight kurtosis ballpark) at the *exact shapes* of the paper's
targets (first linear of Llama-3.2-1B: 2048x8192), and quality tables use
the trained-Markov-LM NLL protocol (tests/test_system.py) instead of
WikiText PPL. Each table mirrors the paper's structure: same methods, same
bit/granularity grid, same sweep axes. See EXPERIMENTS.md for the mapping.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (baselines, dequantize, quantize_blockwise,
                        quantize_pertensor, reconstruction_mse, reference,
                        lambda_from_tilde)

LLAMA32_1B_FIRST_LINEAR = (2048, 8192)   # gate_proj of meta-llama/Llama-3.2-1B


def synth_weight(shape, seed=0, df=4.0):
    rng = np.random.default_rng(seed)
    w = rng.standard_t(df, size=shape).astype(np.float32)
    return w * 0.02 / w.std()


def _mse(w, w_hat):
    return float(reconstruction_mse(w, w_hat))


def _time(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
        isinstance(out, jax.Array) else None
    return out, time.perf_counter() - t0


# ---------------------------------------------------------------------------
# Table 2: first-linear quantization MSE + time, per method x bits x setting
# ---------------------------------------------------------------------------

def table2_first_linear_mse(shape=None, rows=None):
    shape = shape or (512, 2048)     # CPU-budget sub-tile of the 2048x8192
    w = synth_weight(shape, seed=1)
    out = []
    for bits in (6, 5, 4):
        _, t = _time(lambda: baselines.rtn_quantize(w, bits, -1))
        out.append(("RTN", "per-tensor", bits, t,
                    _mse(w, baselines.rtn_quantize(w, bits, -1))))
        _, t = _time(lambda: baselines.hqq_quantize(w, bits, -1))
        out.append(("HQQ", "per-tensor", bits, t,
                    _mse(w, baselines.hqq_quantize(w, bits, -1))))
        q, t = _time(lambda: quantize_pertensor(w, bits=bits, solver="wdp"))
        out.append(("MSB-WDP", "per-tensor", bits, t, _mse(w, dequantize(q))))
    for bits in (4, 3, 2):
        _, t = _time(lambda: baselines.rtn_quantize(w, bits, 64))
        out.append(("RTN", "block-64", bits, t,
                    _mse(w, baselines.rtn_quantize(w, bits, 64))))
        _, t = _time(lambda: baselines.hqq_quantize(w, bits, 64))
        out.append(("HQQ", "block-64", bits, t,
                    _mse(w, baselines.hqq_quantize(w, bits, 64))))
        q, t = _time(lambda: quantize_blockwise(w, bits=bits, solver="dp"))
        out.append(("MSB-DP", "block-64", bits, t, _mse(w, dequantize(q))))
    return [("method", "granularity", "bits", "time_s", "mse")] + out


# ---------------------------------------------------------------------------
# Table 3: full-model quantization wall time (per arch smoke model)
# ---------------------------------------------------------------------------

def table3_model_quant_time():
    from repro.configs import smoke_config
    from repro.core import QuantPolicy, quantize_params
    from repro.models import Model
    out = [("model", "n_quant_leaves", "params_m", "time_s")]
    for arch in ("qwen1.5-0.5b", "gemma2-2b", "granite-moe-3b-a800m"):
        cfg = smoke_config(arch)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        n = sum(x.size for x in jax.tree_util.tree_leaves(params)) / 1e6
        t0 = time.perf_counter()
        _, report = quantize_params(params, QuantPolicy(
            bits=4, block=64, solver="dp", min_size=1024))
        t = time.perf_counter() - t0
        out.append((arch, len(report), round(n, 2), t))
    return out


# ---------------------------------------------------------------------------
# Table 4: DP oracle vs WGM (approximation-gap study)
# ---------------------------------------------------------------------------

def table4_dp_vs_wgm(n_blocks=64):
    w = synth_weight((n_blocks, 64), seed=2)
    out = [("method", "bits", "time_s", "mse")]
    for bits in (4, 3):
        q, t = _time(lambda: quantize_blockwise(w, bits=bits, solver="dp"))
        out.append(("DP(vectorized)", bits, t, _mse(w, dequantize(q))))
        t0 = time.perf_counter()
        q2 = quantize_blockwise(w, bits=bits, solver="wgm")
        t = time.perf_counter() - t0
        out.append(("WGM(paper,CPU)", bits, t, _mse(w, dequantize(q2))))
        t0 = time.perf_counter()
        q3 = quantize_blockwise(w, bits=bits, solver="gg")
        t = time.perf_counter() - t0
        out.append(("GG(paper,CPU)", bits, t, _mse(w, dequantize(q3))))
    return out


# ---------------------------------------------------------------------------
# Table 5 / Appendix E: lambda sweep (low-sensitivity claim)
# ---------------------------------------------------------------------------

def table5_lambda_sweep():
    w = synth_weight((128, 64), seed=3)
    out = [("lambda_tilde", "lambda", "mse")]
    for lt in (0.0, 0.25, 0.5, 0.75, 1.0):
        lam = lambda_from_tilde(np.asarray(w).ravel(), lt)
        q = quantize_blockwise(w, bits=4, solver="dp", lam=lam)
        out.append((lt, f"{lam:.3e}", _mse(w, dequantize(q))))
    return out


# ---------------------------------------------------------------------------
# Table 6/7 + Appendix E: window/group-count sweeps
# ---------------------------------------------------------------------------

def table6_block_window_sweep():
    w = synth_weight((64, 2048), seed=4)
    out = [("block_t", "solver", "time_s", "mse")]
    for t_blk in (2048, 512, 128, 64):
        q, tt = _time(lambda: quantize_blockwise(
            w.reshape(-1, t_blk), bits=4, block=t_blk,
            solver="dp" if t_blk <= 128 else "wdp"))
        out.append((t_blk, "dp" if t_blk <= 128 else "wdp", tt,
                    _mse(w.reshape(-1, t_blk), dequantize(q))))
    return out


def table7_max_group_sweep():
    a = synth_weight((1, 4096), seed=5).ravel()
    out = [("g(levels)", "bits", "mse")]
    v = jnp.sort(jnp.abs(jnp.asarray(a)))
    from repro.core.grouping import (boundaries_to_levels,
                                     scales_from_boundaries,
                                     windowed_dp_boundaries)
    for g in (4, 8, 16, 32, 64, 128):
        b = windowed_dp_boundaries(v, g, n_windows=512)
        sc = scales_from_boundaries(v, b)
        lv = boundaries_to_levels(b, v.shape[0])
        sse = float(jnp.sum((v - sc[lv]) ** 2))
        out.append((g, 1 + int(np.log2(g)), sse))
    return out


def table7b_window_sweep():
    a = synth_weight((1, 8192), seed=6).ravel()
    v = jnp.sort(jnp.abs(jnp.asarray(a)))
    from repro.core.grouping import (boundaries_to_levels, dp_boundaries,
                                     scales_from_boundaries,
                                     windowed_dp_boundaries)
    out = [("windows", "mse", "vs_exact")]
    exact_b, _ = dp_boundaries(v[:2048], 8)   # exact on a sub-slice
    for wn in (64, 128, 256, 512, 1024, 2048):
        b = windowed_dp_boundaries(v, 32, n_windows=wn)
        sc = scales_from_boundaries(v, b)
        lv = boundaries_to_levels(b, v.shape[0])
        sse = float(jnp.sum((v - sc[lv]) ** 2))
        out.append((wn, sse, ""))
    return out


# ---------------------------------------------------------------------------
# Figures 2-5: loss/time vs matrix size for DG/GG/WGM vs XNOR baselines
# ---------------------------------------------------------------------------

def figures_size_sweep(sizes=(16, 32, 64, 128)):
    out = [("n", "method", "time_s", "mse")]
    for n in sizes:
        w = synth_weight((n, n), seed=n)
        flat = np.asarray(w).ravel()
        out.append((n, "XNOR", *_timed_mse(w, lambda: baselines.xnor_quantize(w))))
        out.append((n, "BLOCKED-XNOR",
                    *_timed_mse(w, lambda: baselines.blocked_xnor_quantize(
                        w.reshape(1, -1), block=min(64, n * n)))))
        t0 = time.perf_counter()
        b, o, _ = reference.dynamic_grouping(flat[:256], 8)
        t_dg = time.perf_counter() - t0
        wh, _, _ = reference.reconstruct(flat[:256], b, o)
        out.append((n, "DG(<=256 elems)", t_dg,
                    float(((flat[:256] - wh) ** 2).sum())))
        t0 = time.perf_counter()
        b, o = reference.greedy_grouping(flat, 8)
        t_gg = time.perf_counter() - t0
        wh, _, _ = reference.reconstruct(flat, b, o)
        out.append((n, "GG", t_gg, float(((flat - wh) ** 2).sum())))
        t0 = time.perf_counter()
        b, o = reference.windowed_greedy_merging(flat, 8, window=8)
        t_w = time.perf_counter() - t0
        wh, _, _ = reference.reconstruct(flat, b, o)
        out.append((n, "WGM(w=8)", t_w, float(((flat - wh) ** 2).sum())))
    return out


def _timed_mse(w, fn):
    t0 = time.perf_counter()
    wh = fn()
    wh = np.asarray(wh).reshape(np.asarray(w).shape)
    return time.perf_counter() - t0, _mse(w, wh)


# ---------------------------------------------------------------------------
# Table 1 analogue: end-to-end quality (trained LM, methods x granularity)
# ---------------------------------------------------------------------------

def table1_quality():
    import dataclasses as dc
    from repro.configs import smoke_config
    from repro.core import QuantPolicy, quantize_params
    from repro.data import MarkovStream
    from repro.models import Model
    from repro.train import AdamW, OptConfig, train_loop

    cfg = smoke_config("qwen1.5-0.5b")
    cfg = dc.replace(cfg, vocab_size=64, vocab_round=64, d_model=64,
                     n_layers=2)
    model = Model(cfg)
    data = MarkovStream(64, 32, 8, seed=5)
    opt = AdamW(OptConfig(lr=3e-3, warmup_steps=5, total_steps=80))
    state, _ = train_loop(model, opt, iter(data), steps=60,
                          rng=jax.random.PRNGKey(0), log_every=0,
                          log_fn=lambda *_: None)
    params = state["params"]

    def nll(p):
        tot = 0.0
        for i in range(100, 104):
            b = data.batch(i)
            l, _ = jax.jit(model.loss)(p, {k: jnp.asarray(v)
                                           for k, v in b.items()})
            tot += float(l)
        return tot / 4

    out = [("method", "setting", "nll"), ("FP32", "-", nll(params))]

    def rtn_tree(p, bits, block):
        def visit(path, leaf):
            pol = QuantPolicy(min_size=1024)
            ps = "/".join(str(getattr(x, "key", x)) for x in path)
            if pol.selects(ps, leaf):
                return baselines.rtn_quantize(leaf, bits, block).astype(
                    leaf.dtype)
            return leaf
        return jax.tree_util.tree_map_with_path(visit, p)

    qp, _ = quantize_params(params, QuantPolicy(bits=4, block=64, solver="dp",
                                                min_size=1024))
    out.append(("MSB-DP", "4b block-64", nll(qp)))
    out.append(("RTN", "4b block-64", nll(rtn_tree(params, 4, 64))))
    qp6, _ = quantize_params(params, QuantPolicy(bits=6, block=-1,
                                                 solver="wdp", min_size=1024))
    out.append(("MSB-WDP", "6b per-tensor", nll(qp6)))
    out.append(("RTN", "6b per-tensor", nll(rtn_tree(params, 6, -1))))
    qpd, _ = quantize_params(params, QuantPolicy(bits=4, block=64,
                                                 solver="dp", min_size=1024,
                                                 double_quant=True))
    out.append(("MSB-DP+DQ", "4b block-64 (4.78b eff)", nll(qpd)))
    return out


# ---------------------------------------------------------------------------
# Kernel micro-benchmarks (wall time of the jnp reference vs interpret cost
# is meaningless on CPU; report ref-path throughput + bytes model)
# ---------------------------------------------------------------------------

def kernel_bench():
    from repro.kernels.msb_matmul.ops import to_kernel_layout
    from repro.kernels.msb_matmul.ref import msb_matmul_ref
    out = [("kernel", "shape", "wall_us", "weight_bytes_ratio")]
    w = synth_weight((1024, 1024), seed=9)
    q = quantize_blockwise(w, bits=4, solver="dp")
    packed, scales = to_kernel_layout(q)
    x = jnp.asarray(synth_weight((16, 1024), seed=10))
    f = jax.jit(lambda x: msb_matmul_ref(x, packed, scales))
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f(x).block_until_ready()
    us = (time.perf_counter() - t0) / 20 * 1e6
    ratio = (packed.size + scales.size * 2) / (w.size * 2)
    out.append(("msb_matmul(ref)", "16x1024x1024", round(us, 1),
                round(ratio, 4)))
    return out
