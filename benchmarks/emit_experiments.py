"""Inject the roofline table from artifacts into EXPERIMENTS.md."""
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def table():
    rows = ["| arch | shape | mesh | bound | peak GiB/dev | compute s | "
            "memory s | collective s | useful | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(f))
        mesh = "single" if "single" in r["mesh"] else "multi"
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | SKIP "
                        f"(full-attention) | | | | | | |")
            continue
        rl = r["roofline"]
        peak = r["memory_analysis"]["peak_bytes_per_dev"] / 2 ** 30
        q = " +msb4" if r.get("quantized") else ""
        rows.append(
            f"| {r['arch']}{q} | {r['shape']} | {mesh} | {rl['bottleneck']} "
            f"| {peak:.1f} | {rl['compute_s']:.3f} | {rl['memory_s']:.3f} | "
            f"{rl['collective_s']:.3f} | {rl['useful_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def main():
    text = open(EXP).read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in text:
        text = text.replace(marker, table(), 1)
        open(EXP, "w").write(text)
        print(f"injected {len(glob.glob(os.path.join(ART, '*.json')))} cells")
    else:
        print("marker not found (already injected?)")


if __name__ == "__main__":
    main()
