"""Roofline summary table from dry-run artifacts (deliverable g)."""
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(art_dir=ART):
    cells = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def emit_summary(art_dir=ART):
    cells = load_cells(art_dir)
    if not cells:
        print("\n### roofline_summary\nno artifacts — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all`")
        return
    print("\n### roofline_summary")
    print("arch,shape,mesh,quantized,bound,peak_GiB_dev,compute_s,memory_s,"
          "collective_s,useful_flops_ratio,roofline_fraction")
    for r in cells:
        if "skipped" in r:
            print(f"{r['arch']},{r['shape']},{r['mesh']},,SKIP({r['skipped'][:40]})")
            continue
        rl = r["roofline"]
        peak = r["memory_analysis"]["peak_bytes_per_dev"] / 2 ** 30
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r.get('quantized', False)},"
              f"{rl['bottleneck']},{peak:.2f},{rl['compute_s']:.4f},"
              f"{rl['memory_s']:.4f},{rl['collective_s']:.4f},"
              f"{rl['useful_flops_ratio']:.3f},{rl['roofline_fraction']:.4f}")
