"""Serving throughput: continuous batching + paged KV vs the static engine.

    PYTHONPATH=src python -m benchmarks.serve_bench [--fast] [--out PATH]

Builds a smoke-size MSB-quantized model and serves the same request set
through (a) the static ``ServeEngine`` — arrival-order batches, every row
padded to the batch's longest prompt and decoded in lockstep to the longest
generation — and (b) the ``ContinuousEngine`` — paged KV, chunked prefill,
finished sequences evicted and their slots backfilled mid-flight.

Two metrics per arrival pattern:
  * wall tokens/s (useful generated tokens / wall time, jit warmed out of
    the timed region). CPU smoke scale is dispatch-bound, so this flatters
    the static engine's few-big-calls shape; it is reported for honesty,
    not as the headline.
  * work efficiency = useful token-positions / device token-positions
    actually computed (padding included). This is the quantity continuous
    batching exists to improve and is hardware-independent: lockstep
    padding waste scales with generation-length spread, slot backfill
    removes it.

A third axis compares ``execution="packed"`` vs ``execution="simulated"``
through the continuous engine on the same burst: identical greedy tokens
(asserted), storage bits of the served params, and wall time. On CPU the
packed path runs the jnp fallback, so wall parity is expected; the packed
win on hardware is tracked by benchmarks/matmul_bench.py's roofline.

A fourth axis runs the same burst tensor-parallel (``mesh=``) at tp=1/2/4
over a forced host mesh (``XLA_FLAGS=--xla_force_host_platform_device_
count=N``), asserting greedy-token identity to tp=1 and recording which
param groups sharded (DESIGN.md Sec. 10). With one device the axis
degenerates to tp=1 only.

A fifth axis (``decode_horizon``) serves the same burst with H ∈ {1, 4, 8}
fused decode iterations per dispatch (on-device sampling; DESIGN.md
Sec. 12). It asserts greedy-token identity across horizons and that decode
dispatches-per-token amortize by the horizon factor (< (1+ε)/H of the
horizon=1 rate), and reports tokens/sec plus host-sync counts — at decode
batch sizes the dispatch/sync overhead dominates, so fewer, fatter
dispatches is the whole point.

A sixth axis (``prefix_sharing``) serves a sequential stream of requests
behind one shared full-page-aligned prefix with the automatic prefix cache
on vs off, across execution modes and TP sizes. It asserts the acceptance
invariant of DESIGN.md Sec. 11: every request after the first drops its
prefill work positions by exactly the shared full-page token count
(``prefill_chunk`` divides the shared length, so chunk savings are exact),
while greedy outputs stay token-identical cache-on vs cache-off.

A seventh axis (``api_overhead``) serves the same concurrent burst through
the HTTP+SSE front door (serve/server.py, real sockets, one client thread
per request) vs direct engine use, asserting token identity and that the
API layer costs < 15% of direct tokens/sec, and reporting client-side TTFT
percentiles (DESIGN.md Sec. 13).

An eighth axis (``fault_recovery``) serves the same burst fault-free vs
through ``EngineSupervisor`` with a seeded ``FaultPlan`` crashing the
engine mid-flight (DESIGN.md Sec. 14), asserting token identity and a
clean page-pool audit (zero leaks) and reporting recovery latency,
replayed-token overhead, and the end-to-end wall slowdown the faults cost.

A ninth axis (``kv_quant``) re-serves the workload with the paged KV cache
MSB-quantized at ``kv_bits`` ∈ {16, 8, 4} under one fixed pool byte budget
(DESIGN.md Sec. 15), asserting >= 3x max-concurrent-sequence capacity at
4-bit vs bf16, greedy token identity at 8-bit, a clean allocator audit
after forced preemption on quantized pages, and reporting the codec's
round-trip reconstruction MSE on actually-served K/V pages.

A tenth axis (``prefill_packing``) serves the same multi-prompt admission
wave with packed ragged prefill on vs off across execution × tp ×
decode_horizon × kv_bits (DESIGN.md Sec. 16), asserting greedy token
identity in every cell and that packing drops prefill dispatches-per-
prompt below 1. It also reports client-visible TTFT p50/p99 through the
warmed HTTP front door and an MLPerf-style offline-throughput scenario
(all samples queued at once; samples/sec and tokens/sec over the full
drain).

An eleventh axis (``overload``) offers the same mixed-priority stream
open-loop at 1x and 2x serving capacity with the ``OverloadController``
closing the loop (DESIGN.md Sec. 17), reporting goodput, shed fraction by
class, and the brownout-level timeline, and asserting goodput at 2x stays
>= 0.9x goodput at 1x (no congestion collapse).

Emits a JSON comparison to stdout and --out (default
artifacts/serve_bench.json); see benchmarks/README.md for the schema.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _build(seed=0):
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import smoke_config
    from repro.core import QuantPolicy, quantize_params
    from repro.models import Model

    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    qparams, _ = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="kmeans", min_size=1024))
    return model, qparams, params


def _requests(rng, n, ragged):
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(4, 16)) if ragged else 8
        n_new = int(rng.integers(4, 20)) if ragged else 12
        reqs.append((rng.integers(0, 64, (plen,)).astype(np.int32), n_new))
    return reqs


def _static_batches(reqs, arrivals):
    """Arrival-order batching: everything that has arrived by the time the
    engine goes idle forms the next lockstep batch (arrivals are in engine
    decode-steps, the same logical clock the continuous run uses)."""
    batches, done, clock = [], 0, 0.0
    while done < len(reqs):
        batch = [i for i in range(done, len(reqs)) if arrivals[i] <= clock]
        if not batch:
            clock = arrivals[done]
            continue
        batches.append(batch)
        done += len(batch)
        clock += max(reqs[i][1] for i in batch)   # lockstep decode steps
    return batches


def _run_static(model, params, reqs, arrivals):
    import jax.numpy as jnp

    from repro.serve import ServeEngine

    eng = ServeEngine(model, params, max_seq=64)
    batches = _static_batches(reqs, arrivals)

    def serve(timed):
        work = useful = 0
        for batch in batches:
            plen = max(len(reqs[i][0]) for i in batch)
            n_new = max(reqs[i][1] for i in batch)
            prompts = np.zeros((len(batch), plen), np.int32)
            for row, i in enumerate(batch):
                prompts[row, plen - len(reqs[i][0]):] = reqs[i][0]
            out = eng.generate(jnp.asarray(prompts), n_tokens=n_new)
            np.asarray(out)                       # block for timing
            work += len(batch) * (plen + n_new)
            useful += sum(reqs[i][1] for i in batch)
        return work, useful

    serve(timed=False)                            # warm every jit trace
    t0 = time.perf_counter()
    work, useful = serve(timed=True)
    return {"tokens": useful, "seconds": round(time.perf_counter() - t0, 3),
            "work_positions": work, "n_batches": len(batches)}


def _run_execution_axis(model, qparams, reqs):
    """Packed vs simulated execution through the continuous engine."""
    import jax
    from repro.core import param_bits
    from repro.serve import ContinuousEngine

    axis = {}
    outputs = {}
    for ex in ("simulated", "packed"):
        def serve():
            eng = ContinuousEngine(model, qparams, max_batch=8, page_size=4,
                                   num_pages=96, max_seq=36, prefill_chunk=8,
                                   execution=ex)
            for r in reqs:
                eng.submit(*r)
            return eng, eng.run()

        serve()                                    # warm jit buckets
        t0 = time.perf_counter()
        eng, outs = serve()
        outputs[ex] = outs
        # device_bits = what HBM actually holds (int8 codes in simulation
        # vs two-per-byte uint8 packed); param_bits = the paper's
        # idealized storage accounting
        device_bits = sum(l.size * l.dtype.itemsize * 8
                          for l in jax.tree_util.tree_leaves(eng.params))
        axis[ex] = {"seconds": round(time.perf_counter() - t0, 3),
                    "tokens": eng.n_tokens_out,
                    "param_bits": param_bits(eng.params),
                    "device_bits": device_bits}
    ident = all(
        np.array_equal(outputs["simulated"][rid], outputs["packed"][rid])
        for rid in outputs["simulated"])
    axis["outputs_identical"] = bool(ident)
    axis["packed_vs_simulated_bits"] = round(
        axis["packed"]["device_bits"] / axis["simulated"]["device_bits"], 3)
    # identity is guaranteed by construction only where the packed fallback
    # replays simulation math; the TPU kernel path may flip near-tie argmaxes
    # (bf16 rounding / f32 tile-accumulation order), so there it is reported,
    # not asserted
    if jax.default_backend() != "tpu":
        assert ident, "packed greedy decode must match simulation mode"
    return axis


def _run_tp_axis(model, qparams, reqs):
    """Tensor-parallel axis: the same burst through ContinuousEngine at
    every host-mesh TP size that fits the device count (force devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

    Greedy tokens are asserted identical to tp=1 off-TPU (the acceptance
    invariant; see DESIGN.md Sec. 10). Wall time on a CPU host mesh is
    reported for honesty only — forced host devices share the same socket,
    so TP adds collective overhead without adding FLOPs; the axis exists
    to pin down *correctness* and the sharding report, not speedup.
    """
    import jax

    from repro.launch.mesh import make_tp_mesh
    from repro.serve import ContinuousEngine

    n_dev = len(jax.devices())
    axis = {"devices": n_dev, "sizes": {}}
    baseline = None
    for tp in (1, 2, 4):
        if tp > n_dev:
            continue
        mesh = make_tp_mesh(tp) if tp > 1 else None

        def serve():
            eng = ContinuousEngine(model, qparams, max_batch=8, page_size=4,
                                   num_pages=96, max_seq=36, prefill_chunk=8,
                                   mesh=mesh)
            for r in reqs:
                eng.submit(*r)
            return eng, eng.run()

        serve()                                    # warm jit buckets
        t0 = time.perf_counter()
        eng, outs = serve()
        entry = {"seconds": round(time.perf_counter() - t0, 3),
                 "tokens": eng.n_tokens_out,
                 "sharded": dict(getattr(eng, "tp_report", {}))}
        if baseline is None:
            baseline = outs
        else:
            ident = all(np.array_equal(baseline[r], outs[r])
                        for r in baseline)
            entry["tokens_identical_to_tp1"] = bool(ident)
            if jax.default_backend() != "tpu":
                assert ident, f"tp={tp}: greedy decode diverged from tp=1"
        axis["sizes"][f"tp{tp}"] = entry
    return axis


def _run_horizon_axis(model, qparams, fast):
    """Decode-horizon axis: H fused decode iterations + on-device sampling
    per dispatch vs the classic one-token-per-dispatch loop (DESIGN.md
    Sec. 12). Two workloads per horizon:

      * ``cohort`` — 8 lockstep-budget requests in one burst. Batch ramp-up
        interleaves one decode wave per prefill admission regardless of
        horizon, so the per-token dispatch rate here floors above 1/H;
        asserted strictly decreasing in H plus token identity.
      * ``single_stream`` — one request decoding alone: pure decode regime,
        where the acceptance bound holds exactly — decode dispatches per
        token = ceil((B-1)/H)/B for budget B (the first token is sampled
        from the prefill dispatch), asserted < (1+ε)/H.

    Wall tokens/sec is reported (CPU smoke scale is dispatch-bound, which
    is exactly what fused dispatches attack), but not asserted — wall
    clocks flake in CI; the dispatch/sync counters are the guarantees.
    """
    import jax

    from repro.serve import ContinuousEngine

    rng = np.random.default_rng(3)
    budget = 16 if fast else 32
    cohort = [(rng.integers(0, 64, (6,)).astype(np.int32), budget)
              for _ in range(8)]
    single = [(rng.integers(0, 64, (6,)).astype(np.int32), budget)]
    max_seq = 8 + budget

    def build(h, reqs):
        eng = ContinuousEngine(model, qparams, max_batch=8, page_size=4,
                               num_pages=128, max_seq=max_seq,
                               prefill_chunk=8, decode_horizon=h)
        for r in reqs:
            eng.submit(*r)
        return eng

    def measure(h, reqs):
        build(h, reqs).run()                       # warm jit buckets
        dt = float("inf")                          # best-of-3: the timed
        for _ in range(3):                         # region is tiny, so take
            eng = build(h, reqs)                   # the least-noisy run;
            t0 = time.perf_counter()               # construction/submit sit
            outs = eng.run()                       # outside the clock
            dt = min(dt, time.perf_counter() - t0)
        return outs, {
            "seconds": round(dt, 3),
            "tokens": eng.n_tokens_out,
            "tokens_per_s": round(eng.n_tokens_out / dt, 1),
            "decode_dispatches": eng.n_decode_steps,
            "dispatches": eng.n_steps,
            "host_syncs": eng.n_host_syncs,
            "decode_dispatches_per_token": round(
                eng.n_decode_steps / max(eng.n_tokens_out, 1), 4),
        }

    axis = {"budget": budget, "horizons": {}}
    base = prev = base_outs = None
    for h in (1, 4, 8):
        c_out, c = measure(h, cohort)
        s_out, s = measure(h, single)
        entry = {"cohort": c, "single_stream": s}
        # single stream: the clean amortization bound (ceil rounding only)
        assert s["decode_dispatches_per_token"] < 1.25 / h, (h, s)
        if base is None:
            base = entry
            base_outs = (c_out, s_out)
        else:
            bc, bs = base_outs
            ident = (all(np.array_equal(bc[r], c_out[r]) for r in bc)
                     and all(np.array_equal(bs[r], s_out[r]) for r in bs))
            entry["tokens_identical_to_h1"] = bool(ident)
            if jax.default_backend() != "tpu":
                assert ident, f"decode_horizon={h} diverged from horizon=1"
            for k in ("cohort", "single_stream"):
                # strictly decreasing in H: each horizon vs the previous
                assert (entry[k]["decode_dispatches"]
                        < prev[k]["decode_dispatches"]), (h, k, entry)
                entry[k]["dispatch_rate_vs_h1"] = round(
                    entry[k]["decode_dispatches_per_token"]
                    / base[k]["decode_dispatches_per_token"], 4)
                entry[k]["tokens_per_s_vs_h1"] = round(
                    entry[k]["tokens_per_s"] / base[k]["tokens_per_s"], 2)
        axis["horizons"][f"h{h}"] = entry
        prev = entry
    return axis


def _run_prefix_axis(model, qparams, n_req, page_size=4, shared_pages=4):
    """Prefix-sharing axis: a sequential stream (each request completes
    before the next arrives, so every later one can hit the registry)
    behind one shared prefix of ``shared_pages`` full pages, cache on vs
    off, for every execution mode and TP size the host offers."""
    import jax

    from repro.launch.mesh import make_tp_mesh
    from repro.serve import ContinuousEngine

    shared_len = shared_pages * page_size
    rng = np.random.default_rng(7)
    shared = rng.integers(0, 64, (shared_len,)).astype(np.int32)
    reqs = [(np.concatenate([shared, rng.integers(0, 64, (
        int(rng.integers(1, 6)),)).astype(np.int32)]),
        int(rng.integers(4, 10))) for _ in range(n_req)]
    saved_expect = (n_req - 1) * shared_len

    def serve(prefix_cache, execution, mesh):
        eng = ContinuousEngine(model, qparams, max_batch=8,
                               page_size=page_size, num_pages=96, max_seq=36,
                               prefill_chunk=page_size, execution=execution,
                               mesh=mesh, prefix_cache=prefix_cache)
        outs = {}
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(*r)
            outs.update(eng.run())
        return eng, outs, round(time.perf_counter() - t0, 3)

    n_dev = len(jax.devices())
    axis = {"shared_prefix_tokens": shared_len, "n_requests": n_req,
            "expected_positions_saved": saved_expect, "configs": {}}
    baseline = None
    for tp in (1, 2):
        if tp > n_dev:
            continue
        mesh = make_tp_mesh(tp) if tp > 1 else None
        for ex in ("simulated", "packed"):
            if mesh is None:
                # warm the (model-shared) jit bucket cache once; mesh
                # engines build engine-local shard_map closures, so a
                # warm run cannot pre-compile for them — their seconds
                # include compile and are honesty rows only
                serve(False, ex, mesh)
            on, out_on, s_on = serve(True, ex, mesh)
            off, out_off, s_off = serve(False, ex, mesh)
            ident = all(np.array_equal(out_on[r], out_off[r])
                        for r in out_on)
            entry = {
                "hits": on.n_prefix_hits,
                "positions_saved": on.n_prefix_positions_saved,
                "work_positions_on": on.n_work_positions,
                "work_positions_off": off.n_work_positions,
                "seconds_on": s_on, "seconds_off": s_off,
                "outputs_identical": bool(ident),
            }
            # the acceptance invariant: every request after the first skips
            # exactly the shared full pages (chunk-aligned, so the dispatch
            # positions drop by the same amount the registry adopted)
            assert on.n_prefix_hits == n_req - 1, entry
            assert on.n_prefix_positions_saved == saved_expect, entry
            assert (off.n_work_positions - on.n_work_positions
                    == saved_expect), entry
            if jax.default_backend() != "tpu":
                assert ident, f"prefix cache changed tokens ({ex}, tp={tp})"
            if baseline is None:
                baseline = out_on
            elif jax.default_backend() != "tpu":
                for r in baseline:
                    assert np.array_equal(baseline[r], out_on[r]), \
                        f"prefix cache diverged across ({ex}, tp={tp})"
            axis["configs"][f"{ex}_tp{tp}"] = entry
    return axis


def _run_api_overhead_axis(model, qparams, fast):
    """API-overhead axis: the same concurrent burst served directly through
    ``ContinuousEngine`` vs over the HTTP+SSE front door (serve/server.py),
    with real sockets and one client thread per request. Asserts greedy
    token identity across the two paths and that the HTTP layer costs
    < 15% of direct-engine tokens/sec (best-of-3 each side; the engine
    dominates, the front door must stay out of the way). Client-side TTFT
    percentiles (request written -> first token frame) land in the JSON —
    the latency a streaming user actually sees, queueing included.

    Both paths run ``prefix_cache=False`` so repeat rounds against the
    server's long-lived engine cannot skip prefill work the fresh direct
    engines would have to do, and both run the production decode config
    (``decode_horizon=8``, DESIGN.md Sec. 12) — one fused dispatch delivers
    one token-bearing event per request, which is also what bounds the SSE
    frame pipeline's per-token cost on small hosts."""
    import json as _json
    import socket
    from concurrent.futures import ThreadPoolExecutor

    from repro.serve import APIServer, ContinuousEngine

    rng = np.random.default_rng(11)
    n_req = 6 if fast else 12
    # enough decode work that the per-burst fixed costs (sockets, HTTP
    # parse, submit hand-off) sit in the per-token noise — the bound is
    # about the steady-state tax, not connection setup
    budget = 128 if fast else 160
    reqs = [(rng.integers(0, 64, (int(rng.integers(4, 12)),))
             .astype(np.int32), budget) for _ in range(n_req)]
    eng_kw = dict(max_batch=8, page_size=4, num_pages=384, max_seq=192,
                  prefill_chunk=8, prefix_cache=False, decode_horizon=8)

    def direct_round():
        eng = ContinuousEngine(model, qparams, **eng_kw)
        t0 = time.perf_counter()
        rids = [eng.submit(*r) for r in reqs]
        outs = eng.run()
        dt = time.perf_counter() - t0
        return dt, eng.n_tokens_out, [outs[r].tolist() for r in rids]

    direct_round()                                 # warm jit buckets
    d_dt, d_tokens, refs = min((direct_round() for _ in range(3)),
                               key=lambda r: r[0])

    def sse_client(args):
        host, port, (prompt, max_new) = args
        body = _json.dumps({"prompt": prompt.tolist(),
                            "max_tokens": max_new, "stream": True}).encode()
        t0 = time.perf_counter()
        s = socket.create_connection((host, port), timeout=600)
        s.sendall((f"POST /v1/completions HTTP/1.1\r\nHost: b\r\n"
                   f"Content-Type: application/json\r\n"
                   f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        buf, start, ttft = b"", None, None
        while b"data: [DONE]\n\n" not in buf:
            chunk = s.recv(65536)
            assert chunk, "server closed the stream early"
            buf += chunk
            if start is None and b"\r\n\r\n" in buf:
                start = buf.index(b"\r\n\r\n") + 4
            if start is not None and ttft is None and b"\n\n" in buf[start:]:
                ttft = time.perf_counter() - t0
        s.close()
        toks = []
        for frame in buf[start:].decode().split("\n\n"):
            if frame.startswith("data: ") and frame != "data: [DONE]":
                toks.extend(_json.loads(frame[6:])["choices"][0]["token_ids"])
        return ttft, toks

    srv = APIServer(ContinuousEngine(model, qparams, **eng_kw))
    host, port = srv.serve_background()
    try:
        jobs = [(host, port, r) for r in reqs]
        ttfts, a_dt, a_tokens, api_outs = [], float("inf"), 0, None
        with ThreadPoolExecutor(n_req) as pool:
            list(pool.map(sse_client, jobs))       # warm the server path
            for _ in range(3):
                t0 = time.perf_counter()
                results = list(pool.map(sse_client, jobs))
                dt = time.perf_counter() - t0
                ttfts.extend(t for t, _ in results)
                if dt < a_dt:
                    a_dt = dt
                    api_outs = [toks for _, toks in results]
                    a_tokens = sum(len(t) for t in api_outs)
    finally:
        srv.close()

    ident = api_outs == refs
    assert ident, "HTTP front door changed greedy tokens vs direct engine"
    direct_tps = d_tokens / d_dt
    api_tps = a_tokens / a_dt
    overhead = 1.0 - api_tps / direct_tps
    assert overhead < 0.15, (
        f"HTTP+SSE overhead {overhead:.1%} exceeds 15% "
        f"(direct {direct_tps:.0f} tok/s vs api {api_tps:.0f} tok/s)")
    ms = sorted(1e3 * t for t in ttfts)
    return {
        "n_requests": n_req, "budget": budget,
        "decode_horizon": eng_kw["decode_horizon"],
        "direct": {"seconds": round(d_dt, 3), "tokens": d_tokens,
                   "tokens_per_s": round(direct_tps, 1)},
        "http": {"seconds": round(a_dt, 3), "tokens": a_tokens,
                 "tokens_per_s": round(api_tps, 1)},
        "overhead_frac": round(overhead, 4),
        "outputs_identical": bool(ident),
        "ttft_ms": {"p50": round(float(np.percentile(ms, 50)), 2),
                    "p90": round(float(np.percentile(ms, 90)), 2),
                    "max": round(ms[-1], 2), "n": len(ms)},
    }


def _run_fault_recovery_axis(model, qparams, fast):
    """Fault-recovery axis: the same burst served fault-free vs through
    ``EngineSupervisor`` with a seeded ``FaultPlan`` crashing the engine
    mid-flight (DESIGN.md Sec. 14). Asserts the supervised run's greedy
    outputs are token-identical to the fault-free run and that the page
    pool audits clean afterwards (zero leaks); reports recovery latency
    (seconds per engine rebuild), replayed-token overhead (tokens
    re-decoded to restore in-flight state / useful tokens) and the wall
    slowdown the faults cost end to end."""
    from repro.serve import ContinuousEngine, EngineSupervisor, FaultPlan

    rng = np.random.default_rng(23)
    n_req = 6 if fast else 10
    budget = 24
    reqs = [(rng.integers(0, 64, (int(rng.integers(4, 12)),))
             .astype(np.int32), budget) for _ in range(n_req)]
    eng_kw = dict(max_batch=8, page_size=4, num_pages=96, max_seq=36,
                  prefill_chunk=8)

    def clean_round():
        eng = ContinuousEngine(model, qparams, **eng_kw)
        t0 = time.perf_counter()
        rids = [eng.submit(*r) for r in reqs]
        outs = eng.run()
        dt = time.perf_counter() - t0
        return dt, [outs[r].tolist() for r in rids], eng.n_tokens_out

    clean_round()                                  # warm jit buckets
    c_dt, refs, c_tokens = min((clean_round() for _ in range(3)),
                               key=lambda r: r[0])

    n_faults = 6 if fast else 12
    plan = FaultPlan.seeded(7, n_faults=n_faults,
                            sites=("step", "apply", "alloc"),
                            first=2, spread=30, stall_s=0.01)
    sup = EngineSupervisor(
        lambda: ContinuousEngine(model, qparams, faults=plan, **eng_kw),
        watchdog=False, max_crashes_per_request=100)
    t0 = time.perf_counter()
    rids = [sup.submit(*r) for r in reqs]
    outs = sup.run()
    f_dt = time.perf_counter() - t0
    ident = [outs[r].tolist() for r in rids] == refs
    assert ident, "supervised recovery changed greedy tokens"
    assert plan.exhausted, (
        f"plan only fired {len(plan.fired)}/{plan.n_events}")
    sup.engine.cache.check_invariants(expect_idle=True)   # zero leaks
    st = sup.stats()
    sup.close()
    rec = sorted(1e3 * t for t in st["recovery_log"]) or [0.0]
    return {
        "n_requests": n_req, "budget": budget, "n_faults": n_faults,
        "faults_fired": len(plan.fired),
        "restarts": st["restarts"],
        "replayed_tokens": st["replayed_tokens"],
        "replay_overhead_frac": round(st["replayed_tokens"]
                                      / max(1, c_tokens), 4),
        "clean": {"seconds": round(c_dt, 3), "tokens": c_tokens,
                  "tokens_per_s": round(c_tokens / c_dt, 1)},
        "faulted": {"seconds": round(f_dt, 3),
                    "tokens_per_s": round(c_tokens / f_dt, 1)},
        "wall_slowdown": round(f_dt / c_dt, 3),
        "recovery_ms": {"p50": round(float(np.percentile(rec, 50)), 2),
                        "max": round(rec[-1], 2), "n": len(rec)},
        "outputs_identical": bool(ident),
        "pool_audit_clean": True,
    }


def _run_kv_quant_axis(model, qparams, fparams, fast):
    """KV-cache quantization axis (DESIGN.md Sec. 15): the same serving
    workload at ``kv_bits`` 16 / 8 / 4 under one fixed page-pool *byte*
    budget, so lower-precision pages buy proportionally more of them.

    Reported and asserted per bit-width:
      * capacity = max concurrent sequences the pool hosts before any
        preemption (each request leases the same page count, so capacity is
        pool pages // pages-per-seq, bounded empirically: the analytic
        cohort must serve with zero preemptions, and the 4-bit cohort must
        *not* fit the bf16 pool without preempting). Acceptance:
        capacity(4) >= 3x capacity(16).
      * greedy token identity vs the bf16 cache on a mixed burst, served
        with the *full-precision* weights (``fparams``) so the cache codec
        is the only perturbation — exact at 8-bit (asserted off-TPU; under
        4-bit weights a near-tie argmax can flip on codec noise, which the
        execution axis, not this one, owns); at 4-bit KV the drift
        fraction is reported. Identity before any page commits is exact by
        construction at every bit-width (the hot path is bf16).
      * quality proxy: the codec's round-trip ``reconstruction_mse`` (the
        paper's table metric) on the *actual* K/V pages a bf16 run
        committed, normalized by signal power — grounded in served
        activations, not synthetic normals.
      * a chaos pass: the 4-bit cohort over the tight pool with forced
        preemption, then a full ``check_invariants`` audit (frontier
        bookkeeping included) must come back clean.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import (KVQuantSpec, kv_dequantize_pages,
                            kv_native_page_bytes, kv_quantize_pages,
                            reconstruction_mse)
    from repro.serve import ContinuousEngine

    cfg = model.cfg
    ps = 4
    spec8 = KVQuantSpec(8, ps, cfg.n_kv_heads, cfg.head_dim_)
    spec4 = KVQuantSpec(4, ps, cfg.n_kv_heads, cfg.head_dim_)
    native = kv_native_page_bytes(ps, cfg.n_kv_heads, cfg.head_dim_,
                                  cfg.dtype)
    budget = 12 * native                  # one K-or-V pool's byte budget
    pages = {16: 12, 8: budget // spec8.page_bytes(),
             4: budget // spec4.page_bytes()}
    page_bytes = {16: native, 8: spec8.page_bytes(), 4: spec4.page_bytes()}

    # every request leases exactly 4 pages (prompt 4 + budget 12 = 16
    # tokens) and lives 12 decode steps — long enough that concurrent
    # leases genuinely contend for the pool instead of draining through it
    per_seq = 4
    cap = {b: int(pages[b]) // per_seq for b in (16, 8, 4)}
    rng = np.random.default_rng(13)

    def cohort(n):
        return [(rng.integers(0, 64, (ps,)).astype(np.int32), 3 * ps)
                for _ in range(n)]

    def serve(bits, reqs, n_pages, max_batch, params=qparams):
        eng = ContinuousEngine(model, params, max_batch=max_batch,
                               page_size=ps, num_pages=n_pages + 1,
                               max_seq=8 * ps, prefill_chunk=ps,
                               kv_bits=bits, prefix_cache=False)
        rids = [eng.submit(*r) for r in reqs]
        outs = eng.run()
        eng.cache.check_invariants()
        eng.close()
        return eng, [outs[r].tolist() for r in rids]

    axis = {"page_size": ps, "pool_budget_bytes": int(budget),
            "pages_per_seq": per_seq, "bits": {}}
    mb = 16
    for bits in (16, 8, 4):
        c = min(cap[bits], mb)
        eng, _ = serve(bits, cohort(c), int(pages[bits]), mb)
        assert eng.scheduler.n_preemptions == 0, (
            f"kv_bits={bits}: analytic capacity {c} preempted")
        axis["bits"][f"kv{bits}"] = {
            "page_bytes": int(page_bytes[bits]),
            "pool_pages": int(pages[bits]),
            "capacity": int(cap[bits]),
            "bytes_vs_native": round(page_bytes[bits] / native, 4),
        }
    assert cap[4] >= 3 * cap[16], (
        f"capacity(kv4)={cap[4]} < 3x capacity(kv16)={cap[16]}")
    axis["capacity_gain_4bit"] = round(cap[4] / cap[16], 2)
    # a 4-bit-capacity cohort overflows the bf16 pool: preemption must fire
    # (this doubles as the quantized-pool invariant audit under pressure —
    # serve() runs check_invariants after every leg)
    over = min(cap[4], mb)
    eng, _ = serve(16, cohort(over), int(pages[16]), mb)
    assert eng.scheduler.n_preemptions > 0, (
        f"{over} seqs fit the bf16 pool without preemption — budget too lax")
    eng, _ = serve(4, cohort(over), int(pages[16]), mb)   # tight 4-bit pool
    assert eng.scheduler.n_preemptions > 0
    axis["chaos_preemptions_audited"] = int(eng.scheduler.n_preemptions)

    # fidelity burst: mixed lengths, kv16 as reference, full-precision
    # weights (cache codec is the only perturbation under test)
    reqs = [(rng.integers(0, 64, (int(rng.integers(4, 12)),))
             .astype(np.int32), int(rng.integers(4, 10)))
            for _ in range(4 if fast else 8)]
    outs = {b: serve(b, reqs, 64, 8, params=fparams)[1] for b in (16, 8, 4)}
    ident8 = outs[8] == outs[16]
    if jax.default_backend() != "tpu":
        assert ident8, "kv_bits=8 greedy decode diverged from bf16 cache"
    n_tok = sum(len(o) for o in outs[16])
    drift4 = sum(1 for a, b in zip(outs[16], outs[4]) if a != b)
    axis["fidelity"] = {"kv8_identical": bool(ident8),
                       "kv4_diverged_requests": int(drift4),
                       "n_requests": len(reqs), "n_tokens": int(n_tok)}

    # quality proxy on real committed K/V: round-trip the bf16 run's pages,
    # sampled mid-flight (a drained engine has released its pages)
    eng = ContinuousEngine(model, fparams, max_batch=8, page_size=ps,
                           num_pages=64, max_seq=32, prefill_chunk=ps,
                           kv_bits=16, prefix_cache=False)
    for r in reqs[:4]:
        eng.submit(*r)
    # packed prefill drains the prompt backlog in ~one wave, so a fixed
    # step count can overshoot the live window entirely; instead step
    # until every running sequence is decoding (its prompt pages are
    # committed with real K/V) and sample right there
    used = []
    while eng.scheduler.has_work:
        eng.step()
        used = sorted({p for s in range(eng.cache.max_seqs)
                       for p in eng.cache.seq_pages[s]})
        run = eng.scheduler.running
        if used and run and all(s.state == "decode" for s in run):
            break
    k_pool = jax.tree_util.tree_leaves(eng.cache.pools)[0]   # (p, n, ps, kv, hd)
    assert used, "mid-flight sample found no leased pages"
    real = jnp.asarray(np.asarray(k_pool)[:, used])
    power = float(jnp.sum(jnp.asarray(real, jnp.float32) ** 2))
    q = {}
    for bits in (8, 4):
        codes, scales = kv_quantize_pages(real, bits)
        rt = kv_dequantize_pages(codes, scales, bits, real.dtype)
        q[f"kv{bits}"] = round(
            float(reconstruction_mse(real, rt)) / max(power, 1e-30), 8)
    assert q["kv8"] <= q["kv4"], q
    axis["roundtrip_rel_mse"] = q
    eng.close()
    return axis


def _run_prefill_packing_axis(model, qparams, fast):
    """Packed-ragged-prefill axis (DESIGN.md Sec. 16): the same burst with
    ``prefill_packing`` on vs off across execution mode, TP size, decode
    horizon and KV-cache bits. Asserts, per cell: greedy token identity
    packed-vs-unpacked (off-TPU), and with packing on that a multi-prompt
    admission wave costs < 1 prefill dispatch per prompt — the tentpole
    claim. Reports, on top: client-visible TTFT p50/p99 through a *warmed*
    HTTP server (the latency a user sees once startup AOT warmup has
    eliminated steady-state compiles) and an MLPerf-style offline
    scenario — every sample queued before the clock starts, throughput =
    samples/sec and tokens/sec over the full drain."""
    import json as _json
    import socket
    from concurrent.futures import ThreadPoolExecutor

    import jax

    from repro.launch.mesh import make_tp_mesh
    from repro.serve import APIServer, ContinuousEngine

    rng = np.random.default_rng(29)
    n_req = 6 if fast else 10
    budget = 8 if fast else 16
    reqs = [(rng.integers(0, 64, (int(rng.integers(4, 14)),))
             .astype(np.int32), budget) for _ in range(n_req)]
    base_kw = dict(max_batch=8, page_size=4, num_pages=128, max_seq=48,
                   prefill_chunk=8, prefix_cache=False)

    def serve(packing, ex, mesh, h, kvb):
        eng = ContinuousEngine(model, qparams, execution=ex, mesh=mesh,
                               decode_horizon=h, kv_bits=kvb,
                               prefill_packing=packing, **base_kw)
        rids = [eng.submit(*r) for r in reqs]
        outs = eng.run()
        st = eng.stats()
        eng.close()
        return [outs[r].tolist() for r in rids], st

    n_dev = len(jax.devices())
    axis = {"n_requests": n_req, "budget": budget, "cells": {}}
    for tp in (1, 2):
        if tp > n_dev:
            continue
        mesh = make_tp_mesh(tp) if tp > 1 else None
        for ex in ("simulated", "packed"):
            for h in (1, 8):
                for kvb in (16, 8):
                    on, st_on = serve(True, ex, mesh, h, kvb)
                    off, st_off = serve(False, ex, mesh, h, kvb)
                    ident = on == off
                    dpp_on = st_on["prefill_dispatches"] / n_req
                    dpp_off = st_off["prefill_dispatches"] / n_req
                    cell = {
                        "outputs_identical": bool(ident),
                        "prefill_dispatches_packed":
                            st_on["prefill_dispatches"],
                        "prefill_dispatches_unpacked":
                            st_off["prefill_dispatches"],
                        "dispatches_per_prompt_packed": round(dpp_on, 4),
                        "dispatches_per_prompt_unpacked": round(dpp_off, 4),
                        "packed_segments": st_on["prefill_segments"],
                    }
                    if jax.default_backend() != "tpu":
                        assert ident, (
                            f"packed prefill changed greedy tokens "
                            f"(ex={ex}, tp={tp}, h={h}, kv={kvb})")
                    # the tentpole claim: a multi-prompt admission wave
                    # packs into fewer dispatches than prompts
                    assert dpp_on < 1.0, (ex, tp, h, kvb, cell)
                    assert dpp_on < dpp_off, (ex, tp, h, kvb, cell)
                    axis["cells"][f"{ex}_tp{tp}_h{h}_kv{kvb}"] = cell

    # client-visible TTFT through the warmed front door: concurrent burst,
    # one socket per request, TTFT = request written -> first token frame
    def sse_ttft(args):
        host, port, (prompt, max_new) = args
        body = _json.dumps({"prompt": prompt.tolist(),
                            "max_tokens": max_new, "stream": True}).encode()
        t0 = time.perf_counter()
        s = socket.create_connection((host, port), timeout=600)
        s.sendall((f"POST /v1/completions HTTP/1.1\r\nHost: b\r\n"
                   f"Content-Type: application/json\r\n"
                   f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        buf, start, ttft = b"", None, None
        while b"data: [DONE]\n\n" not in buf:
            chunk = s.recv(65536)
            assert chunk, "server closed the stream early"
            buf += chunk
            if start is None and b"\r\n\r\n" in buf:
                start = buf.index(b"\r\n\r\n") + 4
            if start is not None and ttft is None and b"\n\n" in buf[start:]:
                ttft = time.perf_counter() - t0
        s.close()
        return ttft

    srv = APIServer(ContinuousEngine(model, qparams, decode_horizon=8,
                                     **base_kw), warmup=True)
    host, port = srv.serve_background()
    try:
        # wait out the warming window (503 + Retry-After): the axis
        # measures the steady state startup warmup buys, not the warmup
        deadline = time.monotonic() + 600
        while time.monotonic() < deadline:
            s = socket.create_connection((host, port), timeout=10)
            s.sendall(b"GET /healthz HTTP/1.1\r\nHost: b\r\n\r\n")
            status = s.recv(4096).split(b" ", 2)[1]
            s.close()
            if status == b"200":
                break
            time.sleep(0.1)
        else:
            raise TimeoutError("server never left the warming state")
        jobs = [(host, port, r) for r in reqs]
        ttfts = []
        with ThreadPoolExecutor(n_req) as pool:
            for _ in range(2 if fast else 3):
                ttfts.extend(pool.map(sse_ttft, jobs))
        st = srv.engine_loop.engine.stats()
    finally:
        srv.close()
    ms = sorted(1e3 * t for t in ttfts)
    axis["ttft_ms"] = {
        "p50": round(float(np.percentile(ms, 50)), 2),
        "p99": round(float(np.percentile(ms, 99)), 2),
        "max": round(ms[-1], 2), "n": len(ms),
        "warmup_seconds": round(st["warmup_seconds"], 3),
        "warmup_traces": st["warmup_traces"],
    }

    # MLPerf-style offline scenario: the whole sample set is available
    # before the run starts; the metric is completed samples per second
    # over the full drain (and generated tokens/sec alongside)
    offline = {}
    for packing in (True, False):
        serve(packing, "simulated", None, 8, 16)       # warm jit buckets
        best = None
        for _ in range(2 if fast else 3):
            eng = ContinuousEngine(model, qparams, decode_horizon=8,
                                   prefill_packing=packing, **base_kw)
            for r in reqs:
                eng.submit(*r)
            t0 = time.perf_counter()
            eng.run()
            dt = time.perf_counter() - t0
            if best is None or dt < best[0]:
                best = (dt, eng.n_tokens_out)
            eng.close()
        dt, toks = best
        offline["packed" if packing else "unpacked"] = {
            "seconds": round(dt, 3),
            "samples_per_s": round(n_req / dt, 2),
            "tokens_per_s": round(toks / dt, 1),
        }
    axis["offline_scenario"] = offline
    return axis


def _run_overload_axis(model, qparams, fast):
    """Overload axis (DESIGN.md Sec. 17): the same mixed-priority request
    stream offered open-loop at 1x and 2x the serving capacity, with the
    ``OverloadController`` closing the loop over the default brownout
    ladder. Reports goodput (completed tokens per engine step and per wall
    second), shed fraction by class, and the brownout-level timeline, and
    asserts goodput at 2x offered load stays >= 0.9x goodput at 1x — the
    controller turns excess load into explicitly shed batch work instead
    of letting throughput collapse."""
    from repro.serve import ContinuousEngine, OverloadController, Saturated

    rng = np.random.default_rng(31)
    n_req = 24 if fast else 48
    cohort = []
    for i in range(n_req):
        plen = int(rng.integers(5, 12))
        cohort.append((rng.integers(1, 64, (plen,)).astype(np.int32), 8,
                       ("interactive", "standard", "batch")[i % 3]))

    def serve(per_step):
        eng = ContinuousEngine(model, qparams, max_batch=4, page_size=4,
                               num_pages=24, max_seq=32, prefill_chunk=8,
                               decode_horizon=4, max_waiting=64)
        # the class-blind demand bound would 429 everything first; this
        # axis measures the controller's class-aware response instead
        eng.scheduler.oversubscribe = 100.0
        ctrl = OverloadController(eng, interval_s=0.0, up=0.6, down=0.25,
                                  up_ticks=1, down_ticks=3,
                                  min_dwell_ticks=2)
        shed = {"interactive": 0, "standard": 0, "batch": 0}
        timeline = [[0, 0]]              # [step, level] on every change
        tokens = steps = 0
        next_i = 0
        t0 = time.perf_counter()
        while next_i < len(cohort) or eng.scheduler.has_work:
            for _ in range(per_step):
                if next_i >= len(cohort):
                    break
                prompt, max_new, cls = cohort[next_i]
                try:
                    eng.submit(prompt, max_new, priority=cls,
                               deadline_ms=120_000)
                except Saturated:
                    shed[cls] += 1
                next_i += 1
            eng.step()
            steps += 1
            assert steps < 5000, "overload axis stalled"
            for _rid, (new, _done) in eng.stream_updates().items():
                tokens += len(new)
            ctrl.tick()
            if ctrl.level != timeline[-1][1]:
                timeline.append([steps, ctrl.level])
        dt = time.perf_counter() - t0
        eng.close(check=True)
        n_shed = sum(shed.values())
        return {
            "offered_per_step": per_step,
            "completed_tokens": tokens,
            "steps": steps,
            "seconds": round(dt, 3),
            "goodput_tokens_per_step": round(tokens / steps, 3),
            "goodput_tokens_per_s": round(tokens / dt, 1),
            "shed_by_class": shed,
            "shed_frac": round(n_shed / n_req, 3),
            "peak_level": max(lv for _s, lv in timeline),
            "transitions": ctrl.n_transitions,
            "level_timeline": timeline,
        }

    serve(1)                             # warm every jit bucket
    one = serve(1)
    two = serve(2)
    axis = {"n_requests": n_req, "load_1x": one, "load_2x": two,
            "goodput_ratio_2x_vs_1x": round(
                two["goodput_tokens_per_step"] /
                one["goodput_tokens_per_step"], 3)}
    assert axis["goodput_ratio_2x_vs_1x"] >= 0.9, axis
    assert two["shed_by_class"]["interactive"] == 0, axis
    return axis


def _run_continuous(model, params, reqs, arrivals, warm=True):
    from repro.serve import ContinuousEngine

    def serve():
        eng = ContinuousEngine(model, params, max_batch=8, page_size=4,
                               num_pages=96, max_seq=36, prefill_chunk=8)
        i, t = 0, 0
        while i < len(reqs) or eng.scheduler.has_work:
            while i < len(reqs) and arrivals[i] <= t:
                eng.submit(*reqs[i])
                i += 1
            if not eng.step() and i < len(reqs):
                t = arrivals[i]
                continue
            t += 1
        return eng

    if warm:
        serve()              # warm every jit bucket (cache shared per model)
    t0 = time.perf_counter()
    eng = serve()
    return {"tokens": eng.n_tokens_out,
            "seconds": round(time.perf_counter() - t0, 3),
            "work_positions": eng.n_work_positions, "steps": eng.n_steps,
            "preemptions": eng.scheduler.n_preemptions}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="artifacts/serve_bench.json")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    model, qparams, fparams = _build()
    n_req = 8 if args.fast else 16

    patterns = {
        "burst": lambda n: [0] * n,
        "staggered": lambda n: list(range(0, 6 * n, 6)),
    }
    report = {"n_requests": n_req, "model": model.cfg.name, "patterns": {}}
    for ragged in (False, True):
        reqs = _requests(rng, n_req, ragged)
        for pat, arr_fn in patterns.items():
            arrivals = arr_fn(n_req)
            key = f"{pat}{'_ragged' if ragged else ''}"
            s = _run_static(model, qparams, reqs, arrivals)
            c = _run_continuous(model, qparams, reqs, arrivals)
            s["tokens_per_s"] = round(s["tokens"] / s["seconds"], 1)
            c["tokens_per_s"] = round(c["tokens"] / c["seconds"], 1)
            s["work_efficiency"] = round(s["tokens"] / s["work_positions"], 3)
            c["work_efficiency"] = round(c["tokens"] / c["work_positions"], 3)
            report["patterns"][key] = {
                "static": s, "continuous": c,
                "work_efficiency_gain": round(
                    c["work_efficiency"] / s["work_efficiency"], 2),
            }
            print(f"[serve_bench] {key:18s} efficiency: "
                  f"static {s['work_efficiency']:.3f} | "
                  f"continuous {c['work_efficiency']:.3f} "
                  f"(x{report['patterns'][key]['work_efficiency_gain']:.2f})"
                  f" | wall tok/s {s['tokens_per_s']:.0f} vs "
                  f"{c['tokens_per_s']:.0f}")

    reqs = _requests(rng, n_req, True)
    report["execution"] = _run_execution_axis(model, qparams, reqs)
    ex = report["execution"]
    print(f"[serve_bench] execution axis: identical={ex['outputs_identical']}"
          f" | bits packed/simulated {ex['packed_vs_simulated_bits']:.3f}"
          f" | wall s {ex['simulated']['seconds']} vs "
          f"{ex['packed']['seconds']}")

    report["tensor_parallel"] = _run_tp_axis(model, qparams, reqs)
    tpx = report["tensor_parallel"]
    ident = [f"{k}={v.get('tokens_identical_to_tp1', '-')}"
             for k, v in tpx["sizes"].items()]
    print(f"[serve_bench] tp axis ({tpx['devices']} devices): "
          + " | ".join(f"{k} {v['seconds']}s" for k, v in tpx["sizes"].items())
          + f" | identity {' '.join(ident)}")

    report["decode_horizon"] = _run_horizon_axis(model, qparams, args.fast)
    hx = report["decode_horizon"]["horizons"]
    print("[serve_bench] decode_horizon axis (cohort): "
          + " | ".join(f"{k} {v['cohort']['decode_dispatches']} disp "
                       f"{v['cohort']['tokens_per_s']:.0f} tok/s"
                       for k, v in hx.items())
          + f" | identity {hx['h8'].get('tokens_identical_to_h1')}")
    print("[serve_bench] decode_horizon axis (single): "
          + " | ".join(
              "{} dpt {}".format(
                  k, v["single_stream"]["decode_dispatches_per_token"])
              for k, v in hx.items())
          + " | h8 wall vs h1 x{}".format(
              hx["h8"]["single_stream"].get("tokens_per_s_vs_h1")))

    report["prefix_sharing"] = _run_prefix_axis(
        model, qparams, n_req=4 if args.fast else 8)
    px = report["prefix_sharing"]
    for k, v in px["configs"].items():
        print(f"[serve_bench] prefix axis {k:15s}: hits {v['hits']} | "
              f"saved {v['positions_saved']} positions | work "
              f"{v['work_positions_off']} -> {v['work_positions_on']} | "
              f"identical {v['outputs_identical']}")

    report["api_overhead"] = _run_api_overhead_axis(model, qparams, args.fast)
    ao = report["api_overhead"]
    print(f"[serve_bench] api_overhead axis: direct "
          f"{ao['direct']['tokens_per_s']:.0f} tok/s | http "
          f"{ao['http']['tokens_per_s']:.0f} tok/s | overhead "
          f"{ao['overhead_frac']:+.1%} | ttft p50 {ao['ttft_ms']['p50']}ms "
          f"p90 {ao['ttft_ms']['p90']}ms max {ao['ttft_ms']['max']}ms | "
          f"identical {ao['outputs_identical']}")

    report["fault_recovery"] = _run_fault_recovery_axis(
        model, qparams, args.fast)
    fr = report["fault_recovery"]
    print(f"[serve_bench] fault_recovery axis: {fr['faults_fired']} faults "
          f"-> {fr['restarts']} restarts | recovery p50 "
          f"{fr['recovery_ms']['p50']}ms max {fr['recovery_ms']['max']}ms | "
          f"replayed {fr['replayed_tokens']} tokens "
          f"({fr['replay_overhead_frac']:.1%}) | wall x{fr['wall_slowdown']} "
          f"| identical {fr['outputs_identical']} | pool clean "
          f"{fr['pool_audit_clean']}")

    report["prefill_packing"] = _run_prefill_packing_axis(
        model, qparams, args.fast)
    pp = report["prefill_packing"]
    dpps = [c["dispatches_per_prompt_packed"] for c in pp["cells"].values()]
    idents = all(c["outputs_identical"] for c in pp["cells"].values())
    print(f"[serve_bench] prefill_packing axis: {len(pp['cells'])} cells | "
          f"dispatches/prompt packed {min(dpps)}-{max(dpps)} (< 1) | "
          f"identical {idents} | ttft p50 {pp['ttft_ms']['p50']}ms "
          f"p99 {pp['ttft_ms']['p99']}ms | offline "
          f"{pp['offline_scenario']['packed']['samples_per_s']} samples/s "
          f"packed vs "
          f"{pp['offline_scenario']['unpacked']['samples_per_s']} unpacked")

    report["overload"] = _run_overload_axis(model, qparams, args.fast)
    ov = report["overload"]
    print(f"[serve_bench] overload axis: goodput 1x "
          f"{ov['load_1x']['goodput_tokens_per_step']} tok/step vs 2x "
          f"{ov['load_2x']['goodput_tokens_per_step']} tok/step "
          f"(x{ov['goodput_ratio_2x_vs_1x']}) | shed@2x "
          f"{ov['load_2x']['shed_by_class']} | peak level "
          f"{ov['load_2x']['peak_level']} "
          f"({ov['load_2x']['transitions']} transitions)")

    report["kv_quant"] = _run_kv_quant_axis(model, qparams, fparams,
                                            args.fast)
    kq = report["kv_quant"]
    print("[serve_bench] kv_quant axis: "
          + " | ".join(f"{k} {v['pool_pages']}p cap {v['capacity']}"
                       for k, v in kq["bits"].items())
          + f" | 4-bit capacity x{kq['capacity_gain_4bit']} | kv8 identical "
          f"{kq['fidelity']['kv8_identical']} | rel-mse "
          f"kv8 {kq['roundtrip_rel_mse']['kv8']:.2e} "
          f"kv4 {kq['roundtrip_rel_mse']['kv4']:.2e}")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    # standalone copy of the overload axis so CI can track goodput-under-
    # load and the brownout timeline without parsing the whole report
    ov_out = os.path.join(os.path.dirname(args.out), "overload_axis.json")
    with open(ov_out, "w") as f:
        json.dump(report["overload"], f, indent=2)
    print(f"[serve_bench] wrote {args.out} and {ov_out}")
    print(json.dumps(report))


if __name__ == "__main__":
    main()
