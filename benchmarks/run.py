"""Benchmark runner — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints CSV blocks (``name,...`` header per section) and, when dry-run
artifacts exist (artifacts/dryrun/*.json), the roofline summary table.
"""
import argparse
import sys


def _emit(name, rows):
    print(f"\n### {name}")
    for row in rows:
        print(",".join(str(x) for x in row))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import tables

    sections = [
        ("table1_quality_markov_lm", tables.table1_quality),
        ("table2_first_linear_mse", tables.table2_first_linear_mse),
        ("table3_model_quant_time", tables.table3_model_quant_time),
        ("table4_dp_vs_wgm", tables.table4_dp_vs_wgm),
        ("table5_lambda_sweep", tables.table5_lambda_sweep),
        ("table6_block_sweep", tables.table6_block_window_sweep),
        ("table7_max_group_sweep", tables.table7_max_group_sweep),
        ("table7b_window_sweep", tables.table7b_window_sweep),
        ("figures2to5_size_sweep", tables.figures_size_sweep),
        ("kernel_bench", tables.kernel_bench),
    ]
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        try:
            _emit(name, fn())
        except Exception as e:  # noqa: BLE001 — a bench failure shouldn't hide others
            _emit(name, [("ERROR", repr(e))])

    # roofline summary from dry-run artifacts, if present
    try:
        from .roofline_report import emit_summary
        emit_summary()
    except Exception as e:  # noqa: BLE001
        print(f"\n### roofline_summary\nunavailable: {e!r}")


if __name__ == "__main__":
    main()
