"""Distributed semantics on 8 host devices: sharded == single-device."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import smoke_config
from repro.models import Model
from repro.models.moe import moe_layer
from repro.parallel import ParallelContext, from_mesh, resolve_spec, \
    tree_shardings
from repro.train import AdamW, OptConfig, init_state, make_train_step


def make_mesh(shape=(4, 2), axes=("data", "model")):
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


@pytest.mark.requires_env("axis_type")
def test_resolver_rules():
    ctx = from_mesh(make_mesh())
    # divisible dims get sharded
    assert resolve_spec(("fsdp", "tp"), (8, 16), ctx) == P("data", "model")
    # non-divisible dims are dropped
    assert resolve_spec(("fsdp", "tp"), (3, 16), ctx) == P(None, "model")
    # kv_seq grabs every idle axis: tp when batch took data, everything
    # (joint) when batch is unshardable (long_500k), data+pod leftovers
    assert resolve_spec(("batch", "kv_seq"), (8, 64), ctx) == P("data", "model")
    assert resolve_spec(("batch", "kv_seq"), (1, 64), ctx) == \
        P(None, ("data", "model"))
    assert resolve_spec(("batch", "kv_seq"), (8, 3), ctx) == P("data",)
    # heads fallback to head_dim
    assert resolve_spec(("batch", None, "heads", "head_dim"),
                        (8, 4, 3, 16), ctx) == P("data", None, None, "model")


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "granite-moe-3b-a800m",
                                  "jamba-v0.1-52b"])
@pytest.mark.requires_env("axis_type")
def test_sharded_loss_matches_single_device(arch):
    """The distributed forward is numerically the single-device forward."""
    cfg = smoke_config(arch)
    cfg = dataclasses.replace(cfg, n_experts=8) if cfg.n_experts else cfg
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 8, 32
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    loss_1, _ = jax.jit(model.loss)(params, batch)

    mesh = make_mesh()
    ctx = from_mesh(mesh)
    psh = tree_shardings(ctx, model.param_axes(), model.param_shapes())
    params_s = jax.device_put(params, psh)
    batch_s = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    with mesh:
        loss_8, _ = jax.jit(
            lambda p, b: model.loss(p, b, ctx))(params_s, batch_s)
    # MoE capacity differs per shard layout -> small tolerance for moe archs
    tol = 0.05 if cfg.n_experts else 1e-3
    assert float(loss_8) == pytest.approx(float(loss_1), rel=tol)


@pytest.mark.requires_env("axis_type")
def test_moe_ep_matches_local(rng):
    """shard_map all-to-all EP == single-device dispatch (same capacity)."""
    cfg = dataclasses.replace(smoke_config("granite-moe-3b-a800m"),
                              n_experts=8, n_experts_active=2,
                              capacity_factor=8.0)   # no drops -> exact
    d, e, f = cfg.d_model, cfg.n_experts_padded, cfg.d_ff
    p = {"router": jnp.asarray(rng.standard_normal((d, e)), jnp.float32),
         "wg": jnp.asarray(rng.standard_normal((e, d, f)) * 0.05, jnp.float32),
         "wi": jnp.asarray(rng.standard_normal((e, d, f)) * 0.05, jnp.float32),
         "wo": jnp.asarray(rng.standard_normal((e, f, d)) * 0.05, jnp.float32)}
    x = jnp.asarray(rng.standard_normal((8, 16, d)), jnp.float32)

    y_local, _ = moe_layer(p, x, cfg, None)

    mesh = make_mesh()
    ctx = from_mesh(mesh)
    wsh = NamedSharding(mesh, P("model", "data", None))
    p_s = {"router": p["router"], "wg": jax.device_put(p["wg"], wsh),
           "wi": jax.device_put(p["wi"], wsh),
           "wo": jax.device_put(p["wo"], wsh)}
    x_s = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
    with mesh:
        y_ep, _ = jax.jit(lambda pp, xx: moe_layer(pp, xx, cfg, ctx))(p_s, x_s)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_local),
                               atol=5e-4, rtol=5e-3)


@pytest.mark.requires_env("axis_type")
def test_sharded_train_step_runs(rng):
    cfg = smoke_config("internlm2-1.8b")
    model = Model(cfg)
    mesh = make_mesh()
    ctx = from_mesh(mesh)
    opt = AdamW(OptConfig(warmup_steps=1))
    state = init_state(model, opt, jax.random.PRNGKey(0))
    sh = tree_shardings(ctx, {"params": model.param_axes(),
                              "opt": opt.moment_axes(model.param_axes(),
                                                     model.param_shapes())},
                        jax.eval_shape(lambda: state))
    state = jax.device_put(state, sh)
    B, S = 8, 32
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    with mesh:
        step = jax.jit(make_train_step(model, opt, ctx), donate_argnums=0)
        state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["opt"]["step"]) == 1


def test_production_mesh_shapes():
    # the real 256/512-chip meshes can't be built on 8 host devices; check
    # the constructor signature contract instead
    import repro.launch.mesh as m
    assert m.make_production_mesh.__kwdefaults__ == {"multi_pod": False}


def test_compression_error_feedback(rng):
    from repro.train.compression import ef_compress, ef_decompress
    g = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    res = jnp.zeros_like(g)
    q, scale, res2 = ef_compress(g, res, block=64)
    deq = ef_decompress(q, scale, g.shape)
    # residual telescopes: g == deq + res2
    np.testing.assert_allclose(np.asarray(deq + res2), np.asarray(g),
                               atol=1e-5)
    assert q.dtype == jnp.int8


@pytest.mark.requires_env("axis_type")
def test_psum_compressed_under_shard_map(rng):
    from repro.train.compression import psum_compressed
    mesh = make_mesh((8,), ("data",))
    g = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    res = jnp.zeros_like(g)

    def f(gl, rl):
        avg, new_res = psum_compressed(gl[0], rl[0], "data")
        return avg[None], new_res[None]

    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map
    with mesh:
        avg, _ = jax.jit(shard_map(
            f, mesh=mesh, in_specs=(P("data", None), P("data", None)),
            out_specs=(P("data", None), P("data", None))))(g, res)
    true_avg = np.asarray(g).mean(axis=0)
    got = np.asarray(avg)[0]
    # int8 EF all-reduce: ~1% error on the first step
    np.testing.assert_allclose(got, true_avg, atol=0.05)
