"""Solver correctness: exact DP vs brute force; heuristics vs the oracle."""
import itertools

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import assume, given, settings, strategies as st

from repro.core import reference, solve_flat, dp_boundaries, \
    kmeans1d_boundaries
from repro.core.grouping import boundaries_to_levels, scales_from_boundaries


def brute_force_cost(a, g):
    v = np.sort(np.abs(a))
    n = v.size
    best = np.inf
    for cuts in itertools.combinations(range(1, n), g - 1):
        bb = [0, *cuts, n]
        c = sum(((v[bb[i]:bb[i + 1]] - v[bb[i]:bb[i + 1]].mean()) ** 2).sum()
                for i in range(g))
        best = min(best, c)
    return best


@pytest.mark.parametrize("n,g", [(8, 2), (10, 3), (12, 4)])
def test_numpy_dp_matches_brute_force(rng, n, g):
    a = rng.standard_normal(n)
    _, _, cost = reference.dynamic_grouping(a, g)
    assert cost == pytest.approx(brute_force_cost(a, g), rel=1e-9)


@pytest.mark.parametrize("n,g", [(10, 3), (16, 4), (64, 8)])
def test_jax_dp_matches_numpy_dp(rng, n, g):
    """The vectorized TPU DP finds the same optimum as the reference DP."""
    a = rng.standard_normal(n)
    _, _, cost_ref = reference.dynamic_grouping(a, g)
    v = jnp.sort(jnp.abs(jnp.asarray(a, jnp.float32)))
    _, cost_jax = dp_boundaries(v, g)
    assert float(cost_jax) == pytest.approx(cost_ref, rel=1e-4)


def test_jax_dp_reconstruction(rng):
    a = rng.standard_normal(64)
    levels, scales = solve_flat(jnp.asarray(a, jnp.float32), 8, method="dp")
    w_hat = np.sign(a) * np.asarray(scales)[np.asarray(levels)]
    b, order, cost = reference.dynamic_grouping(a, 8)
    w_ref, _, _ = reference.reconstruct(a, b, order)
    assert ((a - w_hat) ** 2).sum() == pytest.approx(
        ((a - w_ref) ** 2).sum(), rel=1e-4)


@given(st.integers(2, 6), st.lists(
    st.floats(0.0078125, 4, allow_nan=False, width=32).flatmap(
        lambda m: st.sampled_from([m, -m])), min_size=8, max_size=40))
@settings(max_examples=30, deadline=None)
def test_heuristics_never_beat_dp(g, vals):
    """Property: DP is optimal — GG/WGM/WGM-LO/kmeans cost >= DP cost.

    Zero-free tensors only: exact zeros reconstruct exactly (the paper's
    zero-loss special group) which the interval objective doesn't model.
    """
    a = np.asarray(vals)
    _, _, dp_cost = reference.dynamic_grouping(a, g)

    def sse_of(bounds, order):
        w, _, _ = reference.reconstruct(a, bounds, order)
        return ((a - w) ** 2).sum()

    for solver in ("gg", "wgm", "wgm_lo"):
        if solver == "gg":
            b, o = reference.greedy_grouping(a, g)
        elif solver == "wgm":
            b, o = reference.windowed_greedy_merging(a, g, window=2)
        else:
            b, o = reference.wgm_local_opt(a, g, n_bins=8)
        assert sse_of(b, o) >= dp_cost - 1e-6, solver


@given(st.lists(st.floats(-4, 4, allow_nan=False, width=32),
                min_size=16, max_size=64), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_more_groups_never_hurt_dp(vals, g):
    a = np.asarray(vals)
    _, _, c1 = reference.dynamic_grouping(a, g)
    _, _, c2 = reference.dynamic_grouping(a, g + 1)
    assert c2 <= c1 + 1e-9


def test_kmeans_boundaries_valid(rng):
    v = jnp.sort(jnp.abs(jnp.asarray(rng.standard_normal(512), jnp.float32)))
    b = kmeans1d_boundaries(v, 32)
    bn = np.asarray(b)
    assert bn[0] == 0 and bn[-1] == 512
    assert (np.diff(bn) >= 0).all()
    levels = boundaries_to_levels(b, 512)
    assert levels.min() >= 0 and levels.max() < 32


def test_wdp_close_to_dp(rng):
    """Windowed DP lands within 2% of the exact DP optimum."""
    from repro.core import windowed_dp_boundaries
    a = rng.standard_normal(256).astype(np.float32)
    v = jnp.sort(jnp.abs(jnp.asarray(a)))
    _, dp_cost = dp_boundaries(v, 8)
    bk = windowed_dp_boundaries(v, 8, n_windows=64)
    scales = scales_from_boundaries(v, bk)
    lv = boundaries_to_levels(bk, 256)
    sse = float(jnp.sum((v - scales[lv]) ** 2))
    assert sse <= 1.02 * float(dp_cost) + 1e-6


def test_kmeans_is_valid_but_local(rng):
    """Plain Lloyd is a valid grouping but may sit at a local optimum —
    the reason the per-tensor default is the windowed DP."""
    a = rng.standard_normal(256).astype(np.float32)
    v = jnp.sort(jnp.abs(jnp.asarray(a)))
    _, dp_cost = dp_boundaries(v, 8)
    bk = kmeans1d_boundaries(v, 8, iters=50)
    scales = scales_from_boundaries(v, bk)
    lv = boundaries_to_levels(bk, 256)
    sse = float(jnp.sum((v - scales[lv]) ** 2))
    assert float(dp_cost) - 1e-5 <= sse <= 2.0 * float(dp_cost)


def test_wgm_window_degenerates_to_xnor(rng):
    """Appendix D: window >= n collapses WGM to a single XNOR group set."""
    a = rng.standard_normal(32)
    b, o = reference.windowed_greedy_merging(a, 8, window=64)
    assert len(b) == 2  # one group


def test_zero_handling(rng):
    a = rng.standard_normal(64)
    a[::7] = 0.0
    levels, scales = solve_flat(jnp.asarray(a, jnp.float32), 8, method="dp")
    w_hat = np.sign(a) * np.asarray(scales)[np.asarray(levels)]
    assert (w_hat[a == 0] == 0).all()  # exact zeros reconstruct to zero
