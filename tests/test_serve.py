"""Serving engine + quantized-weight serving equivalence."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import QuantPolicy, quantize_params, dequantize_params
from repro.models import Model
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return model, params


def test_generate_shapes(setup, rng):
    model, params = setup
    eng = ServeEngine(model, params, max_seq=64)
    prompts = jnp.asarray(rng.integers(0, 64, (3, 8)), jnp.int32)
    out = eng.generate(prompts, n_tokens=5)
    assert out.shape == (3, 5)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < 64).all()


def test_decode_matches_teacher_forcing(setup, rng):
    """Greedy decode logits == full forward logits at the same positions."""
    model, params = setup
    toks = jnp.asarray(rng.integers(0, 64, (2, 12)), jnp.int32)
    # full forward: logits at position 11 (predicting 12)
    logits_full, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    # prefill 11 tokens then decode token 11
    eng = ServeEngine(model, params, max_seq=32)
    logits_pre, cache = eng._prefill(params, {"tokens": toks[:, :11]})
    cache = eng._grow_cache(cache, 11)
    logits_dec, _ = eng._decode(params, cache, toks[:, 11:12],
                                jnp.full((2,), 11, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_dec[:, :64]),
                               np.asarray(logits_full[:, :64]),
                               atol=2e-3, rtol=1e-2)


def test_quantized_serving_close_to_dense(setup, rng):
    """MSB-quantized params serve logits close to dequantized-dense params
    (identical by construction: dense() dequantizes QTensor leaves)."""
    model, params = setup
    qparams, report = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="dp", min_size=1024))
    assert report, "policy must quantize something"
    dense = dequantize_params(qparams, dtype=jnp.float32)
    toks = jnp.asarray(rng.integers(0, 64, (2, 8)), jnp.int32)
    lq, _ = jax.jit(model.prefill)(qparams, {"tokens": toks})
    ld, _ = jax.jit(model.prefill)(dense, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lq[:, :64]), np.asarray(ld[:, :64]),
                               atol=1e-3, rtol=1e-3)


def test_quantized_score_degrades_gracefully(setup, rng):
    model, params = setup
    toks = jnp.asarray(rng.integers(0, 64, (4, 16)), jnp.int32)
    eng_fp = ServeEngine(model, params, max_seq=32)
    nll_fp = eng_fp.score(toks)
    qparams, _ = quantize_params(params, QuantPolicy(bits=4, block=64,
                                                     solver="dp",
                                                     min_size=1024))
    eng_q = ServeEngine(model, qparams, max_seq=32)
    nll_q = eng_q.score(toks)
    # untrained model on random tokens: quantization moves NLL only slightly
    assert abs(nll_q - nll_fp) < 0.5
