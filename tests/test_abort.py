"""Request cancellation + admission backpressure: nothing leaks.

``ContinuousEngine.abort_request`` must return the allocator to baseline
whatever the request was doing — waiting, mid-chunked-prefill, decoding
under an outstanding decode-horizon lease, or sharing prefix-cache pages —
and ``Scheduler.would_accept`` must shed load without mutating state.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import Model
from repro.serve import ContinuousEngine, Saturated


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return model, params


def _assert_pool_baseline(cache):
    """Every page is free or parked cached-but-alive on the prefix LRU; no
    refcounts, no slots, no dangling registry entries."""
    assert cache.n_free_pages + cache.n_cached_pages == cache.num_pages - 1
    assert (cache.ref_counts[1:] == 0).all() and cache.ref_counts[0] == 1
    assert cache.n_free_slots == cache.max_seqs
    # a registered page with no referents must be exactly the LRU set
    assert set(cache._page_digest) == set(cache._lru)


def _prompt(rng, n=6):
    return rng.integers(0, 64, (n,)).astype(np.int32)


def test_abort_waiting_request(setup, rng):
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=2, page_size=4,
                           num_pages=32, prefill_chunk=8)
    keep = eng.submit(_prompt(rng), 4)
    gone = eng.submit(_prompt(rng), 4)       # never stepped: still waiting
    assert eng.abort_request(gone) is True
    done = eng.run()
    assert sorted(done) == [keep]
    _assert_pool_baseline(eng.cache)
    assert eng.n_aborts == 1 and eng.scheduler.n_aborts == 1


def test_abort_running_mid_decode_leaves_peer_identical(setup, rng):
    model, params = setup
    reqs = [(_prompt(rng), 8), (_prompt(rng), 8)]
    solo = ContinuousEngine(model, params, max_batch=2, page_size=4,
                            num_pages=32, prefill_chunk=8)
    rid = solo.submit(*reqs[0])
    ref = solo.run()[rid]
    eng = ContinuousEngine(model, params, max_batch=2, page_size=4,
                           num_pages=32, prefill_chunk=8)
    a = eng.submit(*reqs[0])
    b = eng.submit(*reqs[1])
    for _ in range(6):                       # both prefilled + decoding
        eng.step()
    assert eng.abort_request(b) is True
    done = eng.run()
    assert sorted(done) == [a]
    np.testing.assert_array_equal(done[a], ref)
    _assert_pool_baseline(eng.cache)


def test_abort_under_outstanding_horizon_lease(setup, rng):
    """decode_horizon=8 reserves the whole lease up front; aborting between
    horizon dispatches must return leased-but-unwritten pages too."""
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=2, page_size=2,
                           num_pages=64, prefill_chunk=8, decode_horizon=8)
    # odd prompt length: the committed extent after a full horizon dispatch
    # lands mid-page, so the reservation provably extends past it
    rid = eng.submit(_prompt(rng, 5), 24)
    while not any(s.state == "decode" for s in eng.scheduler.running):
        eng.step()
    eng.step()                               # one horizon dispatch done
    seq = eng.scheduler.running[0]
    assert eng.cache.n_covered_tokens(seq.slot) > seq.cache_len, \
        "test needs an outstanding lease beyond the committed extent"
    assert eng.abort_request(rid) is True
    assert eng.run() == {}
    _assert_pool_baseline(eng.cache)


def test_abort_releases_prefix_refs_shared_pages_survive(setup, rng):
    """Abort a request that adopted registry pages: the refcounts drop,
    the pages stay adoptable, and a later identical request still hits."""
    model, params = setup
    shared = _prompt(rng, 16)                # 4 full pages at page_size=4
    eng = ContinuousEngine(model, params, max_batch=2, page_size=4,
                           num_pages=64, prefill_chunk=4)
    first = eng.submit(np.concatenate([shared, _prompt(rng, 3)]), 4)
    out_first = eng.run()[first]
    assert out_first is not None
    second = eng.submit(np.concatenate([shared, _prompt(rng, 2)]), 4)
    eng.step()                               # admit (adopts prefix pages)
    assert eng.n_prefix_hits == 1
    assert eng.abort_request(second) is True
    assert eng.run() == {}
    _assert_pool_baseline(eng.cache)
    third = eng.submit(np.concatenate([shared, _prompt(rng, 2)]), 4)
    out = eng.run()
    assert eng.n_prefix_hits == 2 and sorted(out) == [third]
    _assert_pool_baseline(eng.cache)


def test_abort_finished_uncollected_drops_result(setup, rng):
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=2, page_size=4,
                           num_pages=32, prefill_chunk=8)
    rid = eng.submit(_prompt(rng), 2)
    while eng.step():
        pass
    assert eng.abort_request(rid) is False   # finished: abort is a no-op...
    assert eng.collect() == {}               # ...but the output is dropped
    assert eng.n_aborts == 0
    _assert_pool_baseline(eng.cache)


def test_abort_unknown_or_twice_raises(setup, rng):
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=2, page_size=4,
                           num_pages=32, prefill_chunk=8)
    with pytest.raises(KeyError):
        eng.abort_request(123)
    rid = eng.submit(_prompt(rng), 2)
    assert eng.abort_request(rid) is True
    with pytest.raises(KeyError):
        eng.abort_request(rid)


def test_stream_updates_incremental_and_exactly_once(setup, rng):
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=2, page_size=4,
                           num_pages=32, prefill_chunk=8)
    solo = ContinuousEngine(model, params, max_batch=2, page_size=4,
                            num_pages=32, prefill_chunk=8)
    p = _prompt(rng)
    srid = solo.submit(p, 9)
    ref = solo.run()[srid]
    rid = eng.submit(p, 9)
    got, finished = [], False
    while eng.step():
        for r, (new, done) in eng.stream_updates().items():
            assert r == rid
            got.extend(new)
            finished = finished or done
    for r, (new, done) in eng.stream_updates().items():
        got.extend(new)
        finished = finished or done
    assert finished and np.array_equal(np.asarray(got, np.int32), ref)
    assert eng.collect() == {}               # streamed requests are retired
    assert eng.stream_updates() == {}        # nothing reported twice


def test_would_accept_capacity_vs_saturation(setup, rng):
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=2, page_size=2,
                           num_pages=9, prefill_chunk=4, max_waiting=1)
    # permanent: can never fit -> ValueError (probe and submit agree)
    err = eng.would_accept(10, 8)
    assert isinstance(err, ValueError) and not isinstance(err, Saturated)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(10, np.int32), 8)
    # feasible and idle -> accepted
    assert eng.would_accept(4, 2) is None
    a = eng.submit(_prompt(rng, 4), 2)
    eng.step()                               # a admitted: queue drains
    # queue bound: one waiting request is allowed, the next is shed
    b = eng.submit(_prompt(rng, 4), 2)
    err = eng.would_accept(4, 2)
    assert isinstance(err, Saturated)
    with pytest.raises(Saturated):
        eng.submit(_prompt(rng, 4), 2)
    done = eng.run()
    assert sorted(done) == [a, b]
    # drained: accepts again, nothing leaked by the rejected submits
    assert eng.would_accept(4, 2) is None
    _assert_pool_baseline(eng.cache)


def test_would_accept_no_queueing_mode(setup, rng):
    """max_waiting=0 means 'reject unless admissible immediately': an idle
    engine accepts, one running request makes the next submit shed."""
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=1, page_size=4,
                           num_pages=32, prefill_chunk=8, max_waiting=0)
    assert eng.would_accept(6, 4) is None
    rid = eng.submit(_prompt(rng), 4)
    eng.step()                               # admitted into the batch
    assert isinstance(eng.would_accept(6, 4), Saturated)
    eng.run()
    assert eng.would_accept(6, 4) is None


def test_would_accept_page_demand_bound(setup, rng):
    """Outstanding page demand beyond oversubscribe x pool saturates even
    when the waiting-queue count bound alone would admit."""
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=2, page_size=2,
                           num_pages=9, prefill_chunk=4, max_waiting=64)
    eng.scheduler.oversubscribe = 1.0
    eng.submit(_prompt(rng, 4), 4)           # 4 pages of demand
    eng.submit(_prompt(rng, 4), 4)           # 8 of 8 usable
    err = eng.would_accept(4, 4)
    assert isinstance(err, Saturated) and "page pool" in str(err)
    eng.run()
    assert eng.would_accept(4, 4) is None
