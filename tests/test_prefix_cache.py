"""Automatic prefix cache: registry hashing, LRU eviction, admission hits,
fork_request sharing, and prefix-on/off greedy identity (DESIGN.md Sec. 11).
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.launch.mesh import make_tp_mesh
from repro.models import Model
from repro.serve import ContinuousEngine, PagedKVCache, PageStateError

PS = 4                                   # page size used throughout


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return model, params


def make_cache(model, **kw):
    kw.setdefault("num_pages", 16)
    kw.setdefault("page_size", PS)
    kw.setdefault("max_seqs", 4)
    kw.setdefault("prefix_cache", True)
    return PagedKVCache(model, **kw)


def toks(*vals):
    return np.asarray(vals, np.int32)


# ---------------------------------------------------------------------------
# registry: rolling hash, LRU lifecycle, reclaim ordering
# ---------------------------------------------------------------------------

def test_match_requires_identical_chain(setup):
    """Page i's K/V depends on every token before it, so the hash chains:
    an identical page-1 behind a different page-0 must not match."""
    model, _ = setup
    c = make_cache(model)
    s = c.alloc_slot()
    chain = toks(*range(10))             # 2 full pages + partial
    c.reserve(s, 10)
    c.commit(s, 10)
    c.register_prefix(s, chain)

    m = c.match_prefix(chain)
    assert m is not None and m.n_tokens == 8
    assert list(m.pages) == c.seq_pages[s][:2]
    # shared page-0 chain, divergent page-1 -> one page
    m1 = c.match_prefix(toks(*range(4), 50, 51, 52, 53))
    assert m1 is not None and m1.n_tokens == 4
    # same page-1 tokens behind a different page-0 -> nothing
    assert c.match_prefix(toks(9, 9, 9, 9, *range(4, 8))) is None
    # fewer than one full page can never match
    assert c.match_prefix(chain, max_tokens=3) is None


def test_release_parks_registered_pages_and_adopt_revives(setup):
    model, _ = setup
    c = make_cache(model)
    s = c.alloc_slot()
    chain = toks(*range(10))
    c.reserve(s, 10)
    c.commit(s, 10)
    c.register_prefix(s, chain)
    full_pages = list(c.seq_pages[s][:2])
    free_before = c.n_free_pages
    c.release(s)
    # full registered pages park in the LRU; the partial page is plain-freed
    assert c.n_cached_pages == 2
    assert c.n_free_pages == free_before + 1
    assert (c.ref_counts[full_pages] == 0).all()

    d = c.alloc_slot()
    m = c.match_prefix(chain)
    assert m.n_unreferenced == 2
    c.adopt_prefix(d, m)
    assert c.n_cached_pages == 0
    assert (c.ref_counts[full_pages] == 1).all()
    assert c.seq_pages[d] == full_pages
    assert int(c.seq_lens[d]) == 8       # committed without any prefill
    c.release(d)
    assert c.n_cached_pages == 2         # back to cached-but-alive


def test_reserve_reclaims_lru_before_out_of_pages(setup):
    """Cached pages are reclaimable capacity: a reservation that would
    otherwise raise OutOfPages evicts LRU pages instead (tail of the chain
    first, so surviving entries still longest-prefix match)."""
    model, _ = setup
    c = make_cache(model, num_pages=6)   # 5 usable
    s = c.alloc_slot()
    chain = toks(*range(16))
    c.reserve(s, 16)                     # all 4 full pages + none left over
    c.commit(s, 16)
    c.register_prefix(s, chain)
    c.release(s)
    assert c.n_cached_pages == 4 and c.n_free_pages == 1
    assert c.n_available_pages == 5

    d = c.alloc_slot()
    c.reserve(d, 12)                     # needs 3: 1 free + 2 reclaimed
    assert c.n_cache_evictions == 2
    # eviction came off the chain tail: the head still matches
    m = c.match_prefix(chain)
    assert m is not None and m.n_tokens == 8


def test_releasing_shared_prefix_keeps_referenced_pages_alive(setup):
    """Evicting a sequence whose pages are prefix-shared must not free (or
    LRU) pages another sequence still references."""
    model, _ = setup
    c = make_cache(model)
    s = c.alloc_slot()
    chain = toks(*range(8))
    c.reserve(s, 8)
    c.commit(s, 8)
    c.register_prefix(s, chain)
    d = c.alloc_slot()
    c.adopt_prefix(d, c.match_prefix(chain))
    pages = list(c.seq_pages[s])
    assert (c.ref_counts[pages] == 2).all()

    c.release(s)                         # the original holder goes away
    assert (c.ref_counts[pages] == 1).all()
    assert c.n_cached_pages == 0         # still referenced: not reclaimable
    assert not set(pages) & set(c._free)
    c.release(d)
    assert c.n_cached_pages == 2         # now cached-but-alive


def test_fork_under_pressure_returns_none_without_leaking(setup):
    """fork() needing a partial-page copy with a dry free list (and nothing
    reclaimable) must return None leaving slots, refcounts and the free
    list exactly as they were."""
    model, _ = setup
    c = make_cache(model, num_pages=4)   # 3 usable
    s = c.alloc_slot()
    c.reserve(s, 10)                     # 2 full + 1 partial: pool exhausted
    c.commit(s, 10)
    assert c.n_free_pages == 0 and c.n_cached_pages == 0
    slots_before = c.n_free_slots
    refs_before = c.ref_counts.copy()
    assert c.fork(s) is None
    assert c.n_free_slots == slots_before
    np.testing.assert_array_equal(c.ref_counts, refs_before)
    assert c.n_free_pages == 0


def test_table_rows_memoized_until_dirty(setup):
    model, _ = setup
    c = make_cache(model)
    s = c.alloc_slot()
    c.reserve(s, 6)
    r1 = c.table_rows([s, -1])
    assert c.table_rows([s, -1]) is r1   # clean slot: no re-upload
    c.reserve(s, 10)                     # new page -> dirty
    r2 = c.table_rows([s, -1])
    assert r2 is not r1
    assert int(np.asarray(r2)[0, 2]) == c.seq_pages[s][2]
    d = c.alloc_slot()                   # unrelated slot mutation
    assert c.table_rows([s, -1]) is r2
    c.release(s)                         # released slot invalidates its rows
    assert c.table_rows([s, -1]) is not r2


# ---------------------------------------------------------------------------
# engine: admission hits, metrics, on/off identity, fork_request
# ---------------------------------------------------------------------------

def _engine(setup, prefix_cache, mesh=None, **kw):
    model, params = setup
    kw.setdefault("max_batch", 4)
    kw.setdefault("page_size", PS)
    kw.setdefault("num_pages", 64)
    kw.setdefault("max_seq", 40)
    kw.setdefault("prefill_chunk", PS)
    return ContinuousEngine(model, params, prefix_cache=prefix_cache,
                            mesh=mesh, **kw)


def _shared_prefix_requests(rng, n, shared_len=4 * PS):
    shared = rng.integers(0, 64, (shared_len,)).astype(np.int32)
    return [(np.concatenate([shared,
                             rng.integers(0, 64, (int(rng.integers(1, 5)),))
                             .astype(np.int32)]), 6) for _ in range(n)]


def _serve_sequential(eng, reqs):
    """Each request runs to completion before the next arrives, so every
    later request can hit pages the earlier ones registered."""
    outs = {}
    for r in reqs:
        eng.submit(*r)
        outs.update(eng.run())
    return outs


def test_admission_hit_skips_shared_prefill(setup, rng):
    """The acceptance invariant at test scale: the second request's prefill
    drops by exactly the shared full-page token count (prefill_chunk ==
    page_size, so chunks align with the matched boundary), and greedy
    outputs are identical with the cache on or off."""
    reqs = _shared_prefix_requests(rng, 3)
    on = _engine(setup, True)
    out_on = _serve_sequential(on, reqs)
    off = _engine(setup, False)
    out_off = _serve_sequential(off, reqs)

    assert on.n_prefix_hits == 2
    assert on.n_prefix_positions_saved == 2 * 4 * PS
    assert off.n_work_positions - on.n_work_positions == 2 * 4 * PS
    assert sorted(out_on) == sorted(out_off)
    for rid in out_on:
        np.testing.assert_array_equal(out_on[rid], out_off[rid])


def test_identical_prompt_match_capped_before_last_token(setup, rng):
    """A byte-identical repeat prompt may match at most len-1 positions —
    the final position must be prefilled for real to produce logits."""
    prompt = rng.integers(0, 64, (3 * PS,)).astype(np.int32)
    eng = _engine(setup, True)
    out = _serve_sequential(eng, [(prompt, 5), (prompt, 5)])
    # floor((12-1)/4) = 2 full pages adopted, 4 positions prefilled
    assert eng.n_prefix_hits == 1
    assert eng.n_prefix_positions_saved == 2 * PS
    np.testing.assert_array_equal(out[0], out[1])


def test_preemption_with_prefix_cache_token_identical(setup, rng):
    """A pool sized to force preemption, cache on: reclaim happens before
    eviction, preempted sequences re-admit (often onto their own cached
    pages), and outputs still match the uncontended no-cache run."""
    reqs = [(rng.integers(0, 64, (6,)).astype(np.int32), 8)
            for _ in range(2)]
    ref = _engine(setup, False, num_pages=64, page_size=2, prefill_chunk=4,
                  max_seq=None)
    for r in reqs:
        ref.submit(*r)
    ref_out = ref.run()

    eng = _engine(setup, True, num_pages=11, page_size=2, prefill_chunk=4,
                  max_seq=None)
    for r in reqs:
        eng.submit(*r)
    out = eng.run()
    assert eng.scheduler.n_preemptions > 0, "pool sized to force preemption"
    for rid in ref_out:
        np.testing.assert_array_equal(out[rid], ref_out[rid])
    c = eng.cache
    assert c.n_free_pages + c.n_cached_pages == c.num_pages - 1
    assert (c.ref_counts[1:] == 0).all() and c.ref_counts[0] == 1


def test_fork_request_continuations_match_parent(setup, rng):
    """fork_request shares the parent's pages by refcount and each greedy
    child reproduces the parent's own continuation from the fork point."""
    prompt = rng.integers(0, 64, (9,)).astype(np.int32)
    eng = _engine(setup, True)
    rid = eng.submit(prompt, 10)
    parent = eng._seqs[rid]
    while len(parent.generated) < 3:
        assert eng.step()
    kids = eng.fork_request(rid, n=2, max_new_tokens=4)
    assert eng.n_forks == 2
    # full pages genuinely shared: refcount > 1 somewhere in the parent slot
    shared = [p for p in eng.cache.seq_pages[parent.slot]
              if eng.cache.ref_counts[p] >= 3]
    assert shared, "fork must share full pages by refcount"
    out = eng.run()
    assert sorted(out) == sorted([rid, *kids])
    for k in kids:
        np.testing.assert_array_equal(out[k], out[rid][3:7])


def test_fork_request_without_slot_falls_back_to_waiting(setup, rng):
    """Forking a request that holds no slot resubmits its tokens; nothing
    leaks and the child still completes (via the prefix cache if possible).
    """
    prompt = rng.integers(0, 64, (8,)).astype(np.int32)
    eng = _engine(setup, True)
    rid = eng.submit(prompt, 4)
    kids = eng.fork_request(rid, n=1)    # parent still waiting: no slot
    assert eng.n_forks == 0
    out = eng.run()
    np.testing.assert_array_equal(out[kids[0]], out[rid])
    with pytest.raises(ValueError):
        eng.fork_request(rid)            # finished parents cannot fork
    with pytest.raises(KeyError):
        eng.fork_request(10_000)


def test_fork_request_over_capacity_rejected(setup, rng):
    """A child whose fork-point prompt + fresh budget can never fit must be
    rejected like submit() would — admitting it on pool headroom alone
    self-preempts forever at the max_pages_per_seq reserve (livelock)."""
    prompt = rng.integers(0, 64, (8,)).astype(np.int32)
    eng = _engine(setup, True, max_seq=4 * PS, num_pages=64)
    rid = eng.submit(prompt, 8)          # 16 tokens: exactly fits
    parent = eng._seqs[rid]
    while len(parent.generated) < 3:
        assert eng.step()
    with pytest.raises(ValueError, match="max_pages_per_seq"):
        eng.fork_request(rid)            # 11 prompt + 8 budget = 19 > 16
    out = eng.run()                      # parent itself is unharmed
    assert len(out[rid]) == 8


def test_submit_error_names_binding_limit(setup):
    """The rejection message cites whichever limit actually rejected the
    request — max_pages_per_seq when the pool itself would fit it."""
    eng = _engine(setup, True, num_pages=64, max_seq=2 * PS)
    with pytest.raises(ValueError, match="max_pages_per_seq"):
        eng.submit(np.zeros(3 * PS, np.int32), 1)
    eng2 = _engine(setup, True, num_pages=4, max_seq=None,
                   max_pages_per_seq=64)
    with pytest.raises(ValueError, match="page pool"):
        eng2.submit(np.zeros(4 * PS, np.int32), 1)


# ---------------------------------------------------------------------------
# tensor parallelism: the registry is head-agnostic control plane
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tp", [2])
def test_prefix_cache_on_off_identical_under_tp(setup, rng, tp):
    if len(jax.devices()) < tp:
        pytest.skip(f"needs {tp} devices (XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={tp})")
    reqs = _shared_prefix_requests(rng, 3)
    base = _serve_sequential(_engine(setup, False), reqs)
    mesh = make_tp_mesh(tp)
    on = _engine(setup, True, mesh=mesh)
    out_on = _serve_sequential(on, reqs)
    out_off = _serve_sequential(_engine(setup, False, mesh=mesh), reqs)
    assert on.n_prefix_hits == 2         # control plane unchanged under TP
    for rid in base:
        np.testing.assert_array_equal(out_on[rid], base[rid])
        np.testing.assert_array_equal(out_off[rid], base[rid])
