"""Loop-aware HLO cost model: FLOPs vs analytic ground truth."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import hlo_cost
from repro.launch.roofline import Roofline, parse_collectives


@pytest.mark.requires_env("dict_cost_analysis")
def test_scan_flops_multiplied():
    """A scan of L matmuls must be charged L*flops, not 1x (the XLA
    cost_analysis undercount this module exists to fix)."""
    L, n = 16, 64

    def f(x, ws):
        def body(c, w):
            return jnp.dot(c, w, preferred_element_type=jnp.float32), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((L, n, n), jnp.float32)).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    analytic = 2.0 * L * n ** 3
    assert cost.flops == pytest.approx(analytic, rel=0.2)
    xla = (compiled.cost_analysis() or {}).get("flops", 0.0)
    assert xla < 0.5 * analytic  # the undercount we correct


@pytest.mark.requires_env("axis_type")
def test_collectives_counted_with_wire_factors():
    devs = jax.devices()
    mesh = jax.make_mesh((8,), ("d",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def f(x):
        return jnp.sum(x, axis=0)  # contraction over sharded dim -> psum

    sh = NamedSharding(mesh, P("d", None))
    with mesh:
        compiled = jax.jit(f, in_shardings=(sh,),
                           out_shardings=NamedSharding(mesh, P())).lower(
            jax.ShapeDtypeStruct((8, 1024), jnp.float32)).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    assert "all-reduce" in cost.coll
    rec = cost.coll["all-reduce"]
    # 1024 f32 all-reduced over 8: wire = 2 * 4096 * 7/8
    assert rec["wire_bytes"] == pytest.approx(2 * 4096 * 7 / 8, rel=0.01)
    # legacy text parser agrees on op identification
    legacy = parse_collectives(compiled.as_text())
    assert "all-reduce" in legacy


def test_roofline_terms_and_bottleneck():
    rl = Roofline(flops_per_chip=197e12, bytes_per_chip=819e9 * 2,
                  wire_bytes_per_chip=50e9 * 0.5, collectives={},
                  model_flops_total=197e12 * 256, chips=256)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(2.0)
    assert rl.collective_s == pytest.approx(0.5)
    assert rl.bottleneck == "memory"
    assert rl.roofline_fraction == pytest.approx(0.5)
    assert rl.useful_flops_ratio == pytest.approx(1.0)
