"""End-to-end behaviour: train -> MSB-quantize -> serve (the paper's
pipeline), plus baseline comparisons — the full system exercised at once."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import (QuantPolicy, baselines, dequantize_params, param_bits,
                        quantize_params)
from repro.data import MarkovStream
from repro.models import Model
from repro.serve import ServeEngine
from repro.train import AdamW, OptConfig, train_loop


@pytest.fixture(scope="module")
def trained():
    cfg = smoke_config("qwen1.5-0.5b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64,
                              n_layers=2, d_model=64)
    model = Model(cfg)
    data = MarkovStream(64, 32, 8, seed=5)
    opt = AdamW(OptConfig(lr=3e-3, warmup_steps=5, total_steps=80))
    state, _ = train_loop(model, opt, iter(data), steps=60,
                          rng=jax.random.PRNGKey(0), log_every=0,
                          log_fn=lambda *_: None)
    return model, state["params"], data


def _eval_nll(model, params, data, n=4):
    tot = 0.0
    for i in range(100, 100 + n):
        b = data.batch(i)
        loss, _ = jax.jit(model.loss)(params, {k: jnp.asarray(v)
                                               for k, v in b.items()})
        tot += float(loss)
    return tot / n


def test_train_quantize_serve_pipeline(trained):
    """The headline claim: calibration-free MSB 4-bit keeps quality close to
    fp while RTN degrades more (paper Table 1 structure, synthetic stand-in).
    """
    model, params, data = trained
    nll_fp = _eval_nll(model, params, data)
    assert nll_fp < 0.9 * np.log(64)    # the model learned something

    qparams, report = quantize_params(
        params, QuantPolicy(bits=4, block=64, solver="dp", min_size=1024))
    assert len(report) >= 4
    nll_msb = _eval_nll(model, qparams, data)

    # RTN at the same bits/granularity
    def rtn_tree(p):
        def visit(path, leaf):
            pol = QuantPolicy(min_size=1024)
            pstr = "/".join(str(getattr(x, "key", x)) for x in path)
            if pol.selects(pstr, leaf):
                return baselines.rtn_quantize(leaf, 4, 64).astype(leaf.dtype)
            return leaf
        return jax.tree_util.tree_map_with_path(visit, p)

    nll_rtn = _eval_nll(model, rtn_tree(params), data)

    assert nll_msb < nll_rtn + 1e-3, (nll_msb, nll_rtn)
    assert nll_msb - nll_fp < 0.35 * nll_fp

    # storage really shrank
    assert param_bits(qparams) < 0.45 * param_bits(params)

    # and the quantized model still generates
    eng = ServeEngine(model, qparams, max_seq=64)
    out = eng.generate(jnp.zeros((2, 4), jnp.int32), n_tokens=4)
    assert out.shape == (2, 4)


def test_dequantized_params_close(trained):
    model, params, _ = trained
    qparams, _ = quantize_params(params, QuantPolicy(bits=4, block=64,
                                                     solver="dp",
                                                     min_size=1024))
    dense = dequantize_params(qparams, dtype=jnp.float32)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(dense)[0]):
        rel = float(jnp.linalg.norm(a - b) /
                    jnp.maximum(jnp.linalg.norm(a), 1e-9))
        assert rel < 0.35, (pa, rel)


def test_pertensor_6bit_pipeline(trained):
    """6-bit per-tensor (the paper's second granularity) stays near fp."""
    model, params, data = trained
    nll_fp = _eval_nll(model, params, data)
    qparams, _ = quantize_params(params, QuantPolicy(
        bits=6, block=-1, solver="kmeans", min_size=1024))
    nll_q = _eval_nll(model, qparams, data)
    assert nll_q - nll_fp < 0.15 * nll_fp
