"""Optimizer codecs, schedules, and the data pipeline."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.quantize import quantize_blockwise, dequantize
from repro.core import reconstruction_mse
from repro.data import MarkovStream, Prefetcher, TokenStream
from repro.train.optim import (_dq8, _dq8_log, _q8, _q8_log, AdamW,
                               OptConfig, lr_schedule)


def test_q8_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal((8, 256)) * 0.01, jnp.float32)
    st_ = _q8(x, 256)
    back = _dq8(st_, 256, x.shape)
    err = float(jnp.max(jnp.abs(back - x)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127 + 1e-7


def test_q8_log_roundtrip_many_decades(rng):
    """Linear int8 collapses tiny v to 0; the log codec keeps ~9% rel."""
    mags = 10.0 ** rng.uniform(-9, -1, size=(4, 256))
    x = jnp.asarray(mags, jnp.float32)
    st_ = _q8_log(x, 256)
    back = np.asarray(_dq8_log(st_, 256, x.shape))
    rel = np.abs(back - mags) / mags
    assert rel.max() < 0.12
    lin = np.asarray(_dq8(_q8(x, 256), 256, x.shape))
    assert (lin == 0).mean() > 0.5      # the failure mode we avoid


def test_q8_log_zero_exact():
    x = jnp.zeros((1, 256), jnp.float32)
    back = _dq8_log(_q8_log(x, 256), 256, x.shape)
    assert float(jnp.max(jnp.abs(back))) == 0.0


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3, rel=0.05)
    assert lrs[4] == pytest.approx(1e-4, rel=0.05)
    assert lrs[3] < lrs[2]


def test_weight_decay_skips_vectors(rng):
    opt = AdamW(OptConfig(lr=1e-2, weight_decay=0.5, warmup_steps=1))
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = opt.init(params)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    newp, _ = opt.update(zero_g, state, params)
    assert float(jnp.max(jnp.abs(newp["b"] - 1.0))) < 1e-6   # no decay
    assert float(jnp.max(newp["w"])) < 1.0                   # decayed


def test_token_stream_deterministic_and_sharded():
    a = TokenStream(100, 16, 8, seed=1, host=0, n_hosts=2)
    b = TokenStream(100, 16, 8, seed=1, host=1, n_hosts=2)
    x0, x0b = a.batch(3), a.batch(3)
    np.testing.assert_array_equal(x0["tokens"], x0b["tokens"])  # repeatable
    assert not np.array_equal(a.batch(3)["tokens"], b.batch(3)["tokens"])
    assert x0["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(x0["labels"][:, :-1], x0["tokens"][:, 1:])


def test_markov_entropy_floor():
    s = MarkovStream(32, 64, 4, seed=2)
    h = s.entropy()
    assert 0 < h < np.log(32)
    b = s.batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 32


def test_prefetcher_order():
    pf = Prefetcher(iter([{"i": np.asarray(i)} for i in range(5)]), depth=2)
    got = [int(next(pf)["i"]) for _ in range(5)]
    assert got == list(range(5))
    pf.close()


# -- nearest-level refinement (beyond-paper) --------------------------------

@given(st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_refine_never_hurts(seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((2, 64)).astype(np.float32)
    for solver in ("wgm", "kmeans"):
        q0 = quantize_blockwise(w, bits=3, solver=solver)
        q1 = quantize_blockwise(w, bits=3, solver=solver, refine=True)
        m0 = float(reconstruction_mse(w, dequantize(q0)))
        m1 = float(reconstruction_mse(w, dequantize(q1)))
        assert m1 <= m0 + 1e-5


def test_refine_noop_at_dp_optimum(rng):
    w = rng.standard_normal((4, 64)).astype(np.float32)
    q0 = quantize_blockwise(w, bits=4, solver="dp")
    q1 = quantize_blockwise(w, bits=4, solver="dp", refine=True)
    m0 = float(reconstruction_mse(w, dequantize(q0)))
    m1 = float(reconstruction_mse(w, dequantize(q1)))
    assert m1 == pytest.approx(m0, rel=1e-5)
