"""PagedKVCache allocator: free list, block tables, refcount prefix sharing."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import Model
from repro.serve import OutOfPages, PagedKVCache, PageStateError


@pytest.fixture(scope="module")
def model():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    return Model(cfg)


def make_cache(model, **kw):
    kw.setdefault("num_pages", 16)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seqs", 4)
    return PagedKVCache(model, **kw)


def test_page0_reserved_and_freelist(model):
    c = make_cache(model)
    assert c.n_free_pages == 15          # page 0 never in the free list
    s = c.alloc_slot()
    c.reserve(s, 9)                      # ceil(9/4) = 3 pages
    assert c.n_free_pages == 12
    assert len(c.seq_pages[s]) == 3
    assert 0 not in c.seq_pages[s]
    assert (c.block_tables[s, :3] > 0).all()
    c.release(s)
    assert c.n_free_pages == 15
    assert (c.ref_counts[1:] == 0).all() and c.ref_counts[0] == 1


def test_reserve_is_all_or_nothing(model):
    c = make_cache(model, num_pages=5)   # 4 usable pages
    s = c.alloc_slot()
    c.reserve(s, 8)                      # 2 pages
    free_before = c.n_free_pages
    with pytest.raises(OutOfPages):
        c.reserve(s, 100)                # would need 25 pages
    assert c.n_free_pages == free_before
    assert len(c.seq_pages[s]) == 2


def test_reserve_is_idempotent_and_monotonic(model):
    c = make_cache(model)
    s = c.alloc_slot()
    c.reserve(s, 4)
    pages = list(c.seq_pages[s])
    c.reserve(s, 3)                      # shrink request: no-op
    c.reserve(s, 4)                      # same: no-op
    assert c.seq_pages[s] == pages
    c.reserve(s, 5)                      # grow by one page
    assert c.seq_pages[s][:1] == pages


def test_fits_gate(model):
    c = make_cache(model, num_pages=5, max_pages_per_seq=3)
    assert c.fits(12)                    # 3 pages, == both limits
    assert not c.fits(13)                # 4 pages > max_pages_per_seq


def test_fork_shares_full_pages_and_copies_partial(model):
    c = make_cache(model)
    src = c.alloc_slot()
    c.reserve(src, 10)                   # 2 full pages + 1 partial (ps=4)
    c.commit(src, 10)
    # stamp recognizable data into the pools
    leaf = jax.tree_util.tree_leaves(c.pools)[0]
    c.pools = jax.tree_util.tree_map(
        lambda l: jnp.arange(l.size, dtype=l.dtype).reshape(l.shape), c.pools)
    free_before = c.n_free_pages
    dst = c.fork(src)
    assert dst is not None and dst != src
    # full pages shared (refcount 2), partial page fresh
    sp, dp = c.seq_pages[src], c.seq_pages[dst]
    assert sp[:2] == dp[:2] and sp[2] != dp[2]
    assert c.ref_counts[sp[0]] == 2 and c.ref_counts[sp[2]] == 1
    assert c.n_free_pages == free_before - 1
    assert int(c.seq_lens[dst]) == 10
    # partial page device-copied
    for l in jax.tree_util.tree_leaves(c.pools):
        np.testing.assert_array_equal(np.asarray(l[:, dp[2]]),
                                      np.asarray(l[:, sp[2]]))
    # releasing the source keeps shared pages alive for the fork
    c.release(src)
    assert c.ref_counts[dp[0]] == 1
    c.release(dst)
    assert c.n_free_pages == 15


def test_fork_exact_page_boundary_shares_everything(model):
    c = make_cache(model)
    src = c.alloc_slot()
    c.reserve(src, 8)                    # exactly 2 pages
    c.commit(src, 8)
    free_before = c.n_free_pages
    dst = c.fork(src)
    assert c.seq_pages[dst] == c.seq_pages[src]
    assert c.n_free_pages == free_before  # nothing copied, nothing allocated


def test_commit_past_reservation_raises(model):
    """Lifecycle invariants raise real exceptions (not ``assert``, which
    vanishes under ``python -O`` and silently corrupts the free list)."""
    c = make_cache(model)
    s = c.alloc_slot()
    c.reserve(s, 4)                      # one page
    with pytest.raises(PageStateError):
        c.commit(s, 5)                   # 5 tokens > 1 reserved page
    c.commit(s, 4)                       # the reserved extent is fine


def test_double_release_raises(model):
    c = make_cache(model)
    s = c.alloc_slot()
    c.reserve(s, 4)
    page = c.seq_pages[s][0]
    c.release(s)
    # re-enter the stale slot state by hand (simulates a control-plane bug)
    c.seq_pages[s] = [page]
    with pytest.raises(PageStateError):
        c.release(s)


def test_table_rows_pads_inactive(model):
    c = make_cache(model)
    s = c.alloc_slot()
    c.reserve(s, 6)
    rows = np.asarray(c.table_rows([s, -1]))
    assert rows.shape[0] == 2
    assert (rows[0][:2] == c.block_tables[s][:2]).all()
    assert (rows[1] == 0).all()


# -- the invariant audit itself ----------------------------------------------
def test_check_invariants_clean_busy_and_idle(model):
    c = make_cache(model)
    s = c.alloc_slot()
    c.reserve(s, 9)
    c.commit(s, 9)
    c.check_invariants()                 # live sequence: fine
    with pytest.raises(PageStateError, match="live"):
        c.check_invariants(expect_idle=True)
    c.release(s)
    c.check_invariants(expect_idle=True)


def test_check_invariants_catches_leaked_page(model):
    c = make_cache(model)
    s = c.alloc_slot()
    c.reserve(s, 4)
    c.release(s)
    c._free.remove(c._free[0])           # page vanishes from every set
    with pytest.raises(PageStateError, match="conservation"):
        c.check_invariants()


def test_check_invariants_catches_refcount_drift(model):
    c = make_cache(model)
    s = c.alloc_slot()
    c.reserve(s, 4)
    c.ref_counts[c.seq_pages[s][0]] += 1
    with pytest.raises(PageStateError, match="refcount"):
        c.check_invariants()


def test_check_invariants_catches_table_mirror_break(model):
    c = make_cache(model)
    s = c.alloc_slot()
    c.reserve(s, 8)                      # two pages
    c.block_tables[s, 1] = 0             # table no longer mirrors seq_pages
    with pytest.raises(PageStateError, match="block_tables"):
        c.check_invariants()


def test_check_invariants_catches_dirty_free_slot(model):
    c = make_cache(model)
    s = c.alloc_slot()
    c.reserve(s, 4)
    c.release(s)
    c.seq_lens[s] = 3                    # ghost length on a freed slot
    with pytest.raises(PageStateError, match="free slot"):
        c.check_invariants()


def test_check_invariants_catches_free_referenced_overlap(model):
    c = make_cache(model)
    s = c.alloc_slot()
    c.reserve(s, 4)
    c._free.append(c.seq_pages[s][0])    # double-owned page
    with pytest.raises(PageStateError):
        c.check_invariants()
