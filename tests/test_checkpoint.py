"""Checkpointer: atomicity, integrity, elastic resharding."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.train import Checkpointer


@pytest.fixture
def state(rng):
    return {"params": {"w": jnp.asarray(rng.standard_normal((8, 16)),
                                        jnp.float32),
                       "b": jnp.zeros((16,), jnp.float32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore(state, tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(state, 10)
    restored, step = ck.restore_latest(like=state)
    assert step == 10
    np.testing.assert_allclose(restored["params"]["w"],
                               np.asarray(state["params"]["w"]))


def test_async_save(state, tmp_path):
    ck = Checkpointer(tmp_path, async_save=True)
    ck.save(state, 1)
    ck.wait()
    assert ck.steps() == [1]


def test_keep_policy(state, tmp_path):
    ck = Checkpointer(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(state, s)
    assert ck.steps() == [3, 4]


def test_corruption_detected(state, tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(state, 5)
    # flip bytes in one leaf
    d = tmp_path / "step_5"
    f = sorted(p for p in os.listdir(d) if p.endswith(".npy"))[0]
    path = d / f
    raw = bytearray(path.read_bytes())
    raw[-4] ^= 0xFF
    path.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        ck.restore(5, like=state)


def test_incomplete_checkpoint_ignored(state, tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(state, 3)
    os.makedirs(tmp_path / "step_9.tmp", exist_ok=True)  # crashed write
    assert ck.steps() == [3]


@pytest.mark.requires_env("axis_type")
def test_elastic_reshard(state, tmp_path):
    """Save under one sharding, restore onto a different mesh layout."""
    devs = jax.devices()
    assert len(devs) >= 8
    mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    mesh_b = jax.make_mesh((2,), ("data",),
                           axis_types=(jax.sharding.AxisType.Auto,),
                           devices=devs[:2])
    sharded = jax.device_put(
        state["params"]["w"], NamedSharding(mesh_a, P("data", "model")))
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save({"w": sharded}, 1)
    target = NamedSharding(mesh_b, P("data", None))
    restored, _ = ck.restore(1, like={"w": np.zeros((8, 16), np.float32)},
                             shardings={"w": target})
    assert restored["w"].sharding == target
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(state["params"]["w"]))
