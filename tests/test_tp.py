"""Tensor-parallel packed serving (DESIGN.md Sec. 10).

Acceptance invariant: greedy decode is token-identical between tp=1 and
tp>1 on a forced host mesh, for both execution="packed" and "simulated",
through both engines — plus the mixed-topology edge cases: hidden dims
that need padded shards, MoE experts under TP, and forced preemption on a
head-sharded paged cache.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import (PackedQTensor, QTensor, QuantPolicy, pack_params,
                        quantize_params, tp_localize, tp_partition_params)
from repro.launch.mesh import make_tp_mesh
from repro.models import Model
from repro.serve import ContinuousEngine, ServeEngine


def _mesh_or_skip(tp):
    if len(jax.devices()) < tp:
        pytest.skip(f"needs {tp} devices (XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={tp})")
    return make_tp_mesh(tp)


def _quantized(arch="internlm2-1.8b", **over):
    cfg = smoke_config(arch)
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64, **over)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    qparams, _ = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="kmeans", min_size=1024))
    return model, qparams


@pytest.fixture(scope="module")
def dense_model():
    return _quantized()


@pytest.fixture(scope="module")
def moe_model():
    return _quantized("granite-moe-3b-a800m")


@pytest.fixture(scope="module")
def wide_head_model():
    """Head-dim large enough that QKV projections are quantized with whole
    64-blocks per rank at tp=2 -> the planner weight-shards attention."""
    return _quantized(n_heads=2, n_kv_heads=2, head_dim=64)


def _requests(seed=1, n=4):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, 64, (int(rng.integers(4, 12)),)).astype(np.int32),
             int(rng.integers(4, 10))) for _ in range(n)]


def _serve(model, qparams, execution, mesh=None, num_pages=64, seed=1):
    eng = ContinuousEngine(model, qparams, max_batch=4, page_size=4,
                           num_pages=num_pages, max_seq=32, prefill_chunk=8,
                           execution=execution, mesh=mesh)
    for r in _requests(seed):
        eng.submit(*r)
    return eng, eng.run()


def _assert_identical(a, b):
    assert a.keys() == b.keys()
    for rid in a:
        np.testing.assert_array_equal(a[rid], b[rid])


# ---------------------------------------------------------------------------
# planner: marks, padded shards, specs
# ---------------------------------------------------------------------------

def test_planner_padded_mlp_and_vocab(dense_model):
    """d_ff=128 at tp=4 cannot split into whole 64-blocks -> the planner
    pads the hidden dim to 256 (zero columns/rows) on both sides of the
    SwiGLU, and shards the untied unembedding along vocab."""
    model, qparams = dense_model
    tree, specs, report = tp_partition_params(qparams, 4, cfg=model.cfg)
    mlp = tree["dec"]["s0"]["mlp"]
    assert mlp["wg"].shard == "n" and mlp["wi"].shard == "n"
    assert mlp["wo"].shard == "k"
    assert mlp["wg"].codes.shape[-1] == 256          # padded to 64*tp
    assert mlp["wo"].codes.shape[-2] == 256          # row-parallel pair
    assert tree["unembed"].shard == "v"
    # attention cannot head-shard at tp=4 (kv=2) nor block-align: replicated
    assert getattr(tree["dec"]["s0"]["attn"]["wq"], "shard", None) is None
    assert "dec/s0/mlp" in report and "unembed" in report
    # spec tree flattens leaf-for-leaf against the params tree
    assert (jax.tree_util.tree_structure(tree)
            == jax.tree_util.tree_structure(specs))


def test_planner_padding_is_value_preserving(dense_model):
    """The padded tree computes the same function: zero-scale columns and
    zero rows contribute nothing to any matmul."""
    model, qparams = dense_model
    tree, _, _ = tp_partition_params(qparams, 4, cfg=model.cfg)
    wg0 = qparams["dec"]["s0"]["mlp"]["wg"].dequantize()
    wg1 = tree["dec"]["s0"]["mlp"]["wg"].dequantize()
    np.testing.assert_array_equal(np.asarray(wg1[..., :wg0.shape[-1]]),
                                  np.asarray(wg0))
    assert not np.asarray(wg1[..., wg0.shape[-1]:]).any()
    wo1 = tree["dec"]["s0"]["mlp"]["wo"].dequantize()
    assert not np.asarray(wo1[:, wg0.shape[-1]:, :]).any()


def test_planner_packed_localize(dense_model):
    """Packed leaves keep the global padded width in aux; tp_localize
    rebinds n to the (mock-)local storage width for n-sharded leaves."""
    model, qparams = dense_model
    packed, _ = pack_params(qparams)
    tree, _, _ = tp_partition_params(packed, 4, cfg=model.cfg)
    wg = tree["dec"]["s0"]["mlp"]["wg"]
    assert isinstance(wg, PackedQTensor) and wg.shard == "n"
    assert wg.n == wg.n_pad == 256
    local = jax.tree_util.tree_map(
        lambda a: a[..., : a.shape[-1] // 4]
        if a.dtype == jnp.uint8 else a, wg)
    assert tp_localize({"wg": local})["wg"].n == 64


def test_planner_odd_tp_packs(dense_model):
    """Odd tp sizes pad to 64*tp (not 128-multiples): d_ff=128 at tp=3 ->
    192, one whole block (32 packed bytes) per rank."""
    model, qparams = dense_model
    packed, _ = pack_params(qparams)
    tree, _, report = tp_partition_params(packed, 3, cfg=model.cfg)
    wg = tree["dec"]["s0"]["mlp"]["wg"]
    assert wg.n == wg.n_pad == 192 and wg.shard == "n"
    assert "dec/s0/mlp" in report


def test_planner_tp1_is_identity(dense_model):
    model, qparams = dense_model
    tree, specs, report = tp_partition_params(qparams, 1, cfg=model.cfg)
    assert report == {}
    assert (jax.tree_util.tree_structure(tree)
            == jax.tree_util.tree_structure(specs))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(qparams)):
        assert a is b


# ---------------------------------------------------------------------------
# acceptance: greedy decode token-identical tp=1 vs tp>1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("execution", ["simulated", "packed"])
@pytest.mark.parametrize("tp", [2, 4])
def test_continuous_tp_token_identity(dense_model, execution, tp):
    """tp=2 head-shards the paged pools (kv=2); tp=4 falls back to
    replicated attention with padded-shard MLP + vocab-sharded logits.
    Both must reproduce the tp=1 greedy tokens exactly."""
    mesh = _mesh_or_skip(tp)
    model, qparams = dense_model
    _, base = _serve(model, qparams, execution)
    eng, out = _serve(model, qparams, execution, mesh)
    _assert_identical(base, out)
    assert eng.tp_report                   # something actually sharded


@pytest.mark.parametrize("execution", ["simulated", "packed"])
def test_serve_engine_tp_token_identity(dense_model, execution):
    mesh = _mesh_or_skip(2)
    model, qparams = dense_model
    prompts = jnp.asarray(np.arange(8, dtype=np.int32).reshape(2, 4))
    eng1 = ServeEngine(model, qparams, max_seq=32, execution=execution)
    eng2 = ServeEngine(model, qparams, max_seq=32, execution=execution,
                       mesh=mesh)
    np.testing.assert_array_equal(
        np.asarray(eng1.generate(prompts, n_tokens=6)),
        np.asarray(eng2.generate(prompts, n_tokens=6)))


@pytest.mark.parametrize("execution", ["simulated", "packed"])
def test_weight_sharded_attention_heads(wide_head_model, execution):
    """With 64-block-aligned per-rank head widths the planner column-shards
    QKV / row-shards wo (psum) instead of slicing computed heads."""
    mesh = _mesh_or_skip(2)
    model, qparams = wide_head_model
    _, base = _serve(model, qparams, execution)
    eng, out = _serve(model, qparams, execution, mesh)
    assert eng.tp_report.get("dec/s0/attn") == "heads"
    _assert_identical(base, out)


# ---------------------------------------------------------------------------
# mixed-topology edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("execution", ["simulated", "packed"])
def test_moe_experts_under_tp(moe_model, execution):
    """16 padded experts shard 4 ways; routing is replicated so the
    token->expert assignment (and greedy output) matches tp=1 exactly."""
    mesh = _mesh_or_skip(4)
    model, qparams = moe_model
    _, base = _serve(model, qparams, execution, seed=2)
    eng, out = _serve(model, qparams, execution, mesh, seed=2)
    assert eng.tp_report.get("dec/s0/moe") == "experts"
    _assert_identical(base, out)


def test_preemption_on_sharded_cache(dense_model):
    """A page pool too small for the burst forces preemption by recompute;
    re-prefill through the head-sharded pools must reproduce the
    un-preempted tp=1 output."""
    mesh = _mesh_or_skip(2)
    model, qparams = dense_model
    _, base = _serve(model, qparams, "simulated", num_pages=64, seed=2)
    eng, out = _serve(model, qparams, "simulated", mesh, num_pages=8, seed=2)
    assert eng.scheduler.n_preemptions > 0
    _assert_identical(base, out)


def test_tp_engine_still_scores(dense_model):
    """The sharded param tree remains usable outside shard_map (plain jit
    over global arrays): ServeEngine.score works under a TP mesh."""
    mesh = _mesh_or_skip(2)
    model, qparams = dense_model
    eng = ServeEngine(model, qparams, max_seq=32, execution="simulated",
                      mesh=mesh)
    tokens = jnp.asarray(np.arange(16, dtype=np.int32).reshape(2, 8)) % 64
    assert np.isfinite(eng.score(tokens))
