"""Metrics registry: bucketing, monotonicity, Prometheus text rendering,
and the TTFT/ITL timing rules — all with fake clocks, no engine."""
import math

import numpy as np
import pytest

from repro.serve import Registry, ServeMetrics, ValidationError
from repro.serve.lifecycle import (FINISH_LENGTH, FINISH_STOP,
                                   CompletionParams, RequestLifecycle,
                                   parse_completion_request)
from repro.serve.metrics import LATENCY_BUCKETS


# -- counters ----------------------------------------------------------------

def test_counter_monotonic():
    r = Registry()
    c = r.counter("x_total", "help")
    c.inc()
    c.inc(2.5)
    assert c.value() == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    c.set_to(10)
    with pytest.raises(ValueError):        # a regression is a wiring bug
        c.set_to(9)
    assert c.value() == 10


def test_counter_labels_independent():
    r = Registry()
    c = r.counter("req_total", "help", labelnames=("outcome",))
    c.inc(outcome="stop")
    c.inc(outcome="stop")
    c.inc(outcome="length")
    assert c.value(outcome="stop") == 2
    assert c.value(outcome="length") == 1
    with pytest.raises(ValueError):        # wrong label set
        c.inc(reason="stop")
    with pytest.raises(ValueError):        # missing labels entirely
        c.inc()


def test_gauge_set_inc_dec():
    r = Registry()
    g = r.gauge("depth", "help")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value() == 3


def test_registry_rejects_duplicates():
    r = Registry()
    r.counter("a_total", "h")
    with pytest.raises(ValueError):
        r.gauge("a_total", "h")


# -- histograms --------------------------------------------------------------

def test_histogram_bucketing_cumulative():
    r = Registry()
    h = r.histogram("lat", "help", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.1, 0.5, 2.0, 99.0):  # 0.1 lands IN le="0.1" (<=)
        h.observe(v)
    assert h.count() == 5
    assert h.sum() == pytest.approx(101.65)
    text = r.render()
    assert 'lat_bucket{le="0.1"} 2' in text
    assert 'lat_bucket{le="1"} 3' in text
    assert 'lat_bucket{le="10"} 4' in text
    assert 'lat_bucket{le="+Inf"} 5' in text
    assert "lat_count 5" in text


def test_histogram_percentile():
    r = Registry()
    h = r.histogram("lat", "help", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.6, 3.0):
        h.observe(v)
    assert h.percentile(0.25) == 1.0       # bucket upper bounds
    assert h.percentile(0.75) == 2.0
    assert h.percentile(1.0) == 4.0
    h.observe(100.0)
    assert h.percentile(1.0) == math.inf
    assert r.histogram("empty", "h").percentile(0.5) is None


def test_default_buckets_cover_smoke_and_accelerator_range():
    assert LATENCY_BUCKETS[0] <= 0.001 and LATENCY_BUCKETS[-1] >= 10.0
    assert list(LATENCY_BUCKETS) == sorted(LATENCY_BUCKETS)


# -- Prometheus text format (golden) -----------------------------------------

def test_prometheus_golden_output():
    r = Registry()
    c = r.counter("msb_requests_total", "Completed requests by outcome",
                  labelnames=("outcome",))
    g = r.gauge("msb_queue_depth", "Requests waiting")
    h = r.histogram("msb_ttft_seconds", "Time to first token",
                    buckets=(0.5, 1.0))
    c.inc(outcome="stop")
    c.inc(3, outcome="length")
    g.set(2)
    h.observe(0.25)
    h.observe(0.75)
    h.observe(9.5)
    assert r.render() == (
        "# HELP msb_requests_total Completed requests by outcome\n"
        "# TYPE msb_requests_total counter\n"
        'msb_requests_total{outcome="length"} 3\n'
        'msb_requests_total{outcome="stop"} 1\n'
        "# HELP msb_queue_depth Requests waiting\n"
        "# TYPE msb_queue_depth gauge\n"
        "msb_queue_depth 2\n"
        "# HELP msb_ttft_seconds Time to first token\n"
        "# TYPE msb_ttft_seconds histogram\n"
        'msb_ttft_seconds_bucket{le="0.5"} 1\n'
        'msb_ttft_seconds_bucket{le="1"} 2\n'
        'msb_ttft_seconds_bucket{le="+Inf"} 3\n'
        "msb_ttft_seconds_sum 10.5\n"
        "msb_ttft_seconds_count 3\n")


def test_label_value_escaping():
    r = Registry()
    c = r.counter("x_total", "h", labelnames=("v",))
    c.inc(v='a"b\\c\nd')
    assert r.render().splitlines()[2] == 'x_total{v="a\\"b\\\\c\\nd"} 1'


def test_serve_metrics_families_present():
    text = ServeMetrics().render()
    for family in ("msb_ttft_seconds", "msb_inter_token_seconds",
                   "msb_queue_depth", "msb_running_requests",
                   "msb_requests_total", "msb_tokens_generated_total",
                   "msb_preemptions_total", "msb_aborts_total",
                   "msb_prefix_hits_total", "msb_prefix_hit_rate"):
        assert f"# TYPE {family} " in text


# -- TTFT / ITL semantics ----------------------------------------------------

def _params(stream=True, timeout_s=None):
    return CompletionParams(prompt=np.array([1, 2, 3], np.int32),
                            max_tokens=16, temperature=0.0, stop_ids=(),
                            stream=stream, timeout_s=timeout_s)


def test_ttft_measured_from_acceptance_to_first_token_event():
    """Chunked prefill delays the first token-bearing event; TTFT is that
    whole wait, observed exactly once."""
    m = ServeMetrics()
    lc = RequestLifecycle(_params(), metrics=m)
    lc.on_accepted(now=100.0)
    lc.on_tokens([], now=100.2)            # prefill ticks: no tokens yet
    lc.on_tokens([], now=100.5)
    assert m.ttft.count() == 0
    lc.on_tokens([7], now=100.7)
    assert m.ttft.count() == 1
    assert m.ttft.sum() == pytest.approx(0.7)
    lc.on_tokens([8], now=100.9)
    assert m.ttft.count() == 1             # never re-observed


def test_itl_one_observation_per_arrival_not_per_token():
    """A decode_horizon=H dispatch delivers H tokens in ONE event; the only
    latency a client saw is the single gap since the last flush — H-1
    fabricated gaps would corrupt the histogram."""
    m = ServeMetrics()
    lc = RequestLifecycle(_params(), metrics=m)
    lc.on_accepted(now=0.0)
    lc.on_tokens([1], now=1.0)                      # TTFT only
    lc.on_tokens([2, 3, 4, 5, 6, 7, 8, 9], now=1.5)  # H=8 burst: ONE gap
    lc.on_tokens([10], now=2.5)
    assert m.itl.count() == 2              # not 9
    assert m.itl.sum() == pytest.approx(0.5 + 1.0)
    assert lc.n_tokens == 10
    assert lc.token_ids == list(range(1, 11))


def test_finish_counts_outcome_once():
    m = ServeMetrics()
    lc = RequestLifecycle(_params(), metrics=m)
    lc.on_accepted(0.0)
    lc.on_tokens([1], 1.0)
    lc.on_finish(FINISH_LENGTH, 2.0)
    lc.on_finish(FINISH_STOP, 3.0)         # idempotent: first reason wins
    assert lc.finish_reason == FINISH_LENGTH
    assert m.requests.value(outcome="length") == 1
    assert m.requests.value(outcome="stop") == 0
    with pytest.raises(ValueError):
        RequestLifecycle(_params()).on_finish("exploded", 0.0)


def test_deadline_from_timeout_param():
    lc = RequestLifecycle(_params(timeout_s=2.0))
    lc.on_accepted(10.0)
    assert not lc.timed_out(11.9)
    assert lc.timed_out(12.0)
    lc2 = RequestLifecycle(_params(timeout_s=None))
    lc2.on_accepted(10.0)
    assert not lc2.timed_out(1e12)


def test_request_ids_unique_and_prefixed():
    a = RequestLifecycle(_params())
    b = RequestLifecycle(_params())
    assert a.request_id != b.request_id
    assert a.request_id.startswith("cmpl-")


# -- request validation ------------------------------------------------------

def test_parse_valid_body_and_string_prompt():
    p = parse_completion_request(
        {"prompt": [1, 2, 3], "max_tokens": 4, "stop": 9, "stream": True,
         "timeout": 2.5},
        vocab_size=64)
    assert p.prompt.dtype == np.int32 and p.prompt.tolist() == [1, 2, 3]
    assert p.max_tokens == 4 and p.stop_ids == (9,) and p.eos_id == 9
    assert p.stream and p.timeout_s == 2.5
    q = parse_completion_request({"prompt": "5 6 7"}, vocab_size=64)
    assert q.prompt.tolist() == [5, 6, 7] and not q.stream
    assert q.eos_id is None


@pytest.mark.parametrize("body,param", [
    ({}, "prompt"),
    ({"prompt": []}, "prompt"),
    ({"prompt": "not ids"}, "prompt"),
    ({"prompt": [1.5]}, "prompt"),
    ({"prompt": [64]}, "prompt"),              # out of vocab (vocab_size=64)
    ({"prompt": [1], "max_tokens": 0}, "max_tokens"),
    ({"prompt": [1], "max_tokens": 10**9}, "max_tokens"),
    ({"prompt": [1], "temperature": 0.7}, "temperature"),
    ({"prompt": [1], "stop": [1, 2, 3, 4, 5]}, "stop"),
    ({"prompt": [1], "stream": "yes"}, "stream"),
    ({"prompt": [1], "timeout": -1}, "timeout"),
    ({"prompt": [1], "n": 2}, "n"),
])
def test_parse_rejections_name_the_param(body, param):
    with pytest.raises(ValidationError) as ei:
        parse_completion_request(body, vocab_size=64)
    assert ei.value.param == param


def test_server_timeout_cap_applies():
    p = parse_completion_request({"prompt": [1], "timeout": 500},
                                 vocab_size=64, max_timeout_s=30.0)
    assert p.timeout_s == 30.0
    q = parse_completion_request({"prompt": [1]}, vocab_size=64,
                                 max_timeout_s=30.0)
    assert q.timeout_s == 30.0             # cap is also the default deadline
