"""Decode-horizon execution (DESIGN.md Sec. 12): H fused decode steps +
on-device sampling per dispatch, token-identical to ``decode_horizon=1``.

Covers the contract's edges: eos fired mid-horizon (trailing iterations are
no-ops, output trimmed), a page boundary crossed inside one horizon,
preemption while a horizon lease is outstanding, prefix-cache registration
parity across horizons, tp=2 identity at horizon > 1 — plus the static
``ServeEngine.generate`` scan (greedy and temperature) against the per-step
loop it replaced, and the ``Sequence.tokens`` memo.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import QuantPolicy, quantize_params
from repro.launch.mesh import make_tp_mesh
from repro.models import Model
from repro.serve import ContinuousEngine, ServeEngine
from repro.serve.scheduler import Request, Sequence


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return model, params


@pytest.fixture(scope="module")
def qsetup(setup):
    model, params = setup
    qparams, report = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="kmeans", min_size=1024))
    assert report
    return model, qparams


def _mixed_requests(rng, n, max_new=18):
    return [(rng.integers(0, 64, (int(rng.integers(3, 14)),))
             .astype(np.int32), int(rng.integers(2, max_new)))
            for _ in range(n)]


def _serve(model, params, requests, horizon, **over):
    kw = dict(max_batch=8, page_size=4, num_pages=96, max_seq=48,
              prefill_chunk=8, decode_horizon=horizon)
    kw.update(over)
    eng = ContinuousEngine(model, params, **kw)
    for r in requests:
        eng.submit(*r)
    return eng, eng.run()


# ---------------------------------------------------------------------------
# acceptance: token identity across horizons x execution modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("execution", ["simulated", "packed"])
@pytest.mark.parametrize("horizon", [4, 8])
def test_horizon_token_identity(qsetup, rng, execution, horizon):
    """Greedy outputs are token-identical between decode_horizon=1 and
    decode_horizon in {4, 8} for both execution modes, while the decode
    dispatch count drops by roughly the horizon factor."""
    model, qparams = qsetup
    requests = _mixed_requests(rng, 9)
    e1, base = _serve(model, qparams, requests, 1, execution=execution)
    eh, out = _serve(model, qparams, requests, horizon, execution=execution)
    assert sorted(out) == sorted(base)
    for rid in base:
        np.testing.assert_array_equal(out[rid], base[rid])
    assert eh.n_tokens_out == e1.n_tokens_out
    # ragged budgets + admission interleave leave straggler waves, so the
    # reduction here is loose; the exact 1/H amortization is pinned by
    # test_dispatch_count_amortized_by_horizon on a lockstep workload
    assert eh.n_decode_steps < e1.n_decode_steps
    assert eh.n_host_syncs < e1.n_host_syncs


def test_dispatch_count_amortized_by_horizon(setup, rng):
    """Lockstep workload: a 16-token generation at horizon 8 is exactly two
    decode dispatches — dispatches-per-token hits the ideal 1/H."""
    model, params = setup
    prompt = rng.integers(0, 64, (4,)).astype(np.int32)
    eng = ContinuousEngine(model, params, max_batch=2, page_size=4,
                           num_pages=32, prefill_chunk=8, decode_horizon=8)
    rid = eng.submit(prompt, 16)
    out = eng.run()
    assert len(out[rid]) == 16
    assert eng.n_decode_steps == 2            # 16 tokens / horizon 8
    assert eng.n_host_syncs == 3              # 1 prefill + 2 decode waves


# ---------------------------------------------------------------------------
# eos mid-horizon: trailing iterations no-op, output trimmed
# ---------------------------------------------------------------------------

def test_eos_mid_horizon_trims_output(setup, rng):
    model, params = setup
    prompt = rng.integers(0, 64, (6,)).astype(np.int32)
    eng0 = ContinuousEngine(model, params, max_batch=2, page_size=4,
                            num_pages=32, prefill_chunk=8)
    rid = eng0.submit(prompt, 12)
    full = eng0.run()[rid]
    eos = int(full[2])                      # fires on iteration 3 of 12
    eng = ContinuousEngine(model, params, max_batch=2, page_size=4,
                           num_pages=32, prefill_chunk=8, decode_horizon=8)
    rid = eng.submit(prompt, 12, eos_id=eos)
    out = eng.run()[rid]
    assert len(out) == 3 and out[-1] == eos
    np.testing.assert_array_equal(out, full[:3])
    # the whole generation fit one fused dispatch; the 5 post-eos
    # iterations were on-device no-ops, not extra dispatches
    assert eng.n_decode_steps == 1
    assert eng.n_tokens_out == 3


def test_budget_exhausted_mid_horizon(setup, rng):
    """max_new_tokens smaller than the horizon: the stop mask retires the
    row at the budget, never past it."""
    model, params = setup
    prompt = rng.integers(0, 64, (5,)).astype(np.int32)
    eng = ContinuousEngine(model, params, max_batch=2, page_size=4,
                           num_pages=32, prefill_chunk=8, decode_horizon=8)
    rid = eng.submit(prompt, 3)
    out = eng.run()[rid]
    assert len(out) == 3
    assert eng.n_decode_steps == 1


# ---------------------------------------------------------------------------
# page boundary crossed inside a horizon
# ---------------------------------------------------------------------------

def test_page_boundary_inside_horizon(setup, rng):
    """page_size=2 with horizon=8 crosses several page boundaries inside
    every fused dispatch; the up-front lease (reserve + full block-table
    row) means no host intervention and identical tokens."""
    model, params = setup
    requests = _mixed_requests(rng, 3, max_new=14)
    _, base = _serve(model, params, requests, 1, page_size=2, num_pages=96)
    eng, out = _serve(model, params, requests, 8, page_size=2, num_pages=96)
    for rid in base:
        np.testing.assert_array_equal(out[rid], base[rid])


def test_lease_covers_horizon_before_dispatch(setup, rng):
    """The decode lease reserves n_total - 1 + min(H, budget) positions up
    front: after every step, each decoding row's reserved pages cover its
    whole next horizon."""
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=2, page_size=2,
                           num_pages=64, prefill_chunk=8, decode_horizon=4)
    rid = eng.submit(rng.integers(0, 64, (5,)).astype(np.int32), 9)
    seq = eng._seqs[rid]
    saw_decode = False
    while True:
        pre_total, pre_gen = seq.n_total, len(seq.generated)
        if not eng.step():
            break
        if (len(seq.generated) > pre_gen and pre_gen > 0
                and seq.slot >= 0):                        # a decode wave
            saw_decode = True
            h = min(4, seq.req.max_new_tokens - pre_gen)
            # the lease taken before the dispatch covered the whole wave
            assert (eng.cache.n_covered_tokens(seq.slot)
                    >= pre_total - 1 + h)
    assert saw_decode
    assert len(eng.collect()[rid]) == 9


# ---------------------------------------------------------------------------
# preemption while a horizon lease is outstanding
# ---------------------------------------------------------------------------

def test_preemption_with_outstanding_lease(setup, rng):
    """A pool too small for two concurrent horizon leases forces eviction
    mid-stream; recompute preserves greedy identity and the allocator
    drains clean (leased-but-never-written pages are returned too)."""
    model, params = setup
    requests = [(rng.integers(0, 64, (4,)).astype(np.int32), 8)
                for _ in range(2)]
    _, ref = _serve(model, params, requests, 1, max_batch=4, page_size=2,
                    num_pages=64, prefill_chunk=4, max_seq=None)
    eng, out = _serve(model, params, requests, 4, max_batch=4, page_size=2,
                      num_pages=9, prefill_chunk=4, max_seq=None)
    assert eng.scheduler.n_preemptions > 0, "pool sized to force preemption"
    for rid in ref:
        np.testing.assert_array_equal(out[rid], ref[rid])
    c = eng.cache
    assert c.n_free_pages + c.n_cached_pages == c.num_pages - 1
    assert (c.ref_counts[1:] == 0).all() and c.ref_counts[0] == 1


# ---------------------------------------------------------------------------
# prefix-cache registration parity across horizons
# ---------------------------------------------------------------------------

def test_prefix_registration_parity_h1_vs_h8(setup):
    """A sequential stream behind one shared full-page prefix registers and
    matches identically at horizon 1 and 8: same hits, same positions
    saved, same tokens — boundary commits inside a horizon register every
    page the one-step path would have."""
    model, params = setup
    rng = np.random.default_rng(11)
    shared = rng.integers(0, 64, (16,)).astype(np.int32)     # 4 full pages
    requests = [(np.concatenate([shared, rng.integers(0, 64, (
        int(rng.integers(1, 5)),)).astype(np.int32)]),
        int(rng.integers(6, 12))) for _ in range(4)]

    def stream(horizon):
        eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                               num_pages=96, max_seq=48, prefill_chunk=4,
                               decode_horizon=horizon)
        outs = {}
        for r in requests:                  # sequential: each can hit
            eng.submit(*r)
            outs.update(eng.run())
        return eng, outs

    e1, o1 = stream(1)
    e8, o8 = stream(8)
    assert e1.n_prefix_hits == e8.n_prefix_hits == len(requests) - 1
    assert e1.n_prefix_positions_saved == e8.n_prefix_positions_saved
    for rid in o1:
        np.testing.assert_array_equal(o8[rid], o1[rid])
    # decode-filled pages registered mid-horizon too, not only prefill's:
    # both engines end with the same registry size
    assert len(e8.cache._registry) == len(e1.cache._registry)


# ---------------------------------------------------------------------------
# tensor parallel: tp=2 identity at horizon > 1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("horizon", [4])
def test_tp2_horizon_token_identity(qsetup, rng, horizon):
    """The scanned step stays a single shard_map dispatch at tp=2 and the
    on-device argmax (over psum/all_gather-replicated logits) reproduces
    the tp=1 horizon=1 tokens exactly."""
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=2)")
    model, qparams = qsetup
    requests = _mixed_requests(rng, 4, max_new=10)
    _, base = _serve(model, qparams, requests, 1, execution="simulated",
                     max_batch=4, max_seq=32, num_pages=64)
    _, out = _serve(model, qparams, requests, horizon,
                    execution="simulated", mesh=make_tp_mesh(2),
                    max_batch=4, max_seq=32, num_pages=64)
    for rid in base:
        np.testing.assert_array_equal(out[rid], base[rid])


# ---------------------------------------------------------------------------
# fork_request with a horizon engine
# ---------------------------------------------------------------------------

def test_fork_request_under_horizon(setup, rng):
    """fork_request semantics are untouched by the horizon path: children
    forked mid-stream reproduce the parent's greedy continuation."""
    model, params = setup
    prompt = rng.integers(0, 64, (6,)).astype(np.int32)
    ref_eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                               num_pages=64, prefill_chunk=8)
    rid = ref_eng.submit(prompt, 10)
    ref = ref_eng.run()[rid]
    eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                           num_pages=64, prefill_chunk=8, decode_horizon=4)
    rid = eng.submit(prompt, 10)
    for _ in range(3):                      # prefill + first horizon wave
        eng.step()
    seq = eng._seqs[rid]
    g_fork = len(seq.generated)
    assert 0 < g_fork < 10
    (child,) = eng.fork_request(rid, n=1)
    done = eng.run()
    np.testing.assert_array_equal(done[rid], ref)
    # the child continues from the fork point with a fresh 10-token budget:
    # under greedy sampling its output is continuation tokens
    # [g_fork : g_fork + 10] (asserted against a longer-budget reference)
    assert len(done[child]) == 10
    long_eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                                num_pages=64, max_seq=64, prefill_chunk=8)
    long_rid = long_eng.submit(prompt, g_fork + 10)
    np.testing.assert_array_equal(
        done[child], long_eng.run()[long_rid][g_fork:])


# ---------------------------------------------------------------------------
# ServeEngine: scanned generate vs the per-step loop
# ---------------------------------------------------------------------------

def test_serve_engine_scan_greedy_identity(setup, rng):
    model, params = setup
    eng = ServeEngine(model, params, max_seq=64)
    prompts = jnp.asarray(rng.integers(0, 64, (3, 8)), jnp.int32)
    out = eng.generate(prompts, n_tokens=7)
    assert out.shape == (3, 7)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(eng._generate_stepwise(prompts, n_tokens=7)))


def test_serve_engine_scan_temperature_identity(setup, rng):
    """Same rng key => the scanned categorical draws the exact same
    samples as the per-step loop (identical split order)."""
    model, params = setup
    eng = ServeEngine(model, params, max_seq=64)
    prompts = jnp.asarray(rng.integers(0, 64, (2, 6)), jnp.int32)
    key = jax.random.PRNGKey(7)
    np.testing.assert_array_equal(
        np.asarray(eng.generate(prompts, 8, temperature=0.7, rng=key)),
        np.asarray(eng._generate_stepwise(prompts, 8, temperature=0.7,
                                          rng=key)))
    # different temperatures reuse the same trace (temperature is traced,
    # not static) and different keys give different samples
    a = np.asarray(eng.generate(prompts, 8, temperature=1.3, rng=key))
    b = np.asarray(eng.generate(prompts, 8, temperature=1.3,
                                rng=jax.random.PRNGKey(8)))
    assert not np.array_equal(a, b)


# ---------------------------------------------------------------------------
# Sequence.tokens memoization
# ---------------------------------------------------------------------------

def test_sequence_tokens_memoized():
    seq = Sequence(Request(0, np.arange(5, dtype=np.int32), 4))
    t0 = seq.tokens
    assert t0 is seq.tokens                    # cached while unchanged
    assert not t0.flags.writeable              # shared => read-only
    seq.generated.append(7)
    t1 = seq.tokens
    assert t1 is not t0
    np.testing.assert_array_equal(t1, np.r_[np.arange(5), 7].astype(np.int32))
    assert t1 is seq.tokens                    # re-memoized at new length
    with pytest.raises(ValueError):
        t1[0] = 99                             # callers cannot corrupt it
