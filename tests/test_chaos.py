"""Chaos tests: the full serving stack under seeded fault injection.

Acceptance bar (ISSUE 7): N concurrent requests through the real
``APIServer`` while a seeded ``FaultPlan`` injects crashes/stalls/OOM —
final token streams byte-identical to a fault-free run, no request hangs;
and a 100-fault seeded run ends with zero leaked pages
(``check_invariants`` clean) and zero hung requests. Plus the loop-level
failure paths: an unsupervised engine death fails clients with a typed
error (no hang), the detokenize thread restarts after death, poison
requests answer 500 naming the cause, drain answers 503, and an injected
socket drop releases the request's pages.
"""
import dataclasses
import itertools
import json
import socket
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import QuantPolicy, quantize_params
from repro.models import Model
from repro.serve import (APIServer, ContinuousEngine, EngineSupervisor,
                         FaultEvent, FaultPlan)
from repro.serve.supervisor import Recovering, Saturated

import http.client


@pytest.fixture(scope="module")
def qsetup():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    qparams, report = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="dp", min_size=1024))
    assert report
    return model, qparams


ENG_KW = dict(max_batch=4, page_size=4, num_pages=64, prefill_chunk=8)

PROMPTS = [list(range(1, 9)), [3, 5, 7, 2], [10, 11, 12, 13, 14, 15],
           [20, 21, 22], [1, 2, 3, 4, 40, 41], [7, 7, 7, 7, 7]]
MAX_NEW = 8


def _engine(model, params, faults=None, **kw):
    merged = dict(ENG_KW, **kw)
    return ContinuousEngine(model, params, faults=faults, **merged)


def _reference(model, params, prompts, max_new=MAX_NEW):
    eng = _engine(model, params)
    rids = [eng.submit(np.asarray(p, np.int32), max_new) for p in prompts]
    out = eng.run()
    eng.close()
    return [out[r].tolist() for r in rids]


def _post(host, port, payload, timeout=120):
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        body = json.dumps(payload).encode()
        conn.request("POST", "/v1/completions", body=body,
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        return resp.status, dict(resp.getheaders()), resp.read()
    finally:
        conn.close()


def _get(host, port, path, timeout=30):
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, dict(resp.getheaders()), resp.read()
    finally:
        conn.close()


def _parse_sse(body: bytes):
    frames = [f for f in body.decode().split("\n\n") if f]
    for f in frames:
        assert f.startswith("data: "), f"bad SSE frame: {f!r}"
    assert frames[-1] == "data: [DONE]"
    return [json.loads(f[len("data: "):]) for f in frames[:-1]]


def _poll(predicate, timeout=30.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(0.02)
    return False


def _pool_at_baseline(cache):
    return (cache.n_free_pages + cache.n_cached_pages == cache.num_pages - 1
            and (cache.ref_counts[1:] == 0).all()
            and cache.n_free_slots == cache.max_seqs)


# -- the API-server chaos acceptance test -----------------------------------
def test_api_server_chaos_streams_byte_identical(qsetup):
    """Concurrent streaming clients through the real HTTP server while a
    seeded plan injects engine crashes, stalls and allocator OOM; every
    client's final stream is byte-identical to the fault-free run and no
    request hangs. Clients retry on 503 (recovery window) and 429."""
    model, params = qsetup
    refs = _reference(model, params, PROMPTS)
    # spread sized to the packed-prefill cadence: one dispatch covers a
    # whole admission wave, so the run reaches fewer step/apply indices
    plan = FaultPlan.seeded(42, n_faults=8, sites=("step", "apply", "alloc"),
                            first=2, spread=15, stall_s=0.02)
    sup = EngineSupervisor(
        lambda: _engine(model, params, faults=plan, max_waiting=32),
        watchdog=False, max_crashes_per_request=100)
    srv = APIServer(sup)
    host, port = srv.serve_background()
    try:
        def client(i):
            payload = {"prompt": PROMPTS[i], "max_tokens": MAX_NEW,
                       "stream": True}
            deadline = time.monotonic() + 120
            while True:
                assert time.monotonic() < deadline, f"client {i} hung"
                status, headers, body = _post(host, port, payload)
                if status in (429, 503):       # saturated / recovering
                    time.sleep(0.05)
                    continue
                assert status == 200, (status, body)
                return body

        with ThreadPoolExecutor(len(PROMPTS)) as pool:
            bodies = list(pool.map(client, range(len(PROMPTS))))

        for i, body in enumerate(bodies):
            frames = _parse_sse(body)
            toks = [t for f in frames for t in f["choices"][0]["token_ids"]]
            assert toks == refs[i], f"client {i} diverged"
            assert frames[-1]["choices"][0]["finish_reason"] == "length"
        assert plan.exhausted, (plan.fired, plan.n_events)
        assert sup.n_restarts > 0              # crashes actually recovered
        assert _poll(lambda: _pool_at_baseline(sup.engine.cache))
        sup.engine.cache.check_invariants(expect_idle=True)
    finally:
        srv.close()
        sup.close(check=False)


# -- the 100-fault endurance run --------------------------------------------
def test_hundred_fault_chaos_zero_leaks_zero_hangs(qsetup):
    """Direct-drive endurance: a seeded 100-event plan over a rolling
    workload. Every submitted request completes (token-identical to its
    fault-free reference) or is accounted for — none hang — and the pool
    ends at baseline with the invariant audit clean."""
    model, params = qsetup
    refs = _reference(model, params, PROMPTS)
    plan = FaultPlan.seeded(1337, n_faults=100,
                            sites=("step", "apply", "alloc"),
                            first=2, spread=400, stall_s=0.005)
    sup = EngineSupervisor(
        lambda: _engine(model, params, faults=plan, max_waiting=32),
        watchdog=False, max_crashes_per_request=1000)
    prompt_of = {}
    outputs = {}
    cycle = itertools.cycle(range(len(PROMPTS)))
    deadline = time.monotonic() + 240
    while not plan.exhausted:
        assert time.monotonic() < deadline, (
            f"chaos run hung: fired {len(plan.fired)}/{plan.n_events}, "
            f"{len(prompt_of) - len(outputs)} requests outstanding")
        # keep a rolling cohort in flight so every fault index is reached
        while len(prompt_of) - len(outputs) < 8:
            i = next(cycle)
            try:
                rid = sup.submit(np.asarray(PROMPTS[i], np.int32), MAX_NEW)
            except (Recovering, Saturated):
                break                          # recovery/backpressure window
            prompt_of[rid] = i
        sup.step()
        outputs.update({r: o for r, o in sup.collect().items()})
        assert not sup.pop_failures()          # budget 1000: nothing poisons
    # drain the tail: run() loops until has_work is false — the no-hang
    # bound is the pytest-level timeout on this step completing at all
    outputs.update(sup.run())
    assert not sup.pop_failures()
    assert set(outputs) == set(prompt_of), "requests hung or vanished"
    for rid, out in outputs.items():
        assert out.tolist() == refs[prompt_of[rid]], f"request {rid} diverged"
    assert plan.exhausted
    assert sup.n_restarts > 10                 # the plan really was hostile
    sup.engine.cache.check_invariants(expect_idle=True)
    assert _pool_at_baseline(sup.engine.cache)
    sup.close()                                # re-audits at teardown


# -- loop-level failure paths ------------------------------------------------
def test_unsupervised_engine_death_fails_clients_typed(qsetup):
    """Satellite bugfix: a crash escaping EngineLoop._run must fail the
    in-flight client with an error event (finish_reason "error"), not
    strand it, and /healthz must flip to 503."""
    model, params = qsetup
    plan = FaultPlan([FaultEvent("step", 2, "crash")])
    srv = APIServer(_engine(model, params, faults=plan))  # no supervisor
    host, port = srv.serve_background()
    try:
        status, _, body = _post(
            host, port,
            {"prompt": PROMPTS[0], "max_tokens": MAX_NEW, "stream": True})
        assert status == 200
        frames = _parse_sse(body)
        assert frames[-1]["choices"][0]["finish_reason"] == "error"
        assert "InjectedFault" in frames[-1]["error"]["message"]
        assert _poll(lambda: not srv.engine_loop.alive)
        assert srv.engine_loop.health == "dead"
        status, _, body = _get(host, port, "/healthz")
        assert status == 503
        assert json.loads(body)["status"] == "dead"
        # new submissions get a typed 503, not a hang
        status, _, _ = _post(host, port,
                             {"prompt": [1, 2], "max_tokens": 2})
        assert status == 503
    finally:
        srv.close()


def test_supervised_server_survives_the_same_crash(qsetup):
    """The same step crash under a supervisor: the client stream completes
    token-identical and /healthz returns to ok."""
    model, params = qsetup
    refs = _reference(model, params, [PROMPTS[0]])
    plan = FaultPlan([FaultEvent("step", 2, "crash")])
    sup = EngineSupervisor(lambda: _engine(model, params, faults=plan),
                           watchdog=False, degraded_window_s=0.2)
    srv = APIServer(sup)
    host, port = srv.serve_background()
    try:
        status, _, body = _post(
            host, port,
            {"prompt": PROMPTS[0], "max_tokens": MAX_NEW, "stream": True})
        assert status == 200
        frames = _parse_sse(body)
        toks = [t for f in frames for t in f["choices"][0]["token_ids"]]
        assert toks == refs[0]
        assert sup.n_restarts == 1
        assert _poll(lambda: srv.engine_loop.health == "ok")
        status, _, body = _get(host, port, "/healthz")
        assert status == 200
        h = json.loads(body)
        assert h["status"] == "ok"
        assert h["restarts"] == 1
    finally:
        srv.close()
        sup.close(check=False)


def test_detok_thread_death_restarts_and_stream_completes(qsetup):
    model, params = qsetup
    refs = _reference(model, params, [PROMPTS[0]])
    # the detok fire ticks once per batch loop pass; index 2 kills the
    # thread mid-request, between batches
    plan = FaultPlan([FaultEvent("detok", 2, "crash")])
    srv = APIServer(_engine(model, params), faults=plan)
    host, port = srv.serve_background()
    try:
        status, _, body = _post(
            host, port,
            {"prompt": PROMPTS[0], "max_tokens": MAX_NEW, "stream": True})
        assert status == 200
        frames = _parse_sse(body)
        toks = [t for f in frames for t in f["choices"][0]["token_ids"]]
        assert toks == refs[0]                 # nothing lost across restart
        assert srv.engine_loop.n_detok_restarts == 1
        assert srv.engine_loop.alive
        status, _, _ = _get(host, port, "/healthz")
        assert status == 200
    finally:
        srv.close()


def test_poison_request_answers_500_naming_cause(qsetup):
    model, params = qsetup
    plan = FaultPlan([FaultEvent("apply", i, "crash") for i in range(3)])
    sup = EngineSupervisor(lambda: _engine(model, params, faults=plan),
                           watchdog=False, max_crashes_per_request=3)
    srv = APIServer(sup)
    host, port = srv.serve_background()
    try:
        status, _, body = _post(
            host, port, {"prompt": PROMPTS[0], "max_tokens": MAX_NEW})
        assert status == 500
        err = json.loads(body)["error"]
        assert err["type"] == "engine_error"
        assert "PoisonedRequest" in err["message"]
        assert "3 engine crashes" in err["message"]
        assert "InjectedFault" in err["message"]   # names the cause
        assert _poll(lambda: _pool_at_baseline(sup.engine.cache))
    finally:
        srv.close()
        sup.close(check=False)


def test_drain_rejects_new_finishes_inflight_over_http(qsetup):
    model, params = qsetup
    refs = _reference(model, params, [PROMPTS[2]])
    sup = EngineSupervisor(lambda: _engine(model, params), watchdog=False)
    srv = APIServer(sup)
    host, port = srv.serve_background()
    try:
        with ThreadPoolExecutor(1) as pool:
            fut = pool.submit(_post, host, port,
                              {"prompt": PROMPTS[2], "max_tokens": MAX_NEW,
                               "stream": True})
            _poll(lambda: sup.engine.scheduler.has_work, timeout=10)
            srv.drain()
            status, _, body = _get(host, port, "/healthz")
            assert status == 503
            assert json.loads(body)["status"] == "draining"
            status, _, _ = _post(host, port,
                                 {"prompt": [1, 2], "max_tokens": 2})
            assert status == 503               # admissions closed
            status, _, body = fut.result(timeout=60)
        assert status == 200                   # in-flight work finished
        frames = _parse_sse(body)
        toks = [t for f in frames for t in f["choices"][0]["token_ids"]]
        assert toks == refs[0]
        assert _poll(lambda: srv.engine_loop.drained)
    finally:
        srv.close()
        sup.close(check=False)


def test_socket_drop_mid_stream_releases_pages(qsetup):
    """An injected connection drop on a token-bearing frame aborts the
    request server-side: the client sees an abrupt close (no [DONE]) and
    every page returns to the allocator."""
    model, params = qsetup
    plan = FaultPlan([FaultEvent("socket", 1, "crash")])
    eng = _engine(model, params)
    srv = APIServer(eng, faults=plan)
    host, port = srv.serve_background()
    try:
        s = socket.create_connection((host, port), timeout=60)
        payload = json.dumps({"prompt": PROMPTS[0], "max_tokens": MAX_NEW,
                              "stream": True}).encode()
        s.sendall((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                   f"Content-Type: application/json\r\n"
                   f"Content-Length: {len(payload)}\r\n\r\n").encode()
                  + payload)
        buf = b""
        while True:
            try:
                chunk = s.recv(4096)
            except ConnectionError:
                break
            if not chunk:
                break
            buf += chunk
        s.close()
        assert b"data: [DONE]" not in buf      # dropped mid-stream
        assert plan.exhausted
        assert _poll(lambda: _pool_at_baseline(eng.cache))
        eng.cache.check_invariants(expect_idle=True)
        # the server is still healthy for the next client
        status, _, _ = _post(host, port,
                             {"prompt": PROMPTS[1], "max_tokens": 4})
        assert status == 200
    finally:
        srv.close()
