"""Packed ragged prefill (DESIGN.md Sec. 16): bucket edge cases, packing x
preemption / prefix-cache / abort interactions, AOT warmup's zero-retrace
guarantee, the per-wave queue-depth observation, and the segment-masked
flash-attention kernel against its full-softmax oracle.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_segmented_ref
from repro.models import Model
from repro.serve import ContinuousEngine, jit_trace_count


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return model, params


def _run(model, params, requests, **kw):
    eng = ContinuousEngine(model, params, **kw)
    rids = [eng.submit(p, n) for p, n in requests]
    out = eng.run()
    toks = [out[r].tolist() for r in rids]
    return toks, eng


# ---------------------------------------------------------------------------
# bucket edge cases
# ---------------------------------------------------------------------------

def test_prompt_longer_than_largest_bucket_chunks_across_waves(setup, rng):
    """A 40-token prompt against buckets (4, 8, 16) cannot fit one wave:
    it must continue across successive waves (chunking falls out of the
    per-segment cache_len resume — no special case), and the output stays
    token-identical to the unpacked chunked path."""
    model, params = setup
    prompt = rng.integers(0, 64, (40,)).astype(np.int32)
    kw = dict(max_batch=4, page_size=4, num_pages=64, max_seq=48,
              prefill_chunk=4, prefix_cache=False)
    ref, _ = _run(model, params, [(prompt, 6)], prefill_packing=False, **kw)
    toks, eng = _run(model, params, [(prompt, 6)], prefill_packing=True, **kw)
    assert toks == ref
    # ceil(40 / 16) waves, each one dispatch
    assert eng.stats()["prefill_dispatches"] == 3
    assert eng.drain_observations()["packed_segments"] == [1, 1, 1]
    eng.close()


def test_single_short_prompt_one_dispatch(setup, rng):
    """One 5-token prompt: a single packed dispatch (padded to the smallest
    covering bucket), one segment observed, identical tokens."""
    model, params = setup
    prompt = rng.integers(0, 64, (5,)).astype(np.int32)
    kw = dict(max_batch=4, page_size=4, num_pages=64, max_seq=32,
              prefill_chunk=8, prefix_cache=False)
    ref, _ = _run(model, params, [(prompt, 8)], prefill_packing=False, **kw)
    toks, eng = _run(model, params, [(prompt, 8)], prefill_packing=True, **kw)
    assert toks == ref
    st = eng.stats()
    assert st["prefill_dispatches"] == 1 and st["prefill_segments"] == 1
    assert eng.drain_observations()["packed_segments"] == [1]
    eng.close()


def test_packing_with_preemption_token_identical(setup, rng):
    """A pool too small for the whole working set forces preemption by
    recompute mid-run; packed prefill must replay evicted segments at their
    reset cache_len and keep greedy output identical to a roomy engine."""
    model, params = setup
    requests = [(rng.integers(0, 64, (int(n),)).astype(np.int32), 10)
                for n in rng.integers(5, 12, (4,))]
    ref, _ = _run(model, params, requests, prefill_packing=False,
                  max_batch=4, page_size=4, num_pages=64, max_seq=32,
                  prefix_cache=False)
    toks, eng = _run(model, params, requests, prefill_packing=True,
                     max_batch=4, page_size=4, num_pages=14, max_seq=32,
                     prefix_cache=False)
    assert toks == ref
    assert eng.scheduler.n_preemptions > 0, "pool was not small enough"
    eng.cache.check_invariants(expect_idle=True)
    eng.close()


def test_prefix_adoption_packs_at_resumed_boundary(setup, rng):
    """A request adopting a cached prefix enters the packed wave at its
    matched boundary (cache_len > 0) alongside a cold request starting at
    0 — both in one dispatch, tokens identical to a cache-less engine."""
    model, params = setup
    base = rng.integers(0, 64, (12,)).astype(np.int32)
    warm = np.concatenate([base, rng.integers(0, 64, (4,))]).astype(np.int32)
    cold = rng.integers(0, 64, (7,)).astype(np.int32)
    kw = dict(max_batch=4, page_size=4, num_pages=64, max_seq=32,
              prefill_chunk=8)
    ref, _ = _run(model, params, [(base, 6), (warm, 6), (cold, 6)],
                  prefill_packing=False, prefix_cache=False, **kw)

    eng = ContinuousEngine(model, params, prefill_packing=True,
                           prefix_cache=True, **kw)
    r0 = eng.submit(base, 6)
    first = eng.run()                       # populates the prefix registry
    d0 = eng.stats()["prefill_dispatches"]
    r1, r2 = eng.submit(warm, 6), eng.submit(cold, 6)
    out = eng.run()
    st = eng.stats()
    assert [first[r0].tolist(), out[r1].tolist(), out[r2].tolist()] == ref
    assert st["prefix_hits"] >= 1, "warm request missed the registry"
    # the warm+cold wave is ONE dispatch carrying both segments
    assert st["prefill_dispatches"] == d0 + 1
    assert 2 in eng.drain_observations()["packed_segments"]
    eng.close()


def test_empty_prompt_rejected_at_submit(setup):
    """An empty prompt has nothing to prefill, so the packed planner would
    never assign it a segment (it would sit admitted-but-starved forever);
    submit rejects it up front — same contract as the HTTP 400."""
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                           num_pages=32, max_seq=16, prefill_chunk=4)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(np.asarray([], np.int32), 4)
    assert not eng.scheduler.has_work
    eng.close()


def test_abort_mid_packed_prefill_leaks_nothing(setup, rng):
    """Abort a segment whose prompt is still mid-prefill after the first
    packed wave: its leased pages are released, the surviving requests
    finish token-identically, and the pool audits clean."""
    model, params = setup
    a = rng.integers(0, 64, (6,)).astype(np.int32)
    b = rng.integers(0, 64, (20,)).astype(np.int32)     # > largest bucket
    c = rng.integers(0, 64, (5,)).astype(np.int32)
    kw = dict(max_batch=4, page_size=4, num_pages=64, max_seq=32,
              prefill_chunk=4, prefix_cache=False)
    ref, _ = _run(model, params, [(a, 6), (c, 6)],
                  prefill_packing=False, **kw)

    eng = ContinuousEngine(model, params, prefill_packing=True, **kw)
    ra, rb, rc = eng.submit(a, 6), eng.submit(b, 6), eng.submit(c, 6)
    assert eng.step()                       # first packed wave runs a + b
    sb = eng._seqs[rb]
    assert 0 < sb.cache_len < len(b), "b should be mid-prefill"
    assert eng.abort_request(rb)
    out = eng.run()
    assert rb not in out
    assert [out[ra].tolist(), out[rc].tolist()] == ref
    eng.cache.check_invariants(expect_idle=True)        # zero leaked pages
    eng.close()


# ---------------------------------------------------------------------------
# warmup + observations
# ---------------------------------------------------------------------------

def test_warmup_then_zero_new_traces(setup, rng):
    """After warmup() every reachable dispatch shape is compiled: a mixed
    serving run (packed prefill waves, decode-horizon buckets, a prompt
    longer than the largest bucket) performs zero new jit traces."""
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                           num_pages=64, max_seq=48, prefill_chunk=4,
                           decode_horizon=8, prefix_cache=False)
    report = eng.warmup()
    # 3 prefill buckets + decode-horizon batch buckets (1, 2, 4)
    assert report["entries"] == 6
    assert eng.stats()["warmup_traces"] == 6
    assert eng.stats()["warmup_seconds"] > 0
    n0 = jit_trace_count()
    requests = [(rng.integers(0, 64, (int(n),)).astype(np.int32), 9)
                for n in (3, 7, 20, 11)]
    rids = [eng.submit(p, n) for p, n in requests]
    out = eng.run()
    assert sorted(out) == sorted(rids)
    assert jit_trace_count() == n0, "steady-state serving retraced"
    eng.close()


def test_one_queue_depth_observation_per_admission_wave(setup, rng):
    """The scheduler reports queue depth once per admitting wave — not once
    per prefill chunk — so a long prompt's many chunks cannot skew the
    admission-depth histogram."""
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=8, page_size=4,
                           num_pages=64, max_seq=32, prefill_chunk=4,
                           prefix_cache=False)
    for _ in range(4):
        eng.submit(rng.integers(0, 64, (10,)).astype(np.int32), 4)
    eng.run()
    obs = eng.drain_observations()
    assert obs["admission_queue_depth"] == [4]        # one wave, depth 4
    assert eng.stats()["admission_waves"] == 1
    for _ in range(2):
        eng.submit(rng.integers(0, 64, (6,)).astype(np.int32), 4)
    eng.run()
    assert eng.drain_observations()["admission_queue_depth"] == [2]
    assert eng.stats()["admission_waves"] == 2
    eng.close()


# ---------------------------------------------------------------------------
# segment-masked flash attention vs oracle
# ---------------------------------------------------------------------------

def _segmented_inputs(rng, b=1, h=4, kv=2, s=128, d=32):
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, kv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, kv, s, d)), jnp.float32)
    # three contiguous segments + trailing pads, crossing the 64-tile edge
    segs = jnp.asarray(np.repeat([0, 1, 2, -1], [50, 30, 40, 8])[None, :],
                       jnp.int32)
    return q, k, v, segs


@pytest.mark.parametrize("causal,softcap", [(True, 0.0), (False, 0.0),
                                            (True, 30.0)])
def test_flash_segmented_matches_oracle(rng, causal, softcap):
    q, k, v, segs = _segmented_inputs(rng)
    o_k = flash_attention_fwd(q, k, v, segs, segs, causal=causal,
                              softcap=softcap, bq=64, bkv=64, interpret=True)
    o_r = flash_attention_segmented_ref(q, k, v, segs, segs, causal=causal,
                                        softcap=softcap)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=1e-4)


def test_flash_segmented_pad_rows_exactly_zero(rng):
    q, k, v, segs = _segmented_inputs(rng)
    o = np.asarray(flash_attention_fwd(q, k, v, segs, segs, causal=True,
                                       bq=64, bkv=64, interpret=True))
    assert np.all(o[:, :, 120:] == 0.0), "pad (-1) rows must output zero"
    assert np.all(np.isfinite(o))


def test_flash_segmented_cross_segment_isolation_bitwise(rng):
    """Perturbing another segment's K/V must leave this segment's output
    bit-identical — cross-segment attention is structurally zero, not just
    numerically small."""
    q, k, v, segs = _segmented_inputs(rng)
    o1 = np.asarray(flash_attention_fwd(q, k, v, segs, segs, causal=True,
                                        bq=64, bkv=64, interpret=True))
    k2 = k.at[:, :, 50:80].multiply(-3.0)       # segment 1's keys
    v2 = v.at[:, :, 50:80].add(7.0)
    o2 = np.asarray(flash_attention_fwd(q, k2, v2, segs, segs, causal=True,
                                        bq=64, bkv=64, interpret=True))
    np.testing.assert_array_equal(o1[:, :, :50], o2[:, :, :50])     # seg 0
    np.testing.assert_array_equal(o1[:, :, 80:120], o2[:, :, 80:120])
    assert np.any(o1[:, :, 50:80] != o2[:, :, 50:80])   # seg 1 did change


def test_segment_args_must_come_in_pairs(rng):
    from repro.kernels.flash_attention.ops import attention
    q, k, v, segs = _segmented_inputs(rng)
    with pytest.raises(ValueError, match="both"):
        flash_attention_fwd(q, k, v, segs, None, interpret=True)
    with pytest.raises(ValueError, match="both"):
        attention(q, k, v, kv_segs=segs, use_kernel=False)
