"""Pipeline-parallel schedule == sequential layer application."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.pipeline import pipeline_forward


@pytest.mark.requires_env("axis_type")
def test_pipeline_matches_sequential(rng):
    n_stages, n_micro, mb, d = 4, 6, 2, 8
    mesh = jax.make_mesh((n_stages,), ("stage",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    ws = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3, jnp.float32)
    xs = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)

    def layer_fn(w, x):
        return jnp.tanh(x @ w)

    ws_sharded = jax.device_put(ws, NamedSharding(mesh, P("stage")))
    with mesh:
        out = jax.jit(lambda w, x: pipeline_forward(
            layer_fn, w, x, mesh))(ws_sharded, xs)

    ref = xs
    for s in range(n_stages):
        ref = jax.vmap(lambda x: layer_fn(ws[s], x))(ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
