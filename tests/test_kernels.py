"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import quantize_blockwise, dequantize
from repro.kernels.msb_matmul.msb_matmul import msb_matmul
from repro.kernels.msb_matmul.ops import qtensor_matmul, to_kernel_layout
from repro.kernels.msb_matmul.ref import dequant_ref, msb_matmul_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_ref


# ---------------------------------------------------------------------------
# msb_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 64, 128), (16, 128, 128),
                                   (8, 256, 384), (32, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_msb_matmul_sweep(rng, m, k, n, dtype):
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    q = quantize_blockwise(w, bits=4, block=64, solver="dp")
    packed, scales = to_kernel_layout(q)
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    y_kernel = msb_matmul(x, packed, scales, bm=8, bn=128, bk=64,
                          interpret=True)
    y_ref = msb_matmul_ref(x, packed, scales)
    atol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(y_kernel, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=atol, rtol=1e-2)


def test_kernel_layout_matches_qtensor_dequant(rng):
    """packed/scales layout dequantizes to exactly QTensor.dequantize()
    (up to the packed-zero caveat)."""
    w = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
    q = quantize_blockwise(w, bits=4, block=64, solver="dp")
    packed, scales = to_kernel_layout(q)
    np.testing.assert_allclose(np.asarray(dequant_ref(packed, scales)),
                               np.asarray(dequantize(q)), atol=1e-6)


def test_qtensor_matmul_wrapper(rng):
    w = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    q = quantize_blockwise(w, bits=4, block=64, solver="dp")
    x = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    y_ref = x @ dequantize(q)
    y_jnp = qtensor_matmul(x, q, use_kernel=False)
    y_krn = qtensor_matmul(x, q, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(y_krn), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)


def test_msb_matmul_block_shape_invariance(rng):
    """Different VMEM tilings give identical results."""
    k, n = 128, 256
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    q = quantize_blockwise(w, bits=4, block=64, solver="dp")
    packed, scales = to_kernel_layout(q)
    x = jnp.asarray(rng.standard_normal((16, k)), jnp.float32)
    outs = [np.asarray(msb_matmul(x, packed, scales, bm=bm, bn=bn, bk=bk,
                                  interpret=True))
            for bm, bn, bk in [(16, 256, 128), (8, 128, 64), (16, 64, 32)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,h,kv,d", [(128, 4, 2, 32), (256, 8, 8, 16),
                                      (64, 2, 1, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_sweep(rng, s, h, kv, d, causal):
    B = 2
    q = jnp.asarray(rng.standard_normal((B, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, kv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, kv, s, d)), jnp.float32)
    o_k = flash_attention_fwd(q, k, v, causal=causal, bq=64, bkv=64,
                              interpret=True)
    o_r = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("window,cap", [(32, 0.0), (0, 30.0), (64, 50.0)])
def test_flash_kernel_window_softcap(rng, window, cap):
    B, H, KV, S, D = 1, 4, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
    o_k = flash_attention_fwd(q, k, v, causal=True, window=window,
                              softcap=cap, bq=32, bkv=32, interpret=True)
    o_r = flash_attention_ref(q, k, v, causal=True, window=window,
                              softcap=cap)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=1e-4)


def test_flash_kernel_bf16(rng):
    B, H, KV, S, D = 1, 2, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.bfloat16)
    o_k = flash_attention_fwd(q, k, v, causal=True, bq=64, bkv=64,
                              interpret=True)
    o_r = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=3e-2)
