"""Packed execution path: layout, kernel parity on serving shapes,
model dispatch, and packed-vs-simulated engine identity (DESIGN.md Sec. 9)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (PackedQTensor, QuantPolicy, dequantize, pack_params,
                        pack_qtensor, packed_dequantize, packed_gather,
                        param_bits, quantize_blockwise, quantize_params,
                        storage_bits_per_weight)
from repro.kernels.msb_matmul.msb_matmul import msb_matmul, pick_blocks
from repro.kernels.msb_matmul.ops import packed_matmul, qtensor_matmul


def _q(rng, k, n, scale_dtype=jnp.float32):
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    return quantize_blockwise(w, bits=4, block=64, solver="kmeans",
                              scale_dtype=scale_dtype)


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

def test_packed_layout_roundtrip_exact(rng):
    q = _q(rng, 128, 192)
    pq = pack_qtensor(q)
    np.testing.assert_array_equal(np.asarray(packed_dequantize(pq)),
                                  np.asarray(dequantize(q)))


def test_packed_layout_pads_n(rng):
    """N not a multiple of the 128 lane tile (and the pack pad) dequantizes
    to exact zeros in the padded columns."""
    q = _q(rng, 64, 192)
    pq = pack_qtensor(q)
    assert pq.n == 192 and pq.n_pad % 64 == 0
    q2 = _q(rng, 128, 64)
    assert pack_qtensor(q2).n_pad == 64          # already aligned: no pad


def test_kblocked_transpose_pack(rng):
    """Transposed pack of a (V, D) table == dequantize(q).T — same codebook
    assignment, no re-quantization."""
    q = _q(rng, 96, 128)                          # V=96 (pads to 128), D=128
    pq = pack_qtensor(q, transpose=True)
    assert pq.kblocked and pq.shape == (128, 96)
    np.testing.assert_array_equal(np.asarray(packed_dequantize(pq)),
                                  np.asarray(dequantize(q)).T)


def test_packed_pytree_static_aux(rng):
    pq = pack_qtensor(_q(rng, 64, 128))
    leaves, treedef = jax.tree_util.tree_flatten(pq)
    assert len(leaves) == 2
    pq2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert pq2.n == pq.n and pq2.kblocked == pq.kblocked
    y = jax.jit(lambda p: packed_dequantize(p))(pq)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(packed_dequantize(pq)))


def test_packed_scan_slice_invariance(rng):
    """Stacked (scan-over-layers) packed params slice per period cleanly."""
    w = jnp.asarray(rng.standard_normal((3, 64, 128)), jnp.float32)
    pq = pack_qtensor(quantize_blockwise(w, bits=4, block=64,
                                         solver="kmeans"))
    sl = jax.tree_util.tree_map(lambda a: a[1], pq)
    np.testing.assert_array_equal(np.asarray(packed_dequantize(sl)),
                                  np.asarray(packed_dequantize(pq))[1])


# ---------------------------------------------------------------------------
# kernel parity on serving shapes (interpret mode on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 2, 4, 8])
@pytest.mark.parametrize("scale_dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_parity_decode_buckets(rng, m, scale_dtype):
    """M=1 and bucketed decode M in {2,4,8}, N=192 (not divisible by the
    128 tile), bf16 and f32 scales."""
    pq = pack_qtensor(_q(rng, 128, 192, scale_dtype))
    x = jnp.asarray(rng.standard_normal((m, 128)), jnp.float32)
    y_k = packed_matmul(x, pq, use_kernel=True, interpret=True)
    y_r = packed_matmul(x, pq, use_kernel=False)
    assert y_k.shape == (m, 192)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               atol=2e-4, rtol=1e-3)


def test_kernel_parity_kblocked(rng):
    """Unembedding orientation: x (B, D) @ table^T via k-blocked scales."""
    q = _q(rng, 96, 128)
    pq = pack_qtensor(q, transpose=True)
    x = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    y_k = packed_matmul(x, pq, use_kernel=True, interpret=True)
    y_d = np.asarray(x) @ np.asarray(dequantize(q)).T
    np.testing.assert_allclose(np.asarray(y_k), y_d, atol=2e-4, rtol=1e-3)


def test_kernel_fused_bias(rng):
    pq = pack_qtensor(_q(rng, 64, 128))
    b = jnp.asarray(rng.standard_normal((128,)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((3, 64)), jnp.float32)
    y_k = packed_matmul(x, pq, bias=b, use_kernel=True, interpret=True)
    y_r = packed_matmul(x, pq, bias=b, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               atol=2e-4, rtol=1e-3)


def test_kernel_fused_bias_padded_n(rng):
    """Bias of logical width V on a k-blocked table whose storage pads V
    (V=96 -> 128): kernel pads the bias too instead of crashing."""
    q = _q(rng, 96, 128)
    pq = pack_qtensor(q, transpose=True)
    b = jnp.asarray(rng.standard_normal((96,)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 128)), jnp.float32)
    y_k = packed_matmul(x, pq, bias=b, use_kernel=True, interpret=True)
    y_r = packed_matmul(x, pq, bias=b, use_kernel=False)
    assert y_k.shape == (2, 96)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               atol=2e-4, rtol=1e-3)


def test_block_heuristics_divide():
    for m, k, n in [(1, 2048, 2048), (8, 1024, 4096), (32, 192, 576),
                    (128, 256, 128), (7, 96, 64)]:
        bm, bn, bk = pick_blocks(m, k, n)
        assert n % bn == 0 and k % bk == 0 and bn % 64 == 0
        assert m > 8 or bm == 8


def test_gemv_padding_path(rng):
    """M smaller than the sublane tile pads internally and slices back."""
    pq = pack_qtensor(_q(rng, 64, 128))
    for m in (1, 3, 5):
        x = jnp.asarray(rng.standard_normal((m, 64)), jnp.float32)
        y = msb_matmul(x, pq.packed, pq.scales, interpret=True)
        ref = packed_matmul(x, pq, use_kernel=False)
        assert y.shape == (m, 128)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=2e-4, rtol=1e-3)


def test_qtensor_matmul_memoizes_packing(rng):
    from repro.kernels.msb_matmul import ops
    q = _q(rng, 64, 128)
    x = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)
    ops._PACK_CACHE.clear()
    y1 = qtensor_matmul(x, q, use_kernel=False)
    assert len(ops._PACK_CACHE) == 1
    y2 = qtensor_matmul(x, q, use_kernel=False)
    assert len(ops._PACK_CACHE) == 1              # second call: cache hit
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


# ---------------------------------------------------------------------------
# packed gather (embedding path)
# ---------------------------------------------------------------------------

def test_packed_gather_matches_dequant_rows(rng):
    q = _q(rng, 512, 64)                          # (V, D) table
    pq = pack_qtensor(q)
    idx = jnp.asarray(rng.integers(0, 512, (2, 7)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(packed_gather(pq, idx)),
        np.asarray(dequantize(q))[np.asarray(idx)])


# ---------------------------------------------------------------------------
# pack pass + storage accounting
# ---------------------------------------------------------------------------

def test_pack_params_and_footprint(rng):
    w = {"mlp": {"wi": _q(rng, 64, 128, jnp.bfloat16)},
         "norm": jnp.ones((64,), jnp.float32)}
    packed, report = pack_params(w)
    assert isinstance(packed["mlp"]["wi"], PackedQTensor)
    assert "mlp/wi" in report and packed["norm"].shape == (64,)
    # real packed footprint: 4 bits codes + 8 bf16 scales per 64 block = 6.0
    assert storage_bits_per_weight(packed["mlp"]["wi"]) == pytest.approx(6.0)
    # device arrays shrink 2x on the codes (int8 -> two-per-byte uint8)
    assert packed["mlp"]["wi"].packed.size == 64 * 128 // 2
    assert param_bits(packed) == param_bits(w)    # idealized accounting agrees


def test_pack_params_unembed_transposed(rng):
    tree = {"unembed": _q(rng, 128, 64), "embed": _q(rng, 128, 64)}
    packed, _ = pack_params(tree)
    assert packed["unembed"].kblocked and not packed["embed"].kblocked


def test_dense_dispatch_packed_equals_simulated(rng):
    from repro.models.layers import dense
    q = _q(rng, 64, 128)
    pq = pack_qtensor(q)
    x = jnp.asarray(rng.standard_normal((2, 5, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((128,)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(dense(x, q, b)),
                                  np.asarray(dense(x, pq, b)))


# ---------------------------------------------------------------------------
# MoE: per-expert streamed dequant / packed dispatch
# ---------------------------------------------------------------------------

def _moe_setup(rng, packed):
    from repro.configs import smoke_config
    cfg = smoke_config("granite-moe-3b-a800m")
    e, d, f = cfg.n_experts_padded, cfg.d_model, cfg.d_ff
    p = {"router": jnp.asarray(rng.standard_normal((d, e)), jnp.float32)}
    for name, shape in [("wg", (e, d, f)), ("wi", (e, d, f)),
                        ("wo", (e, f, d))]:
        w = jnp.asarray(0.1 * rng.standard_normal(shape), jnp.float32)
        q = quantize_blockwise(w, bits=4, block=64, solver="kmeans")
        p[name] = pack_qtensor(q) if packed else q
    x = jnp.asarray(rng.standard_normal((2, 8, d)), jnp.float32)
    return cfg, p, x


@pytest.mark.parametrize("packed", [False, True])
def test_moe_lazy_expert_path_matches_eager(rng, packed):
    """Quantized expert weights through the streamed/router-gated path equal
    the old eager-dequantize-everything result."""
    from repro.core import dequantize_params
    from repro.models.moe import moe_layer
    cfg, p, x = _moe_setup(rng, packed)
    y_lazy, aux_lazy = moe_layer(p, x, cfg)
    y_eager, aux_eager = moe_layer(dequantize_params(p), x, cfg)
    np.testing.assert_allclose(np.asarray(y_lazy), np.asarray(y_eager),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux_lazy), float(aux_eager), rtol=1e-6)


# ---------------------------------------------------------------------------
# engines: packed vs simulated token identity
# ---------------------------------------------------------------------------

def _tiny_quantized_model():
    from repro.configs import smoke_config
    from repro.models import Model
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    qparams, _ = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="kmeans", min_size=1024))
    return model, qparams


def test_continuous_engine_packed_token_identical():
    """Greedy decode through ContinuousEngine is token-identical between
    execution="packed" and execution="simulated" (acceptance criterion)."""
    from repro.serve import ContinuousEngine
    model, qparams = _tiny_quantized_model()
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(0, 64, (int(rng.integers(4, 12)),)).astype(np.int32),
             int(rng.integers(4, 10))) for _ in range(4)]
    outs = {}
    for ex in ("simulated", "packed"):
        eng = ContinuousEngine(model, qparams, max_batch=4, page_size=4,
                               num_pages=64, max_seq=32, prefill_chunk=8,
                               execution=ex)
        for r in reqs:
            eng.submit(*r)
        outs[ex] = eng.run()
        assert eng.execution == ex
    assert outs["simulated"].keys() == outs["packed"].keys()
    for rid in outs["simulated"]:
        np.testing.assert_array_equal(outs["simulated"][rid],
                                      outs["packed"][rid])


def test_serve_engine_packed_param_tree():
    """Static engine packs at load: params carry PackedQTensor leaves and
    the same greedy tokens come out."""
    from repro.serve import ServeEngine
    model, qparams = _tiny_quantized_model()
    prompts = jnp.asarray(np.arange(8, dtype=np.int32).reshape(2, 4))
    eng_s = ServeEngine(model, qparams, max_seq=32, execution="simulated")
    eng_p = ServeEngine(model, qparams, max_seq=32, execution="packed")
    has_packed = any(isinstance(l, PackedQTensor)
                     for l in jax.tree_util.tree_leaves(
                         eng_p.params,
                         is_leaf=lambda x: isinstance(x, PackedQTensor)))
    assert has_packed
    out_s = eng_s.generate(prompts, n_tokens=6)
    out_p = eng_p.generate(prompts, n_tokens=6)
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(out_p))
