"""Test env: 8 host devices for the distributed tests (NOT the dry-run's
512 — that flag lives only in launch/dryrun.py per the assignment).

``requires_env`` marker: a handful of tier-1 tests exercise jax APIs that
not every runtime in the support window ships (``jax.sharding.AxisType``
explicit-mesh types; dict-shaped ``compiled.cost_analysis()``). They are
marked ``@pytest.mark.requires_env("<capability>")`` and skip — with the
missing capability named — on runtimes that lack it, so a clean run
reports 0 failures everywhere and any *unmarked* failure is a real
regression CI must reject.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import pytest  # noqa: E402

_CAPS = None


def _env_capabilities():
    """Probe the jax runtime once per session for the optional capabilities
    the marked tests need. Probes are behavioural (try it), not version
    string comparisons — forks and backports stay honest."""
    global _CAPS
    if _CAPS is None:
        import jax

        caps = {"axis_type": hasattr(jax.sharding, "AxisType")}
        try:
            compiled = jax.jit(lambda x: x + 1.0).lower(1.0).compile()
            caps["dict_cost_analysis"] = isinstance(
                compiled.cost_analysis(), dict)
        except Exception:
            caps["dict_cost_analysis"] = False
        _CAPS = caps
    return _CAPS


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "requires_env(capability): skip when the jax runtime lacks the "
        "named capability ('axis_type' = jax.sharding.AxisType explicit "
        "mesh axis types; 'dict_cost_analysis' = dict-shaped "
        "Compiled.cost_analysis())")


def pytest_collection_modifyitems(config, items):
    for item in items:
        for mark in item.iter_markers("requires_env"):
            missing = [c for c in mark.args
                       if not _env_capabilities().get(c, False)]
            if missing:
                item.add_marker(pytest.mark.skip(
                    reason="jax runtime lacks capability "
                           f"{'/'.join(missing)} (requires_env)"))


@pytest.fixture
def rng():
    return np.random.default_rng(0)
