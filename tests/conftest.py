"""Test env: 8 host devices for the distributed tests (NOT the dry-run's
512 — that flag lives only in launch/dryrun.py per the assignment)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
