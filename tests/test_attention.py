"""Chunked flash attention vs naive softmax attention (fwd + grad + decode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import decode_attention, flash_attention


def naive(q, k, v, causal=True, window=0, softcap=0.0):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    qh = q.reshape(b, sq, kv, rep, d).astype(jnp.float32)
    s = jnp.einsum("bqkrd,bskd->bqkrs", qh, k.astype(jnp.float32)) / d ** 0.5
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos, kpos = jnp.arange(sq), jnp.arange(k.shape[1])
    m = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        m &= kpos[None] <= qpos[:, None]
    if window > 0:
        m &= kpos[None] > qpos[:, None] - window
    s = jnp.where(m[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqkrs,bskd->bqkrd", p,
                      v.astype(jnp.float32)).reshape(b, sq, h, d)


@pytest.fixture
def qkv(rng):
    B, S, H, KV, D = 2, 129, 8, 4, 16
    return (jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32),
            jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32),
            jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32))


@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (True, 32, 0.0), (True, 0, 30.0), (False, 0, 0.0),
    (True, 32, 50.0)])
def test_forward(qkv, causal, window, cap):
    q, k, v = qkv
    o1 = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                         chunk_q=32, chunk_kv=32)
    o2 = naive(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=2e-5, rtol=1e-4)


def test_gradients(qkv):
    q, k, v = qkv

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True, window=32,
                                       softcap=30.0, chunk_q=32,
                                       chunk_kv=32) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(naive(q, k, v, causal=True, window=32,
                             softcap=30.0) ** 2)

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=1e-3)


def test_decode_matches_full(rng):
    B, S, KV, H, D = 2, 64, 2, 4, 16
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    valid = 40
    pos = jnp.where(jnp.arange(S)[None] < valid, jnp.arange(S)[None], -1)
    pos = jnp.tile(pos, (B, 1)).astype(jnp.int32)
    out = decode_attention(q, kc, vc, pos, jnp.full((B,), valid - 1,
                                                    jnp.int32), chunk_kv=16)
    ref = naive(q, kc[:, :valid], vc[:, :valid], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_decode_sliding_window(rng):
    B, S, KV, H, D = 1, 64, 2, 2, 16
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    pos = jnp.tile(jnp.arange(S)[None], (B, 1)).astype(jnp.int32)
    cur = jnp.full((B,), S - 1, jnp.int32)
    win = 16
    out = decode_attention(q, kc, vc, pos, cur, window=win, chunk_kv=16)
    ref = naive(q, kc[:, S - win:], vc[:, S - win:], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)
