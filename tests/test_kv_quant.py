"""MSB-quantized paged KV cache (DESIGN.md Sec. 15).

Four layers of coverage:
  * token fidelity: greedy serving outputs across ``kv_bits`` 16/8/4 x
    ``execution`` simulated/packed x ``decode_horizon`` 1/8 x TP 1/2,
    plus prefix-cache adoption, ``fork_request`` and mid-horizon page
    boundaries. 8-bit is token-identical to the bf16 cache on the smoke
    workload; 4-bit is exact until the first page commits, deterministic
    across horizons/modes after, with bounded logit drift.
  * codec properties: round-trip shape/dtype stability, 4-bit scale
    monotonicity, exactness on small alphabets, determinism — asserted on
    fixed seeds here and fuzzed under hypothesis when it is installed.
  * the fused-dequant Pallas kernel against its jnp oracle (interpret
    mode, mixed partial/full sequences).
  * dual-pool allocator invariants: the quantization frontier tracks
    commits, corruptions are detected (negative tests), prefix digests
    are bit-exact across kv_bits, and a chaos run (tight pool, forks,
    aborts, preemption) audits clean.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import (KVQuantSpec, QuantPolicy, kv_dequantize_pages,
                        kv_native_page_bytes, kv_quantize_pages,
                        quantize_params)
from repro.models import Model
from repro.serve import ContinuousEngine
from repro.serve.paged_cache import PageStateError


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return model, params


@pytest.fixture(scope="module")
def qsetup(setup):
    model, params = setup
    qparams, report = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="dp", min_size=1024))
    assert report
    return model, qparams


def _requests(n=6, seed=5):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, 64, (int(rng.integers(3, 14)),))
             .astype(np.int32), int(rng.integers(2, 10)))
            for _ in range(n)]


def _serve(model, params, reqs, **kw):
    opts = dict(max_batch=4, page_size=4, num_pages=64, max_seq=32,
                prefill_chunk=6, prefix_cache=False)
    opts.update(kw)
    eng = ContinuousEngine(model, params, **opts)
    rids = [eng.submit(*r) for r in reqs]
    outs = eng.run()
    eng.cache.check_invariants()
    eng.close()
    return eng, [outs[r].tolist() for r in rids]


# ---------------------------------------------------------------------------
# token fidelity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ref16(setup):
    model, params = setup
    return _serve(model, params, _requests())[1]


@pytest.mark.parametrize("horizon", [1, 8])
def test_kv8_token_identical(setup, ref16, horizon):
    """8-bit cache: greedy output == bf16 cache, at both decode horizons
    (horizon=8 crosses page boundaries mid-dispatch at page_size=4)."""
    model, params = setup
    _, outs = _serve(model, params, _requests(), kv_bits=8,
                     decode_horizon=horizon)
    assert outs == ref16


@pytest.mark.parametrize("execution", ["simulated", "packed"])
@pytest.mark.parametrize("kv_bits", [8, 4])
def test_execution_modes_identical(qsetup, execution, kv_bits):
    """Quantized-cache decoding is execution-mode-invariant: the packed
    weight path and its simulation produce the same greedy tokens over
    the same quantized pools (the cache codec is orthogonal to the weight
    representation)."""
    model, qparams = qsetup
    _, outs = _serve(model, qparams, _requests(), kv_bits=kv_bits,
                     execution=execution)
    _, sim = _serve(model, qparams, _requests(), kv_bits=kv_bits,
                    execution="simulated")
    assert outs == sim


def test_kv4_deterministic_across_horizons(setup):
    """4-bit drifts from bf16 after pages commit, but the drift is a pure
    function of the cache content: horizons 1 and 8 agree token-for-token,
    and a repeat run is bit-identical (no hidden nondeterminism)."""
    model, params = setup
    _, h1 = _serve(model, params, _requests(), kv_bits=4, decode_horizon=1)
    _, h8 = _serve(model, params, _requests(), kv_bits=4, decode_horizon=8)
    _, h1b = _serve(model, params, _requests(), kv_bits=4, decode_horizon=1)
    assert h1 == h8 == h1b


def test_kv4_exact_before_any_commit(setup, ref16):
    """Until a sequence fills its first page nothing is quantized (the hot
    row stays full precision), so with page_size=16 a workload capped at
    12 positions per request never commits a page and the 4-bit engine is
    token-identical to bf16 — exactness-before-commit, by construction."""
    model, params = setup
    reqs = [(p[:6], min(n, 6)) for p, n in _requests()]   # <= 12 positions
    _, o16 = _serve(model, params, reqs, page_size=16, num_pages=16,
                    kv_bits=16)
    _, o4 = _serve(model, params, reqs, page_size=16, num_pages=16,
                   kv_bits=4)
    assert o4 == o16


def test_kv4_bounded_logit_drift(setup):
    """After committing two full pages the 4-bit logits stay within a
    small bound of the bf16-cache logits on the real vocab (padded rows
    are -inf on both sides and excluded)."""
    model, params = setup
    rng = np.random.default_rng(9)
    toks = rng.integers(0, 64, (1, 8)).astype(np.int32)
    q_pos = np.arange(8, dtype=np.int32)[None]
    bt = np.array([[1, 2, 3]], np.int32)
    lens = np.array([8], np.int32)
    slots = np.array([0], np.int32)
    outs = {}
    for bits in (16, 4):
        pools = model.init_paged_pools(4, 4, kv_bits=bits, max_seqs=2)
        logits, pools = model.paged_step(
            params, pools, jnp.asarray(toks), jnp.asarray(q_pos),
            jnp.asarray(lens), jnp.asarray(bt), kv_bits=bits,
            slots=jnp.asarray(slots))
        # one decode step on top: reads the two committed (quantized) pages
        logits2, _ = model.paged_step(
            params, pools, jnp.asarray([[7]], np.int32),
            jnp.asarray([[8]], np.int32), jnp.asarray([9], np.int32),
            jnp.asarray(bt), kv_bits=bits, slots=jnp.asarray(slots))
        outs[bits] = np.asarray(logits2)[0, :64]          # real vocab only
    diff = np.max(np.abs(outs[4] - outs[16]))
    assert np.isfinite(diff)
    assert diff < 0.5, f"4-bit logit drift {diff} exceeds bound"


def test_prefix_adoption_identity(setup):
    """A request admitted through the prefix registry (pages adopted by
    refcount, prefill skipped) decodes the same tokens as the request that
    populated it — at every kv_bits (digests hash tokens, not codes)."""
    model, params = setup
    prompt = np.random.default_rng(7).integers(0, 64, (11,)).astype(np.int32)
    for bits in (16, 8, 4):
        eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                               num_pages=64, max_seq=32, prefill_chunk=6,
                               kv_bits=bits, prefix_cache=True)
        a = eng.submit(prompt, 6)
        o1 = eng.run()
        b = eng.submit(prompt, 6)
        o2 = eng.run()
        assert eng.n_prefix_hits >= 1
        assert o1[a].tolist() == o2[b].tolist()
        eng.cache.check_invariants()
        eng.close()


def test_fork_identity(setup):
    """fork_request on quantized pools: children share committed (packed)
    pages by refcount and copy the parent's hot row; under greedy decoding
    every child reproduces the parent's own continuation — token-identical
    to the bf16-cache fork at 8-bit, deterministic at 4-bit."""
    model, params = setup
    prompt = np.random.default_rng(3).integers(0, 64, (11,)).astype(np.int32)

    def fork_run(bits):
        eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                               num_pages=64, max_seq=40, prefill_chunk=6,
                               kv_bits=bits)
        rid = eng.submit(prompt, 8)
        for _ in range(4):
            eng.step()
        kids = eng.fork_request(rid, n=2)
        assert eng.n_forks == 2            # shared pages, not resubmission
        outs = eng.run()
        eng.cache.check_invariants()
        eng.close()
        return [outs[r].tolist() for r in [rid] + kids]

    r16 = fork_run(16)
    r8 = fork_run(8)
    assert r8 == r16
    r4 = fork_run(4)
    # representation-independent structure, asserted at every width: the
    # children are deterministic twins, and each child's head replays the
    # parent's own continuation from the fork point (committed shared
    # pages + the copied hot row are coherent)
    for parent, c1, c2 in (r16, r8, r4):
        assert c1 == c2
        g = next(i for i in range(len(parent) + 1)
                 if c1[:len(parent) - i] == parent[i:])
        assert g <= 4, f"children do not extend the parent: {parent} {c1}"


def test_tp2_token_identity(setup):
    """Head-sharded quantized pools (codes/hot along axis 3, scales along
    axis 2): tp=2 greedy output == tp=1 at both quantized widths, with the
    decode-horizon scan inside the shard_map body."""
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=2)")
    from repro.launch.mesh import make_tp_mesh
    model, params = setup
    mesh = make_tp_mesh(2)
    for bits in (8, 4):
        _, tp1 = _serve(model, params, _requests(4), kv_bits=bits)
        _, tp2 = _serve(model, params, _requests(4), kv_bits=bits, mesh=mesh)
        _, tp2h = _serve(model, params, _requests(4), kv_bits=bits,
                         mesh=mesh, decode_horizon=4)
        assert tp2 == tp1
        assert tp2h == tp1


# ---------------------------------------------------------------------------
# codec properties (fixed-seed; hypothesis fuzzing below when installed)
# ---------------------------------------------------------------------------

def _check_roundtrip_static(x, bits):
    spec = KVQuantSpec(bits, x.shape[-3], x.shape[-2], x.shape[-1])
    codes, scales = kv_quantize_pages(jnp.asarray(x), bits)
    assert codes.shape == x.shape[:-3] + spec.codes_tail
    assert scales.shape == x.shape[:-3] + spec.scales_tail
    assert codes.dtype == (jnp.uint8 if bits == 4 else jnp.int8)
    assert scales.dtype == spec.scale_dtype
    out = kv_dequantize_pages(codes, scales, bits, x.dtype)
    assert out.shape == x.shape and out.dtype == x.dtype
    # determinism: a second call is bitwise identical
    c2, s2 = kv_quantize_pages(jnp.asarray(x), bits)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(s2))
    return np.asarray(out, np.float32)


@pytest.mark.parametrize("bits", [8, 4])
def test_roundtrip_shape_dtype_deterministic(rng, bits):
    x = rng.standard_normal((3, 8, 2, 16)).astype(np.float32)
    out = _check_roundtrip_static(x, bits)
    err = np.max(np.abs(out - x))
    assert err < (0.05 if bits == 8 else 1.5) * np.max(np.abs(x))


def test_scales_monotone_4bit(rng):
    """The 4-bit per-group codebook rows are sorted ascending (DP group
    means over sorted magnitudes) — the property the kernel's
    take_along_axis dequant relies on being stable."""
    x = rng.standard_normal((5, 8, 2, 16)).astype(np.float32)
    _, scales = kv_quantize_pages(jnp.asarray(x), 4)
    s = np.asarray(scales, np.float32)
    assert np.all(np.diff(s, axis=-1) >= 0)
    assert np.all(s >= 0)


def test_exact_on_small_alphabet_4bit(rng):
    """Pages whose per-group magnitudes take <= 8 distinct bf16 values
    round-trip exactly: the DP solver puts each magnitude in its own
    group, the group mean is the magnitude itself, and the sign rides the
    MSB."""
    alphabet = np.asarray([0.0, 0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0],
                          np.float32)              # bf16-exact values
    mags = alphabet[rng.integers(0, 8, (2, 8, 2, 16))]
    signs = np.where(rng.standard_normal((2, 8, 2, 16)) < 0, -1.0, 1.0)
    x = (mags * signs).astype(np.float32)
    codes, scales = kv_quantize_pages(jnp.asarray(x), 4)
    out = np.asarray(kv_dequantize_pages(codes, scales, 4, jnp.float32))
    np.testing.assert_array_equal(out, x)


def test_8bit_exact_at_absmax_and_zero(rng):
    """Sign-magnitude absmax codes are exact at 0 and +-absmax."""
    x = np.zeros((1, 8, 2, 16), np.float32)
    x[0, 0, 0, 0] = 3.5
    x[0, 3, 1, 5] = -3.5
    codes, scales = kv_quantize_pages(jnp.asarray(x), 8)
    out = np.asarray(kv_dequantize_pages(codes, scales, 8, jnp.float32))
    np.testing.assert_array_equal(out, x)


def test_page_bytes_accounting():
    """KVQuantSpec.page_bytes vs the native pool: the storage ratios the
    serve_bench capacity axis banks on."""
    native = kv_native_page_bytes(16, 2, 16, jnp.float32)
    s4 = KVQuantSpec(4, 16, 2, 16)
    s8 = KVQuantSpec(8, 16, 2, 16)
    assert native == 2048
    assert s4.page_bytes() < s8.page_bytes() < native
    assert native / s4.page_bytes() > 4          # >4x pages per byte
    assert native / s8.page_bytes() > 3


# -- hypothesis fuzzing (skips cleanly when hypothesis is not installed) ----

def test_property_roundtrip_fuzz():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.integers(0, 2 ** 32 - 1), st.sampled_from([4, 8]),
               st.sampled_from([(4, 2, 8), (8, 2, 16), (16, 1, 32)]))
    @hyp.settings(max_examples=25, deadline=None)
    def run(seed, bits, shape):
        x = (np.random.default_rng(seed)
             .standard_normal((2,) + shape).astype(np.float32))
        _check_roundtrip_static(x, bits)
        if bits == 4:
            _, scales = kv_quantize_pages(jnp.asarray(x), 4)
            assert np.all(np.diff(np.asarray(scales, np.float32),
                                  axis=-1) >= 0)

    run()


def test_property_small_alphabet_fuzz():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.integers(0, 2 ** 32 - 1), st.integers(1, 8))
    @hyp.settings(max_examples=25, deadline=None)
    def run(seed, n_mags):
        rng = np.random.default_rng(seed)
        # n_mags distinct bf16-exact magnitudes (powers of two stay exact)
        alphabet = np.float32(2.0) ** rng.choice(
            np.arange(-4, 4), size=n_mags, replace=False)
        mags = alphabet[rng.integers(0, n_mags, (1, 8, 2, 16))]
        signs = np.where(rng.standard_normal((1, 8, 2, 16)) < 0, -1, 1)
        x = (mags * signs).astype(np.float32)
        codes, scales = kv_quantize_pages(jnp.asarray(x), 4)
        out = np.asarray(kv_dequantize_pages(codes, scales, 4, jnp.float32))
        np.testing.assert_array_equal(out, x)

    run()


# ---------------------------------------------------------------------------
# fused-dequant kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
def test_quant_kernel_matches_oracle(rng, bits):
    """Pallas fused-dequant decode kernel (interpret mode) == the jnp
    gather+dequant oracle, over mixed partial/full sequences with hot-row
    overlays."""
    from repro.kernels.paged_attention import (
        paged_attention_decode_quant, paged_attention_decode_quant_ref)
    b, h, kv, d, ps, mp = 3, 4, 2, 16, 4, 4
    n_pages, n_hot = 1 + b * mp, b + 1
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kfull = rng.standard_normal((n_pages, ps, kv, d)).astype(np.float32)
    vfull = rng.standard_normal((n_pages, ps, kv, d)).astype(np.float32)
    k_codes, k_scales = kv_quantize_pages(jnp.asarray(kfull), bits)
    v_codes, v_scales = kv_quantize_pages(jnp.asarray(vfull), bits)
    k_hot = jnp.asarray(rng.standard_normal((n_hot, ps, kv, d)), jnp.float32)
    v_hot = jnp.asarray(rng.standard_normal((n_hot, ps, kv, d)), jnp.float32)
    bt = jnp.asarray(1 + np.arange(b * mp).reshape(b, mp), jnp.int32)
    lens = jnp.asarray([6, 8, 13], jnp.int32)     # partial, full, partial
    hot_rows = jnp.asarray([1, 2, 3], jnp.int32)
    args = (q, k_codes, k_scales, v_codes, v_scales, k_hot, v_hot, bt,
            lens, hot_rows)
    ref = paged_attention_decode_quant_ref(*args, kv_bits=bits)
    out = paged_attention_decode_quant(*args, kv_bits=bits, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# dual-pool allocator invariants
# ---------------------------------------------------------------------------

def _live_engine(setup, bits, n_steps=6):
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                           num_pages=64, max_seq=32, prefill_chunk=6,
                           kv_bits=bits, prefix_cache=False)
    for p, n in _requests(3):
        eng.submit(p, n)
    for _ in range(n_steps):
        eng.step()
    return eng


def test_frontier_tracks_commits(setup):
    eng = _live_engine(setup, 4)
    cache = eng.cache
    live = [s for s in range(cache.max_seqs) if s not in cache._free_slots]
    assert live
    for s in live:
        assert int(cache._quant_frontier[s]) == \
            int(cache.seq_lens[s]) // cache.page_size
    cache.check_invariants()


@pytest.mark.parametrize("delta", [-1, 1])
def test_frontier_corruption_detected(setup, delta):
    """A frontier behind the commit = a committed page left unquantized;
    ahead = pages marked quantized that were never committed. Both are
    audit failures."""
    eng = _live_engine(setup, 4)
    cache = eng.cache
    live = [s for s in range(cache.max_seqs)
            if s not in cache._free_slots
            and int(cache.seq_lens[s]) // cache.page_size + delta >= 0]
    assert live
    cache._quant_frontier[live[0]] += delta
    with pytest.raises(PageStateError, match="quant"):
        cache.check_invariants()


def test_free_slot_frontier_detected(setup):
    eng = _live_engine(setup, 4)
    cache = eng.cache
    assert cache._free_slots
    cache._quant_frontier[cache._free_slots[0]] = 1
    with pytest.raises(PageStateError, match="free slot"):
        cache.check_invariants()


def test_native_pools_skip_frontier_audit(setup):
    """kv_bits=16 pools carry no frontier semantics: the same corruption
    is a no-op for the audit (the field is engine bookkeeping only)."""
    eng = _live_engine(setup, 16)
    live = [s for s in range(eng.cache.max_seqs)
            if s not in eng.cache._free_slots]
    eng.cache._quant_frontier[live[0]] += 1
    eng.cache.check_invariants()                   # no raise


def test_prefix_digests_bit_exact_across_kv_bits(setup):
    """The registry hashes token chains, never pool bytes: the digest set
    a prompt registers is identical at 16/8/4 — what makes prefix matches
    (and supervisor replay) representation-agnostic."""
    model, params = setup
    prompt = np.random.default_rng(4).integers(0, 64, (13,)).astype(np.int32)
    digests = {}
    for bits in (16, 8, 4):
        eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                               num_pages=64, max_seq=32, prefill_chunk=6,
                               kv_bits=bits, prefix_cache=True)
        eng.submit(prompt, 6)
        eng.run()
        digests[bits] = set(eng.cache._registry.keys())
        assert digests[bits]
        eng.close()
    assert digests[16] == digests[8] == digests[4]


def test_chaos_preemption_quantized_pool_audits_clean(setup):
    """Tight pool at kv_bits=4: forced preemption + a fork + an abort, all
    requests still complete, and the full invariant suite (frontier
    included) comes back clean with an idle pool at the end."""
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=8, page_size=4,
                           num_pages=13, max_seq=32, prefill_chunk=4,
                           kv_bits=4, prefix_cache=False)
    rng = np.random.default_rng(2)
    # 8 concurrent sequences x 4 pages each (prompt 4 + 12 new tokens
    # writes 15 positions) against 12 usable pages: decode growth must
    # overlap and evict
    reqs = [(rng.integers(0, 64, (4,)).astype(np.int32), 12)
            for _ in range(8)]
    rids = [eng.submit(*r) for r in reqs]
    for _ in range(3):
        eng.step()
    eng.abort_request(rids[-1])
    outs = eng.run()
    assert eng.scheduler.n_preemptions > 0, "pool was not tight enough"
    assert set(rids[:-1]) <= set(outs)
    assert all(len(outs[r]) == 12 for r in rids[:-1])
    eng.cache.check_invariants(expect_idle=True)
    eng.close()
