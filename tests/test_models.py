"""Per-arch reduced-config smoke tests: forward/train/decode on CPU.

One test per assigned architecture (assignment deliverable f): instantiate
the reduced same-family config, run one forward + one gradient step + one
decode step, assert shapes and finiteness.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, smoke_config, SHAPES, \
    cell_is_skipped
from repro.models import Model


def make_batch(cfg, B=2, S=32, rng=None):
    rng = rng or np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)
    batch = {"labels": jnp.asarray(toks[:, 1:])}
    if cfg.frontend == "vision":
        p = cfg.n_frontend_tokens
        batch["tokens"] = jnp.asarray(toks[:, :S - p])
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, p, cfg.d_model)), cfg.dtype)
    elif cfg.is_encdec:
        batch["tokens"] = jnp.asarray(toks[:, :S])
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), cfg.dtype)
    else:
        batch["tokens"] = jnp.asarray(toks[:, :S])
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke(arch):
    """Forward + gradient + decode for every assigned architecture."""
    cfg = smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch

    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch

    B, S = 2, 32
    cache = model.init_cache(B, S)
    logits, cache2 = jax.jit(model.decode_step)(
        params, cache, jnp.zeros((B, 1), jnp.int32),
        jnp.full((B,), S - 1, jnp.int32))
    assert logits.shape == (B, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits[:, :cfg.vocab_size]))), arch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_prefill_decode_consistency(arch):
    """Greedy next-token from prefill == teacher-forced forward argmax."""
    cfg = smoke_config(arch)
    if cfg.frontend == "vision" or cfg.is_encdec:
        batch_extra = True
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, B=2, S=32)
    batch.pop("labels")
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (2, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits[:, :cfg.vocab_size])))


def test_full_configs_match_assignment():
    """The registered full configs carry the exact assigned dimensions."""
    spec = {
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch


def test_param_counts_sane():
    """Analytic N in the right ballpark for named model sizes."""
    approx = {"gemma2-2b": (2e9, 4e9), "qwen1.5-0.5b": (0.3e9, 0.8e9),
              "internlm2-1.8b": (1.5e9, 2.5e9), "arctic-480b": (4e11, 5.5e11),
              "jamba-v0.1-52b": (4e10, 6e10), "internvl2-76b": (6e10, 9e10),
              "xlstm-125m": (0.6e8, 2.5e8)}
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, (arch, n)


def test_cell_grid_and_skips():
    cells = [(a, s) for a in list_archs() for s in SHAPES]
    assert len(cells) == 40
    skips = [c for c in cells if cell_is_skipped(*c)]
    assert len(skips) == 7   # long_500k for the 7 pure full-attention archs
    for arch in ("gemma2-2b", "jamba-v0.1-52b", "xlstm-125m"):
        assert cell_is_skipped(arch, "long_500k") is None


def test_gemma2_window_pattern():
    from repro.models.transformer import _window_array
    cfg = get_config("gemma2-2b")
    wins = np.asarray(_window_array(cfg)).reshape(-1)
    assert wins.shape[0] == 26
    assert (wins[::2] == 4096).all() and (wins[1::2] == 0).all()


def test_jamba_layer_plan():
    from repro.models.transformer import layer_plan
    cfg = get_config("jamba-v0.1-52b")
    plan = layer_plan(cfg)
    assert len(plan) == 8
    assert plan[4][0] == "attn"
    assert sum(m == "mamba" for m, _ in plan) == 7
    assert sum(f == "moe" for _, f in plan) == 4
