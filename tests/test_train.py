"""Training loop: learning works, optimizer variants, fault tolerance."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.data import MarkovStream
from repro.models import Model
from repro.train import (AdamW, Checkpointer, OptConfig, PreemptionHandler,
                         StragglerMonitor, init_state, make_train_step,
                         train_loop)


@pytest.fixture(scope="module")
def tiny():
    import dataclasses
    cfg = smoke_config("qwen1.5-0.5b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64, d_model=64,
                              n_layers=2)
    return Model(cfg)


def _stream(model, batch=8, seq=32):
    return MarkovStream(model.cfg.vocab_size, seq, batch, seed=3)


def test_loss_decreases(tiny):
    opt = AdamW(OptConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    data = iter(_stream(tiny))
    state, metrics = train_loop(tiny, opt, data, steps=40,
                                rng=jax.random.PRNGKey(0), log_every=0,
                                log_fn=lambda *_: None)
    first = float(jax.jit(tiny.loss)(state["params"],
                                     next(iter(_stream(tiny))))[0])
    stream = _stream(tiny)
    uniform = np.log(tiny.cfg.vocab_size)
    assert first < 0.9 * uniform, (first, uniform)
    assert first > stream.entropy() - 0.1   # can't beat the chain's entropy


def test_int8_moments_track_f32(tiny, rng):
    data = _stream(tiny)
    batches = [data.batch(i) for i in range(8)]
    results = {}
    for mdtype in ("f32", "int8"):
        opt = AdamW(OptConfig(lr=1e-3, moment_dtype=mdtype,
                              min_quant_size=128, warmup_steps=1))
        state = init_state(tiny, opt, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(tiny, opt))
        for b in batches:
            state, m = step(state, b)
        results[mdtype] = float(m["loss"])
    assert results["int8"] == pytest.approx(results["f32"], rel=0.05)


def test_grad_clip():
    from repro.train import clip_by_global_norm
    tree = {"a": jnp.ones((4,)) * 100.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(200.0)
    total = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert total == pytest.approx(1.0, rel=1e-4)


def test_checkpoint_resume_identical(tiny, tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    opt = AdamW(OptConfig(lr=1e-3, warmup_steps=1))
    data = _stream(tiny)

    def run(steps, ckpt=None, state=None, start=0):
        step = jax.jit(make_train_step(tiny, opt))
        if state is None:
            state = init_state(tiny, opt, jax.random.PRNGKey(0))
        for i in range(start, steps):
            state, m = step(state, data.batch(i))
        return state, m

    state_a, _ = run(6)

    state_b, _ = run(3)
    ck = Checkpointer(tmp_path / "ck", async_save=False)
    ck.save(state_b, 3)
    restored, step_n = ck.restore_latest(like=jax.tree_util.tree_map(
        np.asarray, state_b))
    assert step_n == 3
    state_c, _ = run(6, state=jax.tree_util.tree_map(jnp.asarray, restored),
                     start=3)

    la = jax.tree_util.tree_leaves(state_a["params"])
    lc = jax.tree_util.tree_leaves(state_c["params"])
    for a, c in zip(la, lc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=1e-6, rtol=1e-5)


def test_preemption_checkpoints_and_resumes(tiny, tmp_path):
    opt = AdamW(OptConfig(lr=1e-3, warmup_steps=1))
    ck = Checkpointer(tmp_path / "ck", async_save=False)
    handler = PreemptionHandler(signals=())
    calls = {"n": 0}

    def should_stop():
        calls["n"] += 1
        return calls["n"] >= 4          # preempt mid-run

    state, _ = train_loop(tiny, opt, iter(_stream(tiny)), steps=50,
                          rng=jax.random.PRNGKey(0), checkpointer=ck,
                          checkpoint_every=100, should_stop=should_stop,
                          log_every=0, log_fn=lambda *_: None)
    saved = ck.steps()
    assert saved, "preemption must leave a checkpoint"

    # auto-resume picks up from the preemption checkpoint
    state2, _ = train_loop(tiny, opt, iter(_stream(tiny)), steps=saved[-1] + 2,
                           rng=jax.random.PRNGKey(0), checkpointer=ck,
                           log_every=0, log_fn=lambda *_: None)
    assert int(state2["opt"]["step"]) >= saved[-1]


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0)
    for step in range(20):
        mon.record(step, 0.1)
    mon.record(20, 0.5)
    assert mon.flagged and mon.flagged[-1][0] == 20
