"""QTensor pytree behavior + int4 pack/unpack round-trip (no hypothesis)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.quantize import (QTensor, pack_codes_int4, quantize_blockwise,
                                 unpack_codes_int4)


def _random_codes(rng, n):
    """Valid MSB codes: sign * (level + 1), level in [0, 8), plus exact 0."""
    lv = rng.integers(0, 8, n)
    sign = rng.choice([-1, 1], n)
    codes = (sign * (lv + 1)).astype(np.int8)
    codes[rng.random(n) < 0.1] = 0
    return codes


def test_pack_unpack_round_trip_nonzero(rng):
    codes = _random_codes(rng, 512)
    codes[codes == 0] = 1                       # zero-free: exact round trip
    packed = pack_codes_int4(jnp.asarray(codes))
    assert packed.dtype == jnp.uint8 and packed.shape == (256,)
    out = np.asarray(unpack_codes_int4(packed, codes.shape))
    np.testing.assert_array_equal(out, codes)


def test_pack_unpack_negative_codes(rng):
    codes = -np.arange(1, 9, dtype=np.int8).repeat(2)   # all 8 negative codes
    out = np.asarray(unpack_codes_int4(
        pack_codes_int4(jnp.asarray(codes)), codes.shape))
    np.testing.assert_array_equal(out, codes)


def test_pack_zero_densifies_to_code_one(rng):
    """Exact zeros (code 0) pack as level-0/sign+ and unpack as +1: the
    packed path trades the zero special-case for density (DESIGN.md §7)."""
    codes = _random_codes(rng, 256)
    out = np.asarray(unpack_codes_int4(
        pack_codes_int4(jnp.asarray(codes)), codes.shape))
    nz = codes != 0
    np.testing.assert_array_equal(out[nz], codes[nz])
    np.testing.assert_array_equal(out[~nz], np.ones((~nz).sum(), np.int8))


def test_pack_rejects_odd_length():
    with pytest.raises(ValueError):
        pack_codes_int4(jnp.ones((3,), jnp.int8))


def test_pack_unpack_2d_shape(rng):
    codes = _random_codes(rng, 128).reshape(8, 16)
    codes[codes == 0] = 2
    out = np.asarray(unpack_codes_int4(
        pack_codes_int4(jnp.asarray(codes)), codes.shape))
    np.testing.assert_array_equal(out, codes)


# ---------------------------------------------------------------------------
# QTensor as a pytree
# ---------------------------------------------------------------------------

def _make_qtensor(rng):
    w = jnp.asarray(rng.standard_normal((8, 128)), jnp.float32)
    return w, quantize_blockwise(w, bits=4, block=64, solver="kmeans")


def test_qtensor_flatten_unflatten_identity(rng):
    _, q = _make_qtensor(rng)
    leaves, treedef = jax.tree_util.tree_flatten(q)
    assert len(leaves) == 2                      # codes, scales
    q2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(q2, QTensor)
    assert (q2.bits, q2.block, q2.dtype) == (q.bits, q.block, q.dtype)
    np.testing.assert_array_equal(np.asarray(q2.codes), np.asarray(q.codes))
    np.testing.assert_array_equal(np.asarray(q2.scales), np.asarray(q.scales))


def test_qtensor_through_jit(rng):
    """QTensor crosses the jit boundary as a pytree argument AND return
    value; dequantize inside jit matches eager."""
    _, q = _make_qtensor(rng)

    @jax.jit
    def f(qt):
        return qt, qt.dequantize()

    q2, deq = f(q)
    assert isinstance(q2, QTensor) and q2.bits == q.bits
    np.testing.assert_allclose(np.asarray(deq), np.asarray(q.dequantize()),
                               rtol=1e-6)
    # static aux data means retracing only on bits/block/dtype change
    assert f._cache_size() == 1
    f(q2)
    assert f._cache_size() == 1


def test_qtensor_tree_map_touches_leaves(rng):
    _, q = _make_qtensor(rng)
    doubled = jax.tree_util.tree_map(lambda x: x * 2, q)
    np.testing.assert_array_equal(np.asarray(doubled.codes),
                                  2 * np.asarray(q.codes))
