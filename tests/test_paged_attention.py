"""Paged attention (gather-from-block-tables) vs dense attention + kernel."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (decode_attention, flash_attention,
                                    paged_attention, paged_write)
from repro.kernels.paged_attention import (paged_attention_decode,
                                           paged_attention_decode_ref)


def _paged_layout(rng, b, s, kv, d, page_size, n_extra_pages=3):
    """Random K/V laid out into a shuffled page pool + matching block table."""
    k = rng.standard_normal((b, s, kv, d)).astype(np.float32)
    v = rng.standard_normal((b, s, kv, d)).astype(np.float32)
    mp = -(-s // page_size)
    n_pages = 1 + b * mp + n_extra_pages          # + reserved page 0
    perm = rng.permutation(np.arange(1, n_pages))  # never page 0
    k_pool = rng.standard_normal((n_pages, page_size, kv, d)).astype(np.float32)
    v_pool = rng.standard_normal((n_pages, page_size, kv, d)).astype(np.float32)
    bt = np.zeros((b, mp), np.int32)
    for i in range(b):
        for j in range(mp):
            page = int(perm[i * mp + j])
            bt[i, j] = page
            lo, hi = j * page_size, min((j + 1) * page_size, s)
            k_pool[page, : hi - lo] = k[i, lo:hi]
            v_pool[page, : hi - lo] = v[i, lo:hi]
    return (jnp.asarray(k), jnp.asarray(v), jnp.asarray(k_pool),
            jnp.asarray(v_pool), jnp.asarray(bt))


def test_paged_matches_dense_prefill(rng):
    """Full-sequence paged attention == causal flash attention <= 1e-5."""
    b, s, h, kv, d, ps = 2, 24, 4, 2, 16, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k, v, k_pool, v_pool, bt = _paged_layout(rng, b, s, kv, d, ps)
    ref = flash_attention(q, k, v, causal=True, chunk_q=8, chunk_kv=8)
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    out = paged_attention(q, k_pool, v_pool, bt, q_pos,
                          jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_paged_matches_dense_decode(rng):
    """Single-token paged attention == ring-buffer decode_attention."""
    b, s, h, kv, d, ps = 3, 20, 4, 2, 16, 4
    q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
    k, v, k_pool, v_pool, bt = _paged_layout(rng, b, s, kv, d, ps)
    lens = jnp.asarray([s, s - 3, s - 7], jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    ref = decode_attention(q, k, v, positions, lens - 1)
    out = paged_attention(q, k_pool, v_pool, bt, (lens - 1)[:, None], lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_paged_window_softcap(rng):
    b, s, h, kv, d, ps = 2, 16, 4, 2, 16, 4
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k, v, k_pool, v_pool, bt = _paged_layout(rng, b, s, kv, d, ps)
    ref = flash_attention(q, k, v, causal=True, window=5, softcap=30.0,
                          chunk_q=8, chunk_kv=8)
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    out = paged_attention(q, k_pool, v_pool, bt, q_pos,
                          jnp.full((b,), s, jnp.int32), window=5, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_chunked_prefill_equals_full(rng):
    """Prefilling in chunks through paged attention == one-shot prefill."""
    b, s, h, kv, d, ps, chunk = 2, 24, 4, 2, 16, 4, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    knew = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    vnew = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    ref = flash_attention(q, knew, vnew, causal=True, chunk_q=8, chunk_kv=8)
    mp = s // ps
    n_pages = 1 + b * mp
    bt = jnp.asarray(
        np.arange(1, n_pages).reshape(b, mp), jnp.int32)
    k_pool = jnp.zeros((n_pages, ps, kv, d), jnp.float32)
    v_pool = jnp.zeros((n_pages, ps, kv, d), jnp.float32)
    outs = []
    for c0 in range(0, s, chunk):
        q_pos = jnp.broadcast_to(
            jnp.arange(c0, c0 + chunk, dtype=jnp.int32)[None], (b, chunk))
        k_pool, v_pool = paged_write(k_pool, v_pool,
                                     knew[:, c0:c0 + chunk],
                                     vnew[:, c0:c0 + chunk], bt, q_pos)
        outs.append(paged_attention(q[:, c0:c0 + chunk], k_pool, v_pool, bt,
                                    q_pos, jnp.full((b,), c0 + chunk,
                                                    jnp.int32)))
    out = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_paged_write_pads_to_scratch_page(rng):
    ps, kv, d = 4, 2, 8
    k_pool = jnp.zeros((4, ps, kv, d), jnp.float32)
    v_pool = jnp.zeros((4, ps, kv, d), jnp.float32)
    bt = jnp.asarray([[1, 2]], jnp.int32)
    k_new = jnp.ones((1, 3, kv, d), jnp.float32)
    q_pos = jnp.asarray([[4, -1, -1]], jnp.int32)   # one real, two pads
    k2, v2 = paged_write(k_pool, v_pool, k_new, k_new, bt, q_pos)
    assert float(k2[2, 0].sum()) == kv * d           # real write: page 2 slot 0
    assert float(k2[1].sum()) == 0                   # page 1 untouched
    assert float(k2[3].sum()) == 0                   # unrelated page untouched
    assert float(k2[0, 1:].sum()) == 0               # pads land in page 0


def test_kernel_interpret_matches_ref(rng):
    b, h, kv, d, ps, n_pages, mp = 3, 4, 2, 16, 4, 13, 4
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((n_pages, ps, kv, d)),
                         jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((n_pages, ps, kv, d)),
                         jnp.float32)
    bt = jnp.asarray(rng.permutation(np.arange(1, n_pages))[: b * mp]
                     .reshape(b, mp), jnp.int32)
    lens = jnp.asarray([16, 9, 3], jnp.int32)
    out = paged_attention_decode(q, k_pool, v_pool, bt, lens, interpret=True)
    ref = paged_attention_decode_ref(q, k_pool, v_pool, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_kernel_softcap_and_scale(rng):
    b, h, kv, d, ps, n_pages, mp = 2, 4, 4, 8, 4, 9, 2
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((n_pages, ps, kv, d)),
                         jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((n_pages, ps, kv, d)),
                         jnp.float32)
    bt = jnp.asarray(np.arange(1, 1 + b * mp).reshape(b, mp), jnp.int32)
    lens = jnp.asarray([7, 8], jnp.int32)
    out = paged_attention_decode(q, k_pool, v_pool, bt, lens, softcap=20.0,
                                 scale=0.25, interpret=True)
    ref = paged_attention_decode_ref(q, k_pool, v_pool, bt, lens,
                                     softcap=20.0, scale=0.25)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
