"""End-to-end tests for the HTTP serving front door.

The acceptance bar: concurrent streaming clients over real sockets get
greedy output token-identical to direct engine use (across execution modes
and decode horizons), and every failure path — validation, saturation,
client disconnect, server-side timeout — leaves the page pool at baseline.
"""
import dataclasses
import http.client
import json
import socket
import time
from concurrent.futures import ThreadPoolExecutor
from contextlib import contextmanager

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import QuantPolicy, quantize_params
from repro.models import Model
from repro.serve import APIServer, ContinuousEngine


@pytest.fixture(scope="module")
def qsetup():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    qparams, report = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="dp", min_size=1024))
    assert report
    return model, qparams


@contextmanager
def _server(model, params, server_kw=None, **eng_kw):
    eng_kw.setdefault("max_batch", 4)
    eng_kw.setdefault("page_size", 4)
    eng_kw.setdefault("num_pages", 64)
    eng_kw.setdefault("prefill_chunk", 8)
    srv = APIServer(ContinuousEngine(model, params, **eng_kw),
                    **(server_kw or {}))
    host, port = srv.serve_background()
    try:
        yield srv, host, port
    finally:
        srv.close()


def _request(host, port, method, path, payload=None, timeout=120):
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        body = None if payload is None else json.dumps(payload).encode()
        conn.request(method, path, body=body,
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        return resp.status, dict(resp.getheaders()), resp.read()
    finally:
        conn.close()


def _post(host, port, payload, **kw):
    return _request(host, port, "POST", "/v1/completions", payload, **kw)


def _parse_sse(body: bytes):
    """Assert well-formed SSE framing; return the JSON payloads (the final
    [DONE] sentinel is checked and stripped)."""
    frames = [f for f in body.decode().split("\n\n") if f]
    for f in frames:
        assert f.startswith("data: "), f"bad SSE frame: {f!r}"
    assert frames[-1] == "data: [DONE]"
    return [json.loads(f[len("data: "):]) for f in frames[:-1]]


def _stream_tokens(status, headers, body):
    assert status == 200
    assert headers["Content-Type"].startswith("text/event-stream")
    frames = _parse_sse(body)
    assert frames[-1]["choices"][0]["finish_reason"] is not None
    for f in frames[:-1]:
        assert f["choices"][0]["finish_reason"] is None
    toks = [t for f in frames for t in f["choices"][0]["token_ids"]]
    text = "".join(f["choices"][0]["text"] for f in frames)
    return toks, text, frames[-1]["choices"][0]["finish_reason"]


def _recv_until(sock, marker, buf=b""):
    while marker not in buf:
        chunk = sock.recv(4096)
        assert chunk, "connection closed before expected data"
        buf += chunk
    return buf


def _open_stream(host, port, payload):
    """Raw-socket streaming POST; returns (socket, bytes) once the first
    token-bearing SSE frame has arrived — i.e. the request is provably
    running server-side."""
    s = socket.create_connection((host, port), timeout=120)
    body = json.dumps(payload).encode()
    s.sendall((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
               f"Content-Type: application/json\r\n"
               f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    buf = _recv_until(s, b"\r\n\r\n")
    assert buf.startswith(b"HTTP/1.1 200"), buf
    rest = buf.split(b"\r\n\r\n", 1)[1]
    return s, _recv_until(s, b"\n\n", rest)


def _poll(predicate, timeout=15.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(0.02)
    return False


def _pool_at_baseline(cache):
    return (cache.n_free_pages + cache.n_cached_pages == cache.num_pages - 1
            and (cache.ref_counts[1:] == 0).all()
            and cache.n_free_slots == cache.max_seqs)


# -- token identity across execution modes and horizons ----------------------

@pytest.mark.parametrize("execution,horizon", [
    ("simulated", 1), ("packed", 1), ("simulated", 8), ("packed", 8)])
def test_concurrent_clients_token_identical_to_direct_engine(
        qsetup, execution, horizon):
    model, qparams = qsetup
    r = np.random.default_rng(7)
    reqs = [(r.integers(0, 64, (int(n),)).astype(np.int32), int(m))
            for n, m in ((5, 7), (9, 6), (7, 9))]
    eng_kw = dict(execution=execution, decode_horizon=horizon)

    direct = ContinuousEngine(model, qparams, max_batch=4, page_size=4,
                              num_pages=64, prefill_chunk=8, **eng_kw)
    rids = [direct.submit(p, m) for p, m in reqs]
    outs = direct.run()
    refs = [outs[rid].tolist() for rid in rids]

    with _server(model, qparams, **eng_kw) as (srv, host, port):
        def client(i):
            p, m = reqs[i]
            body = {"prompt": p.tolist(), "max_tokens": m,
                    "stream": i > 0}         # client 0 non-stream, rest SSE
            return _post(host, port, body)
        with ThreadPoolExecutor(3) as pool:
            results = list(pool.map(client, range(3)))

        status, _, body = results[0]
        assert status == 200
        resp = json.loads(body)
        choice = resp["choices"][0]
        assert choice["token_ids"] == refs[0]
        assert choice["finish_reason"] == "length"
        assert choice["text"] == "".join(f" {t}" for t in refs[0])
        assert resp["usage"] == {
            "prompt_tokens": len(reqs[0][0]),
            "completion_tokens": len(refs[0]),
            "total_tokens": len(reqs[0][0]) + len(refs[0])}

        for i in (1, 2):
            toks, text, reason = _stream_tokens(*results[i])
            assert toks == refs[i], f"stream client {i} diverged"
            assert text == "".join(f" {t}" for t in refs[i])
            assert reason == "length"

        assert _pool_at_baseline(srv.engine_loop.engine.cache)


# -- request validation and routing ------------------------------------------

def test_routes_validation_and_metrics(qsetup):
    model, qparams = qsetup
    with _server(model, qparams) as (srv, host, port):
        status, _, body = _request(host, port, "GET", "/healthz")
        assert status == 200 and json.loads(body)["status"] == "ok"

        status, _, body = _request(host, port, "GET", "/v1/models")
        assert status == 200
        assert json.loads(body)["data"][0]["id"] == model.cfg.name

        # typed 400s name the offending param
        for payload, param in [({"prompt": [1], "temperature": 0.9},
                                "temperature"),
                               ({"prompt": [99]}, "prompt"),
                               ({"prompt": [1], "max_tokens": 0},
                                "max_tokens")]:
            status, _, body = _post(host, port, payload)
            err = json.loads(body)["error"]
            assert status == 400, err
            assert err["type"] == "invalid_request_error"
            assert err["param"] == param
        status, _, body = _post(host, port, {"prompt": [1],
                                             "model": "gpt-4"})
        assert status == 404
        assert json.loads(body)["error"]["param"] == "model"

        conn = http.client.HTTPConnection(host, port, timeout=30)
        conn.request("POST", "/v1/completions", body=b"{not json",
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        assert resp.status == 400
        assert json.loads(resp.read())["error"]["type"] == \
            "invalid_request_error"
        conn.close()

        assert _request(host, port, "GET", "/v1/completions")[0] == 405
        assert _request(host, port, "DELETE", "/healthz")[0] == 405
        assert _request(host, port, "GET", "/no/such/route")[0] == 404

        # one real completion so the scrape has request-path series
        status, _, body = _post(host, port, {"prompt": [1, 2, 3],
                                             "max_tokens": 3})
        assert status == 200

        status, headers, body = _request(host, port, "GET", "/metrics")
        assert status == 200
        assert headers["Content-Type"] == \
            "text/plain; version=0.0.4; charset=utf-8"
        text = body.decode()
        for family in ("msb_ttft_seconds_bucket", "msb_ttft_seconds_count",
                       "msb_inter_token_seconds_bucket", "msb_queue_depth",
                       "msb_running_requests", "msb_prefix_hit_rate",
                       "msb_tokens_generated_total"):
            assert family in text, f"{family} missing from scrape"
        assert 'msb_requests_total{outcome="length"} 1' in text
        assert 'msb_requests_total{outcome="rejected"} 5' in text
        assert srv.metrics.ttft.count() == 1
        assert srv.metrics.itl.count() >= 1


# -- backpressure -------------------------------------------------------------

def test_saturated_engine_returns_429_with_retry_after(qsetup):
    """max_batch=1 + max_waiting=0: while one request runs, the next gets a
    deterministic 429; once drained, submissions flow again."""
    model, qparams = qsetup
    with _server(model, qparams, max_batch=1, max_waiting=0,
                 num_pages=128) as (srv, host, port):
        s, buf = _open_stream(host, port, {"prompt": [3, 1, 4, 1, 5],
                                           "max_tokens": 256,
                                           "stream": True})
        try:
            status, headers, body = _post(host, port,
                                          {"prompt": [2], "max_tokens": 2})
            assert status == 429, body
            # Retry-After is load-derived (serve/overload.py): whole
            # seconds in [1, MAX_RETRY_AFTER_S]
            assert 1 <= int(headers["Retry-After"]) <= 30
            assert json.loads(body)["error"]["type"] == "overloaded_error"
            buf = _recv_until(s, b"data: [DONE]\n\n", buf)
        finally:
            s.close()
        toks, _, reason = _stream_tokens(
            200, {"Content-Type": "text/event-stream"}, buf)
        assert len(toks) == 256 and reason == "length"
        assert srv.metrics.requests.value(outcome="saturated") == 1

        status, _, body = _post(host, port, {"prompt": [2],
                                             "max_tokens": 2})
        assert status == 200
        assert _pool_at_baseline(srv.engine_loop.engine.cache)


# -- cancellation paths --------------------------------------------------------

def test_mid_stream_disconnect_aborts_and_frees_pages(qsetup):
    model, qparams = qsetup
    with _server(model, qparams, num_pages=128) as (srv, host, port):
        s, _ = _open_stream(host, port, {"prompt": [1, 2, 3, 4, 5, 6],
                                         "max_tokens": 200, "stream": True})
        s.close()                          # client walks away mid-stream
        eng = srv.engine_loop.engine
        assert _poll(lambda: eng.n_aborts == 1), \
            "disconnect never reached abort_request"
        assert _poll(lambda: _pool_at_baseline(eng.cache)), \
            "abort leaked pages or slots"
        assert _poll(lambda: srv.metrics.requests.value(
            outcome="cancelled") == 1)
        # the engine is still healthy: a fresh request completes
        status, _, body = _post(host, port, {"prompt": [7, 8],
                                             "max_tokens": 3})
        assert status == 200
        assert len(json.loads(body)["choices"][0]["token_ids"]) == 3
        assert _pool_at_baseline(eng.cache)


def test_server_side_timeout_finishes_with_timeout_reason(qsetup):
    model, qparams = qsetup
    with _server(model, qparams, num_pages=128) as (srv, host, port):
        status, _, body = _post(host, port, {"prompt": [1, 2, 3],
                                             "max_tokens": 500,
                                             "timeout": 0.2})
        assert status == 200
        choice = json.loads(body)["choices"][0]
        assert choice["finish_reason"] == "timeout"
        assert len(choice["token_ids"]) < 500    # cut off mid-generation
        eng = srv.engine_loop.engine
        assert eng.n_aborts == 1
        assert _poll(lambda: _pool_at_baseline(eng.cache))
        assert srv.metrics.requests.value(outcome="timeout") == 1


def test_healthz_reports_dead_engine_loop(qsetup):
    model, qparams = qsetup
    with _server(model, qparams) as (srv, host, port):
        srv.engine_loop.stop()
        assert _poll(lambda: not srv.engine_loop.alive)
        status, _, body = _request(host, port, "GET", "/healthz")
        assert status == 503
        assert json.loads(body)["status"] != "ok"
