"""Supervision layer tests: deterministic fault injection, crash recovery
with token-identical replay, watchdog hang containment, poison quarantine,
the abort-during-recovery race, drain, and metrics monotonicity across
engine rebuilds (DESIGN.md Sec. 14).

The acceptance bar: a mid-stream injected engine crash recovers with
output token-identical to the fault-free run, across execution modes and
decode horizons, with zero leaked pages.
"""
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import QuantPolicy, quantize_params
from repro.models import Model
from repro.serve import (ContinuousEngine, Draining, EngineSupervisor,
                         FaultEvent, FaultPlan, EngineDied, InjectedFault,
                         InjectedOOM, NO_FAULTS, PoisonedRequest,
                         ServeMetrics)
from repro.serve.supervisor import Recovering, WatchdogTimeout

PROMPTS = [list(range(1, 9)), [3, 5, 7, 2], [10, 11, 12, 13, 14, 15]]


@pytest.fixture(scope="module")
def qsetup():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    qparams, report = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="dp", min_size=1024))
    assert report
    return model, qparams


def _factory(model, params, faults=None, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("page_size", 4)
    kw.setdefault("num_pages", 64)
    kw.setdefault("prefill_chunk", 8)
    return lambda: ContinuousEngine(model, params, faults=faults, **kw)


def _reference(model, params, prompts, max_new=10, **kw):
    """Fault-free greedy outputs, one list per prompt."""
    eng = _factory(model, params, **kw)()
    rids = [eng.submit(np.asarray(p), max_new) for p in prompts]
    out = eng.run()
    eng.close()
    return [out[r].tolist() for r in rids]


# -- FaultPlan -------------------------------------------------------------
def test_fault_plan_fires_at_scheduled_indices():
    plan = FaultPlan([FaultEvent("step", 2, "crash"),
                      FaultEvent("alloc", 0, "oom")])
    plan.fire("step")
    plan.fire("step")
    with pytest.raises(InjectedFault):
        plan.fire("step")
    with pytest.raises(InjectedOOM):
        plan.fire("alloc")
    assert plan.exhausted
    assert plan.fired == [("step", 2, "crash"), ("alloc", 0, "oom")]
    plan.fire("step")                          # past the schedule: no-op


def test_fault_plan_stall_sleeps_not_raises():
    plan = FaultPlan([FaultEvent("step", 0, "stall", stall_s=0.05)])
    t0 = time.monotonic()
    plan.fire("step")                          # returns, late
    assert time.monotonic() - t0 >= 0.04
    assert plan.fired == [("step", 0, "stall")]


def test_fault_plan_seeded_deterministic():
    a = FaultPlan.seeded(1234, n_faults=20)
    b = FaultPlan.seeded(1234, n_faults=20)
    assert a._events.keys() == b._events.keys()
    for site in a._events:
        assert {at: (e.kind, e.stall_s) for at, e in a._events[site].items()} \
            == {at: (e.kind, e.stall_s) for at, e in b._events[site].items()}
    c = FaultPlan.seeded(5678, n_faults=20)
    assert any(a._events.get(s, {}).keys() != c._events.get(s, {}).keys()
               for s in ("step", "apply", "alloc"))
    assert a.n_events == 20
    # alloc events are always oom; oom never lands elsewhere
    for site, evs in a._events.items():
        for e in evs.values():
            assert (e.kind == "oom") == (site == "alloc")


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultEvent("nonsense", 0)
    with pytest.raises(ValueError):
        FaultEvent("step", 0, "explode")
    with pytest.raises(ValueError):
        FaultEvent("step", -1)
    with pytest.raises(ValueError):            # duplicate (site, at)
        FaultPlan([FaultEvent("step", 3), FaultEvent("step", 3)])
    assert NO_FAULTS.armed is False
    NO_FAULTS.fire("step")                     # no-op, no counters


# -- the acceptance criterion ---------------------------------------------
@pytest.mark.parametrize("execution", ["simulated", "packed"])
@pytest.mark.parametrize("horizon", [1, 8])
def test_crash_recovery_token_identical(qsetup, execution, horizon):
    """Mid-stream engine crash -> rebuild + replay -> byte-identical greedy
    output, across execution modes and decode horizons; zero leaked pages
    at teardown."""
    model, params = qsetup
    kw = dict(execution=execution, decode_horizon=horizon)
    ref = _reference(model, params, PROMPTS, **kw)
    # crash twice: once on the first decode dispatch, once early in the
    # rebuilt incarnation (packed prefill makes the whole horizon=8 run
    # ~3 dispatches, so the triggers sit low enough to fire in every
    # parametrization)
    plan = FaultPlan([FaultEvent("apply", 1, "crash"),
                      FaultEvent("step", 3, "crash")])
    sup = EngineSupervisor(_factory(model, params, faults=plan, **kw),
                           watchdog=False)
    rids = [sup.submit(np.asarray(p), 10) for p in PROMPTS]
    out = sup.run()
    assert plan.exhausted, "both faults must actually fire"
    assert sup.n_restarts == 2
    assert sup.n_replayed_tokens > 0
    for rid, expect in zip(rids, ref):
        assert out[rid].tolist() == expect
    sup.engine.cache.check_invariants(expect_idle=True)
    sup.close()


def test_recovery_replay_resumes_mid_stream(qsetup):
    """The replay set is prompt + tokens-so-far: tokens generated before
    the crash are never re-delivered, and the continuation matches."""
    model, params = qsetup
    ref = _reference(model, params, PROMPTS)
    plan = FaultPlan([FaultEvent("apply", 6, "crash")])
    sup = EngineSupervisor(_factory(model, params, faults=plan),
                           watchdog=False)
    rids = [sup.submit(np.asarray(p), 10) for p in PROMPTS]
    streamed = {r: [] for r in rids}
    while sup.has_work:
        if not sup.step():
            break
        for r, (new, done) in sup.stream_updates().items():
            streamed[r].extend(new)
    assert sup.n_restarts == 1
    for rid, expect in zip(rids, ref):
        assert streamed[rid] == expect         # exactly once, in order
    sup.close()


def test_injected_oom_degrades_without_restart(qsetup):
    """InjectedOOM is an OutOfPages: the scheduler preempts and retries —
    the supervisor must see no crash at all."""
    model, params = qsetup
    ref = _reference(model, params, PROMPTS)
    plan = FaultPlan([FaultEvent("alloc", 3, "oom"),
                      FaultEvent("alloc", 6, "oom")])
    sup = EngineSupervisor(_factory(model, params, faults=plan),
                           watchdog=False)
    rids = [sup.submit(np.asarray(p), 10) for p in PROMPTS]
    out = sup.run()
    assert plan.exhausted
    assert sup.n_restarts == 0                 # graceful degradation
    assert sup.engine.scheduler.n_preemptions > 0
    for rid, expect in zip(rids, ref):
        assert out[rid].tolist() == expect
    sup.engine.cache.check_invariants(expect_idle=True)
    sup.close()


def test_watchdog_trips_on_stall_and_recovers(qsetup):
    """A hung step (injected stall) blows the rolling-median deadline; the
    worker is abandoned and recovery proceeds exactly as for a crash."""
    model, params = qsetup
    ref = _reference(model, params, PROMPTS)
    plan = FaultPlan([FaultEvent("step", 5, "stall", stall_s=3.0)])
    sup = EngineSupervisor(
        _factory(model, params, faults=plan),
        watchdog=True, watchdog_floor_s=0.3, warmup_steps=2,
        warmup_deadline_s=120.0)
    rids = [sup.submit(np.asarray(p), 10) for p in PROMPTS]
    out = sup.run()
    assert sup.n_watchdog_trips == 1
    assert sup.n_restarts == 1
    assert isinstance(sup.last_crash, WatchdogTimeout)
    for rid, expect in zip(rids, ref):
        assert out[rid].tolist() == expect
    sup.close(check=False)    # abandoned worker may still hold the old pool


def test_poison_request_quarantined_cohort_survives(qsetup):
    """A request blamed for max_crashes_per_request crashes fails with
    PoisonedRequest naming the cause; the other requests complete with
    token-identical output."""
    model, params = qsetup
    # the first dispatch of every incarnation crashes (apply 0, 1, 2).
    # Incarnation 1's first dispatch is the packed A+B prefill — blame is
    # imprecise, so crash isolation kicks in and incarnations 2 and 3
    # prefill one segment per wave: their first dispatch is A's alone.
    # A collects 3 blames (quarantined); B collects 1 and survives.
    plan = FaultPlan([FaultEvent("apply", 0, "crash"),
                      FaultEvent("apply", 1, "crash"),
                      FaultEvent("apply", 2, "crash")])
    ref = _reference(model, params, [PROMPTS[1]])
    sup = EngineSupervisor(_factory(model, params, faults=plan),
                           watchdog=False, max_crashes_per_request=3)
    rid_a = sup.submit(np.asarray(PROMPTS[0]), 10)
    rid_b = sup.submit(np.asarray(PROMPTS[1]), 10)
    out = sup.run()
    fails = sup.pop_failures()
    assert set(fails) == {rid_a}
    assert isinstance(fails[rid_a], PoisonedRequest)
    assert "3 engine crashes" in str(fails[rid_a])
    assert "InjectedFault" in str(fails[rid_a])    # names the cause
    assert sup.n_quarantined == 1
    assert out[rid_b].tolist() == ref[0]           # the cohort survives
    sup.engine.cache.check_invariants(expect_idle=True)
    sup.close()


def test_abort_during_recovery_not_resurrected(qsetup):
    """Satellite negative-test: an abort_request landing between a crash
    (phase A: rebuild) and the next step (phase B: replay) must drop the
    request from the replay set — never resurrect it."""
    model, params = qsetup
    ref = _reference(model, params, PROMPTS)
    plan = FaultPlan([FaultEvent("apply", 5, "crash")])
    sup = EngineSupervisor(_factory(model, params, faults=plan),
                           watchdog=False)
    rids = [sup.submit(np.asarray(p), 10) for p in PROMPTS]
    while sup.n_restarts == 0:
        assert sup.step(), "fault must fire before work runs out"
    assert sup._pending_replay, "crash must leave a replay set"
    victim = rids[1]
    assert sup.abort_request(victim) is True   # the race window
    out = sup.run()
    assert victim not in out
    assert victim not in sup.pop_failures()
    # the rebuilt engine never admitted the aborted request
    assert sup.engine.scheduler.n_admissions == len(rids) - 1
    assert sup.stats()["aborts"] == 1
    for rid, expect in zip(rids, ref):
        if rid != victim:
            assert out[rid].tolist() == expect
    sup.engine.cache.check_invariants(expect_idle=True)
    sup.close()


def test_drain_stops_admissions_finishes_inflight(qsetup):
    model, params = qsetup
    ref = _reference(model, params, PROMPTS)
    sup = EngineSupervisor(_factory(model, params), watchdog=False)
    rids = [sup.submit(np.asarray(p), 10) for p in PROMPTS]
    sup.drain()
    assert sup.health == "draining"
    assert isinstance(sup.would_accept(4, 4), Draining)
    with pytest.raises(Draining):
        sup.submit(np.asarray([1, 2, 3]), 4)
    out = sup.run()                            # in-flight work finishes
    for rid, expect in zip(rids, ref):
        assert out[rid].tolist() == expect
    assert sup.drained
    sup.close()                                # invariant check included


def test_restart_budget_exhausted_dies_typed(qsetup):
    """Beyond max_restarts every in-flight request fails with EngineDied
    (not a hang), and the supervisor refuses new work."""
    model, params = qsetup
    plan = FaultPlan([FaultEvent("step", i, "crash") for i in range(6)])
    sup = EngineSupervisor(_factory(model, params, faults=plan),
                           watchdog=False, max_restarts=2)
    rid = sup.submit(np.asarray(PROMPTS[0]), 10)
    out = sup.run()
    assert rid not in out
    assert sup.health == "dead"
    assert sup.step() is False
    fails = sup.pop_failures()
    assert isinstance(fails[rid], EngineDied)
    assert isinstance(sup.would_accept(4, 4), EngineDied)
    with pytest.raises(EngineDied):
        sup.submit(np.asarray([1, 2]), 4)
    sup.close(check=False)


def test_recovering_window_rejects_submissions(qsetup):
    model, params = qsetup
    plan = FaultPlan([FaultEvent("apply", 5, "crash")])
    sup = EngineSupervisor(_factory(model, params, faults=plan),
                           watchdog=False)
    for p in PROMPTS:
        sup.submit(np.asarray(p), 10)
    while sup.n_restarts == 0:
        sup.step()
    # phase-B window: replay still pending
    assert isinstance(sup.would_accept(4, 4), Recovering)
    with pytest.raises(Recovering):
        sup.submit(np.asarray([1, 2]), 4)
    sup.run()
    assert sup.would_accept(4, 4) is None      # back to accepting
    sup.close()


def test_metrics_monotonic_across_rebuilds(qsetup):
    """A rebuilt engine's counters restart at zero; the supervisor's
    aggregated stats() must never regress (Counter.set_to raises)."""
    model, params = qsetup
    plan = FaultPlan([FaultEvent("apply", 4, "crash"),
                      FaultEvent("step", 8, "crash")])
    sup = EngineSupervisor(_factory(model, params, faults=plan),
                           watchdog=False)
    metrics = ServeMetrics()
    for p in PROMPTS:
        sup.submit(np.asarray(p), 10)
    prev_tokens = -1.0
    while sup.has_work:
        if not sup.step():
            break
        metrics.sync_engine(sup)               # raises on any regression
        assert metrics.tokens.value() >= prev_tokens
        prev_tokens = metrics.tokens.value()
    assert sup.n_restarts == 2
    assert metrics.restarts.value() == 2
    assert metrics.replayed_tokens.value() == sup.n_replayed_tokens
    assert metrics.recovery.count() == 2
    text = metrics.render()
    assert "msb_engine_restarts_total 2" in text
    sup.close()


def test_crash_on_final_step_still_finishes_identical(qsetup):
    """A crash landing on the very step that would deliver the request's
    final token loses that step's work; replay regenerates it and the
    request still finishes token-identical."""
    model, params = qsetup
    ref = _reference(model, params, [PROMPTS[0]], max_new=3)
    probe = _factory(model, params)()
    probe.submit(np.asarray(PROMPTS[0]), 3)
    n_steps = 0
    while probe.scheduler.has_work:
        probe.step()
        n_steps += 1
    probe.close()
    plan = FaultPlan([FaultEvent("step", n_steps - 1, "crash")])
    sup = EngineSupervisor(_factory(model, params, faults=plan),
                           watchdog=False)
    rid = sup.submit(np.asarray(PROMPTS[0]), 3)
    out = sup.run()
    assert plan.exhausted
    assert sup.n_restarts == 1
    assert out[rid].tolist() == ref[0]
    sup.engine.cache.check_invariants(expect_idle=True)
    sup.close()
