"""MoE routing/dispatch unit tests (single-device math)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models.moe import _capacity, _route, moe_layer


@pytest.fixture
def cfg():
    return dataclasses.replace(smoke_config("granite-moe-3b-a800m"),
                               n_experts=8, n_experts_active=2)


def _params(cfg, rng):
    d, e, f = cfg.d_model, cfg.n_experts_padded, cfg.d_ff
    return {"router": jnp.asarray(rng.standard_normal((d, e)), jnp.float32),
            "wg": jnp.asarray(rng.standard_normal((e, d, f)) * 0.05,
                              jnp.float32),
            "wi": jnp.asarray(rng.standard_normal((e, d, f)) * 0.05,
                              jnp.float32),
            "wo": jnp.asarray(rng.standard_normal((e, f, d)) * 0.05,
                              jnp.float32)}


def test_capacity_rounding():
    assert _capacity(1024, 2, 8, 1.25) == 320
    assert _capacity(4, 2, 128, 1.25) == 8     # floor of 8


def test_route_positions_unique_per_expert(rng, cfg):
    x = jnp.asarray(rng.standard_normal((64, cfg.d_model)), jnp.float32)
    gates, eidx, pos, keep, aux = _route(
        x, jnp.asarray(rng.standard_normal((cfg.d_model, 8)), jnp.float32),
        8, 8, 2, capacity=1000)
    e = np.asarray(eidx).reshape(-1)
    p = np.asarray(pos).reshape(-1)
    # (expert, position) pairs are unique -> no dispatch collisions
    assert len({(ee, pp) for ee, pp in zip(e, p)}) == e.size
    assert bool(np.asarray(keep).all())          # capacity not exceeded
    g = np.asarray(gates)
    np.testing.assert_allclose(g.sum(-1), 1.0, atol=1e-5)
    assert float(aux) > 0


def test_padded_experts_never_routed(rng):
    cfg = dataclasses.replace(smoke_config("granite-moe-3b-a800m"),
                              n_experts=5, n_experts_active=2)  # pads to 16
    assert cfg.n_experts_padded == 16
    x = jnp.asarray(rng.standard_normal((128, cfg.d_model)), jnp.float32)
    router = jnp.asarray(rng.standard_normal((cfg.d_model, 16)), jnp.float32)
    _, eidx, _, _, _ = _route(x, router, 16, 5, 2, capacity=1000)
    assert int(jnp.max(eidx)) < 5


def test_capacity_drops_overflow(rng, cfg):
    p = _params(cfg, rng)
    x = jnp.asarray(rng.standard_normal((2, 64, cfg.d_model)), jnp.float32)
    tight = dataclasses.replace(cfg, capacity_factor=0.25)
    y_tight, _ = moe_layer(p, x, tight, None)
    loose = dataclasses.replace(cfg, capacity_factor=8.0)
    y_loose, _ = moe_layer(p, x, loose, None)
    # dropping changes outputs; some tokens fall back to the residual (zero)
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_loose))
    assert np.isfinite(np.asarray(y_tight)).all()


def test_moe_grads_flow_to_router(rng, cfg):
    p = _params(cfg, rng)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)

    def loss(p):
        y, aux = moe_layer(p, x, cfg, None)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["wg"]))) > 0
