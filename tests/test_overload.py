"""Overload control plane (DESIGN.md Sec. 17): priority/deadline-aware
admission, the SLO-driven brownout ladder, and starvation-free shedding.

Acceptance bar (ISSUE 10): a mixed-priority cohort at ~2x pool capacity
completes with zero starvation and zero leaked pages; every completed
request is greedy-token-identical to an unloaded run of the same config
(across execution modes, tp widths and kv precisions); brownout level
changes never trigger a post-warmup jit trace; transitions are
hysteresis-bounded even under injected controller faults ("stuck",
"flap"); a supervisor rebuild inherits the brownout level; and with the
controller on, interactive TTFT p99 under overload beats the
uncontrolled baseline.
"""
import dataclasses
import json
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import QuantPolicy, quantize_params
from repro.launch.mesh import make_tp_mesh
from repro.models import Model
from repro.serve import (APIServer, BrownoutLevel, ContinuousEngine,
                         DEFAULT_LADDER, EngineSupervisor, FaultEvent,
                         FaultPlan, OverloadController, Request, Saturated,
                         Scheduler, ServeMetrics, ValidationError,
                         compute_retry_after, jit_trace_count,
                         parse_completion_request)
from repro.serve.scheduler import _WaitingQueue, Sequence


@pytest.fixture(scope="module")
def qsetup():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    qparams, report = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="dp", min_size=1024))
    assert report
    return model, qparams


# -- the unified Retry-After computation -----------------------------------

def test_retry_after_golden():
    """Pure function of (base, pressure, level, salt): golden values pin
    the exact header every shedding path emits."""
    assert [compute_retry_after(1.0, salt=s) for s in range(6)] == \
        [1, 2, 2, 2, 2, 2]
    assert [compute_retry_after(1.0, pressure=1.0, level=0, salt=s)
            for s in range(1, 4)] == [3, 3, 3]
    assert [compute_retry_after(1.0, pressure=1.0, level=4, salt=s)
            for s in range(1, 4)] == [12, 11, 13]
    assert [compute_retry_after(2.0, pressure=0.5, level=lv, salt=9)
            for lv in range(5)] == [4, 7, 11, 14, 18]


def test_retry_after_properties():
    # whole seconds >= 1, capped, deterministic per salt
    assert compute_retry_after(0.1, salt=7) == 1
    assert compute_retry_after(20.0, pressure=1.0, level=4, salt=1) == 30
    assert compute_retry_after(5.0, pressure=0.3, level=2, salt=42) == \
        compute_retry_after(5.0, pressure=0.3, level=2, salt=42)
    # monotone (within jitter-free comparison) in level and pressure
    for lv in range(4):
        assert compute_retry_after(1.0, level=lv, salt=0) <= \
            compute_retry_after(1.0, level=lv + 1, salt=0)
    assert compute_retry_after(1.0, pressure=0.0, salt=0) <= \
        compute_retry_after(1.0, pressure=1.0, salt=0)
    # out-of-range pressure is clipped, not propagated
    assert compute_retry_after(1.0, pressure=99.0, salt=0) == \
        compute_retry_after(1.0, pressure=1.0, salt=0)


# -- HTTP-layer validation --------------------------------------------------

def test_priority_deadline_validation():
    base = {"prompt": [1, 2, 3]}
    p = parse_completion_request(base, vocab_size=64)
    assert p.priority == "standard" and p.deadline_ms is None
    p = parse_completion_request(
        dict(base, priority="interactive", deadline_ms=1500),
        vocab_size=64)
    assert p.priority == "interactive" and p.deadline_ms == 1500.0
    for bad in ("urgent", 3, None):
        with pytest.raises(ValidationError) as ei:
            parse_completion_request(dict(base, priority=bad),
                                     vocab_size=64)
        assert ei.value.param == "priority"
    for bad in (0, -5, "soon", True):
        with pytest.raises(ValidationError) as ei:
            parse_completion_request(dict(base, deadline_ms=bad),
                                     vocab_size=64)
        assert ei.value.param == "deadline_ms"


# -- per-class admission queue ----------------------------------------------

def _seq(rid, priority="standard", deadline=None, submitted_at=0.0):
    return Sequence(Request(rid, np.asarray([1], np.int32), 4,
                            priority=priority, deadline=deadline,
                            submitted_at=submitted_at))


def test_waiting_queue_edf_within_class():
    q = _WaitingQueue()
    a = _seq(0, deadline=9.0)
    b = _seq(1, deadline=3.0)
    c = _seq(2)                       # deadline-free: after deadlined peers
    d = _seq(3)
    for s in (a, c, b, d):
        q.append(s)
    assert [q.popleft().req.req_id for _ in range(4)] == [1, 0, 2, 3]


def test_waiting_queue_class_order_and_aging():
    q = _WaitingQueue(promote_after=3)
    batch = _seq(0, "batch")
    q.append(batch)
    ids = [10, 11, 12]
    for i in ids:
        q.append(_seq(i, "interactive"))
    # interactive beats fresh batch...
    assert q.popleft().req.req_id == 10
    assert q.popleft().req.req_id == 11
    # ...ties on effective rank still favor the better class, so the
    # batch entry overtakes interactive only after aging one extra
    # promote_after window (rank deficit 2 -> 3 windows total): bounded
    # starvation, not instant priority inversion
    q.append(_seq(13, "interactive"))
    q.append(_seq(14, "interactive"))
    got = [q.popleft().req.req_id for _ in range(4)]
    assert got == [12, 13, 0, 14], f"batch request starved: {got}"


def test_waiting_queue_preemption_front_pin():
    q = _WaitingQueue()
    q.append(_seq(0, deadline=1.0))
    pre = _seq(1)                      # no deadline at all
    q.appendleft(pre)                  # preemption re-entry
    # a later EDF arrival must not leapfrog the head-of-line pin
    q.append(_seq(2, deadline=0.5))
    assert q[0] is pre
    assert q.popleft() is pre
    assert q.popleft().req.req_id == 2


def test_waiting_queue_facade():
    q = _WaitingQueue()
    assert not q and len(q) == 0
    s = _seq(0, "batch")
    q.append(s)
    q.append(_seq(1))
    assert q and len(q) == 2 and q.depth("batch") == 1
    assert {x.req.req_id for x in q} == {0, 1}
    q.remove(s)
    assert len(q) == 1
    with pytest.raises(IndexError):
        q[1]


# -- preemption victim selection ---------------------------------------------

def _bare_sched(running):
    sched = Scheduler.__new__(Scheduler)
    sched.running = running
    return sched


def test_pick_victim_lowest_class_youngest():
    running = [_seq(0, "interactive"), _seq(1, "batch"),
               _seq(2, "standard"), _seq(3, "batch")]
    v = Scheduler._pick_victim(_bare_sched(running), now=100.0)
    assert v.req.req_id == 3          # batch class, youngest


def test_pick_victim_deadline_protection():
    # the nearly-due standard sequence is protected; the batch one with
    # plenty of slack is not
    near = _seq(5, "interactive", deadline=10.0, submitted_at=0.0)
    slack = _seq(6, "interactive", deadline=100.0, submitted_at=0.0)
    v = Scheduler._pick_victim(_bare_sched([near, slack]), now=7.0)
    assert v is slack
    # all protected: the pool must still make progress -> fallback picks
    v = Scheduler._pick_victim(_bare_sched([near]), now=7.0)
    assert v is near
    assert Scheduler._past_point_of_no_return(near, 7.0)
    assert not Scheduler._past_point_of_no_return(near, 2.0)
    assert not Scheduler._past_point_of_no_return(_seq(7), 1e9)


# -- brownout shedding at the scheduler --------------------------------------

def test_shed_classes_and_unknown_priority(qsetup):
    model, params = qsetup
    eng = ContinuousEngine(model, params, max_batch=2, page_size=4,
                           num_pages=16, max_seq=16, prefill_chunk=4)
    assert isinstance(eng.would_accept(4, 4, priority="vip"), ValueError)
    eng.scheduler.shed_classes = frozenset({"batch"})
    assert isinstance(eng.would_accept(4, 4, priority="batch"), Saturated)
    assert eng.would_accept(4, 4, priority="interactive") is None
    with pytest.raises(Saturated):
        eng.submit(np.asarray([1, 2], np.int32), 2, priority="batch")
    assert eng.scheduler.n_sheds_by_class["batch"] == 1
    eng.scheduler.shed_classes = frozenset()
    eng.submit(np.asarray([1, 2], np.int32), 2, priority="batch")
    eng.run()
    assert eng.stats()["admissions_by_class"]["batch"] == 1
    eng.close(check=True)


# -- the controller, unit-level (fake engine) --------------------------------

class _FakeEngine:
    """Just enough surface for OverloadController: a pressure dial."""

    def __init__(self):
        self.scheduler = Scheduler.__new__(Scheduler)
        self.scheduler.max_waiting = 8
        self.scheduler.max_batch = 4
        self.scheduler.decode_horizon = 8
        self.scheduler.horizon_cap = None
        self.scheduler.max_wave_segments = None
        self.scheduler.shed_classes = frozenset()
        self.scheduler.waiting = []
        self.cache = type("C", (), {})()
        self.cache.num_pages = 33
        self.cache.n_available_pages = 32
        self.cache.shrink_calls = []
        self.cache.shrink_lru = lambda floor: (
            self.cache.shrink_calls.append(floor), 0)[1]

    def set_pressure(self, p):
        self.cache.n_available_pages = round((1.0 - p) * 32)

    def stats(self):
        return {"preemptions": 0, "steps": 0}


def test_hysteresis_needs_consecutive_ticks_and_dwell():
    eng = _FakeEngine()
    ctrl = OverloadController(eng, interval_s=0.0, up=0.8, down=0.3,
                              up_ticks=2, down_ticks=3, min_dwell_ticks=4)
    eng.set_pressure(1.0)
    assert ctrl.tick() is None         # 1 hot tick: not yet
    assert ctrl.tick() == 1            # 2 consecutive: escalate
    # dwell: even sustained pressure cannot transition again for 4 ticks
    for _ in range(3):
        assert ctrl.tick() is None
    assert ctrl.tick() == 2
    # the dead band (0.3 < p < 0.8) resets both streaks
    eng.set_pressure(0.5)
    for _ in range(20):
        assert ctrl.tick() is None
    assert ctrl.level == 2
    # de-escalation needs down_ticks consecutive cool ticks + dwell
    eng.set_pressure(0.0)
    ticks = [ctrl.tick() for _ in range(3)]
    assert ticks[-1] == 1
    # a single hot tick mid-cooldown resets the cool streak
    eng.set_pressure(1.0)
    ctrl.tick()
    eng.set_pressure(0.0)
    assert [ctrl.tick() for _ in range(8)].count(0) == 1
    assert ctrl.level == 0
    assert ctrl.n_transitions == 4
    assert len(ctrl.transition_log) == 4


def test_transition_rate_bounded_under_adversarial_oscillation():
    """Pressure flipping between extremes every tick (the worst case the
    'flap' fault injects) transitions at most once per dwell window."""
    eng = _FakeEngine()
    ctrl = OverloadController(eng, interval_s=0.0, up=0.8, down=0.3,
                              up_ticks=1, down_ticks=1, min_dwell_ticks=5)
    n_ticks = 200
    for i in range(n_ticks):
        eng.set_pressure(1.0 if i % 2 == 0 else 0.0)
        ctrl.tick()
    assert ctrl.n_transitions <= n_ticks // ctrl.min_dwell_ticks + 1


def test_controller_fault_stuck_and_flap_and_crash():
    eng = _FakeEngine()
    plan = FaultPlan([FaultEvent("controller", 0, "stuck"),
                      FaultEvent("controller", 29, "crash")])
    ctrl = OverloadController(eng, interval_s=0.0, up_ticks=1,
                              down_ticks=1, min_dwell_ticks=2, faults=plan)
    for _ in range(29):
        ctrl.tick()
    assert ctrl.level == len(DEFAULT_LADDER) - 1    # pinned at max
    errors_before = ctrl.n_tick_errors
    level_before = ctrl.level
    ctrl.tick()                                     # the injected crash
    assert ctrl.n_tick_errors == errors_before + 1
    assert ctrl.level == level_before               # fail-safe: level held
    assert plan.exhausted

    # flap injection: forced oscillation, hysteresis still bounds the rate
    eng2 = _FakeEngine()
    plan2 = FaultPlan([FaultEvent("controller", 0, "flap")])
    ctrl2 = OverloadController(eng2, interval_s=0.0, up_ticks=1,
                               down_ticks=1, min_dwell_ticks=6,
                               faults=plan2)
    for _ in range(120):
        ctrl2.tick()
    assert ctrl2.n_transitions <= 120 // 6 + 1
    assert plan2.exhausted


def test_controller_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent("step", 0, "stuck")          # controller-site only
    with pytest.raises(ValueError):
        FaultEvent("controller", 0, "oom")      # not a controller kind
    FaultEvent("controller", 0, "crash")        # crash is allowed


def test_controller_ladder_validation():
    eng = _FakeEngine()
    with pytest.raises(ValueError):
        OverloadController(eng, ladder=(BrownoutLevel(1),))
    with pytest.raises(ValueError):
        OverloadController(eng, ladder=(BrownoutLevel(0),
                                        BrownoutLevel(2)))
    with pytest.raises(ValueError):
        OverloadController(eng, up=0.5, down=0.5)


def test_interval_rate_limit():
    eng = _FakeEngine()
    ctrl = OverloadController(eng, interval_s=3600.0)
    assert ctrl.tick() is None
    n = ctrl._tick_n
    for _ in range(5):
        ctrl.tick()                    # all rate-limited away
    assert ctrl._tick_n == n


def test_apply_knobs(qsetup):
    """The ladder's levers land on the scheduler/cache as documented, and
    level 0 restores exactly today's behavior."""
    model, params = qsetup
    eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                           num_pages=32, max_seq=32, prefill_chunk=8,
                           decode_horizon=8)
    ctrl = OverloadController(eng, interval_s=0.0)
    sched = eng.scheduler
    ctrl.level = 3
    ctrl.apply_to(eng)
    assert sched.horizon_cap == 2              # 8 * 0.25
    assert sched.max_wave_segments == 2        # 4 * 0.5
    assert sched.effective_horizon == 2
    assert sched.shed_classes == frozenset()
    ctrl.level = 4
    ctrl.apply_to(eng)
    assert sched.shed_classes == frozenset({"batch"})
    assert sched.max_wave_segments == 1
    ctrl.level = 0
    ctrl.apply_to(eng)
    assert sched.horizon_cap is None
    assert sched.max_wave_segments is None
    assert sched.shed_classes == frozenset()
    assert sched.effective_horizon == 8
    eng.close(check=True)


def test_lru_eviction_floor(qsetup):
    """Level >= 3 shrinks the prefix-cache LRU park toward the floor; the
    pages come back to the free list (no leak)."""
    model, params = qsetup
    eng = ContinuousEngine(model, params, max_batch=2, page_size=4,
                           num_pages=32, max_seq=32, prefill_chunk=8)
    # park prefix pages on the LRU: run a few prompts to completion
    for i in range(3):
        eng.submit(np.arange(1 + 8 * i, 9 + 8 * i, dtype=np.int32) % 64, 2)
    eng.run()
    parked = len(eng.cache._lru)
    assert parked > 0
    free_before = eng.cache.n_free_pages
    ctrl = OverloadController(eng, interval_s=0.0)
    ctrl.level = 4                     # lru_frac=0.0: evict the whole park
    ctrl.apply_to(eng)
    assert len(eng.cache._lru) == 0
    assert eng.cache.n_free_pages == free_before + parked
    eng.close(check=True)


# -- trace discipline ---------------------------------------------------------

def test_level_changes_never_trace_after_warmup(qsetup):
    """Brownout levels only select already-warmed shapes: horizon capping
    is a dynamic clamp, wave capping selects a smaller warmed bucket. A
    load driven through every level after warmup() must add zero jit
    traces — and stay token-identical to level 0."""
    model, params = qsetup
    prompts = [np.arange(2 + 3 * i, 12 + 3 * i, dtype=np.int32) % 64
               for i in range(6)]

    def run(level):
        eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                               num_pages=64, max_seq=32, prefill_chunk=8,
                               decode_horizon=4)
        eng.warmup()
        ctrl = OverloadController(eng, interval_s=0.0)
        ctrl.level = level
        ctrl.apply_to(eng)
        baseline = jit_trace_count()
        rids = [eng.submit(p, 6) for p in prompts]
        out = eng.run()
        assert jit_trace_count() == baseline, \
            f"level {level} triggered a post-warmup trace"
        eng.close(check=True)
        return [out[r].tolist() for r in rids]

    ref = run(0)
    for level in range(1, len(DEFAULT_LADDER)):
        assert run(level) == ref, f"level {level} changed tokens"


# -- supervisor integration ---------------------------------------------------

def test_supervisor_rebuild_inherits_level(qsetup):
    """A crash mid-overload rebuilds the engine at the controller's level
    (no flap through level 0), and by-class counters fold across the
    incarnations."""
    model, params = qsetup
    plan = FaultPlan([FaultEvent("step", 4, "crash")])
    sup = EngineSupervisor(
        lambda: ContinuousEngine(model, params, max_batch=4, page_size=4,
                                 num_pages=64, max_seq=32, prefill_chunk=8,
                                 decode_horizon=4, faults=plan),
        watchdog=False)
    ctrl = OverloadController(sup, interval_s=0.0)
    assert sup._overload is ctrl               # attach_overload ran
    ctrl.level = 2
    ctrl.apply_to(sup)
    assert sup.scheduler.horizon_cap == 2
    rids = [sup.submit(np.arange(1 + i, 9 + i, dtype=np.int32) % 64, 6,
                       priority=("interactive", "batch")[i % 2],
                       deadline_ms=60_000)
            for i in range(4)]
    out = sup.run()
    assert sup.n_restarts >= 1
    assert sorted(out) == sorted(rids)         # replay completed everyone
    # the rebuilt incarnation still carries level 2's knobs
    assert ctrl.level == 2
    assert sup.scheduler.horizon_cap == 2
    st = sup.stats()
    assert st["admissions_by_class"]["interactive"] >= 2
    assert st["admissions_by_class"]["batch"] >= 2
    sup.close(check=True)


def test_supervised_chaos_drain(qsetup):
    """Controller-site chaos during supervised serving: a stuck-at-max
    injection while work is in flight. The ladder pins at max, transitions
    stay hysteresis-bounded, admitted work still completes token-identical,
    and the drain leaves a clean pool."""
    model, params = qsetup
    prompts = [np.arange(1 + 2 * i, 11 + 2 * i, dtype=np.int32) % 64
               for i in range(5)]
    ref_eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                               num_pages=64, max_seq=32, prefill_chunk=8)
    ref_rids = [ref_eng.submit(p, 6) for p in prompts]
    ref_out = ref_eng.run()
    ref = {i: ref_out[r].tolist() for i, r in enumerate(ref_rids)}
    ref_eng.close()

    plan = FaultPlan([FaultEvent("controller", 2, "stuck")])
    sup = EngineSupervisor(
        lambda: ContinuousEngine(model, params, max_batch=4, page_size=4,
                                 num_pages=64, max_seq=32,
                                 prefill_chunk=8),
        watchdog=False)
    ctrl = OverloadController(sup, interval_s=0.0, up_ticks=1,
                              down_ticks=1, min_dwell_ticks=3, faults=plan)
    rids = [sup.submit(p, 6) for p in prompts]
    done = {}
    n_ticks = 0
    while sup.has_work:
        sup.step()
        ctrl.tick()
        done.update(sup.collect())
        n_ticks += 1
        assert n_ticks < 2000
    done.update(sup.collect())
    # stuck-at-max engaged, transitions stayed hysteresis-bounded, and
    # every admitted request completed token-identical under max brownout
    assert ctrl.level == len(DEFAULT_LADDER) - 1
    assert ctrl.n_transitions <= n_ticks // ctrl.min_dwell_ticks + 1
    assert sorted(done) == sorted(rids)
    for i, r in enumerate(rids):
        assert done[r].tolist() == ref[i], f"prompt {i} diverged"
    sup.drain()
    assert sup.drained
    sup.close(check=True)              # invariants: zero leaked pages


# -- the overload soak --------------------------------------------------------

SOAK_LADDER = (
    BrownoutLevel(0),
    BrownoutLevel(1, horizon_frac=0.5, wave_frac=0.5),
    BrownoutLevel(2, horizon_frac=0.25, wave_frac=0.5, lru_frac=0.0,
                  shed=("batch",)),
)


def _soak_cohort(n=12):
    """Mixed-priority cohort, round-robin classes, deterministic prompts.
    Deadlines are generous (they order admission, not abort work)."""
    rng = np.random.default_rng(7)
    cohort = []
    for i in range(n):
        prompt = rng.integers(1, 64, (int(rng.integers(5, 12)),)) \
            .astype(np.int32)
        cls = ("interactive", "standard", "batch")[i % 3]
        cohort.append((prompt, 8, cls))
    return cohort


def _drive_soak(model, params, *, overload, execution="simulated",
                kv_bits=16, mesh=None, num_pages=24, cohort=None,
                ladder=SOAK_LADDER, ctrl_kw=None):
    """Direct-drive overload run: the cohort's whole-sequence page demand
    is ~2x the pool's usable pages. TTFT is measured in engine *steps*
    (fully deterministic — no wall clock). Returns
    ``(outputs_by_cohort_idx, ttft_steps_by_idx, shed_idxs, stats)``."""
    eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                           num_pages=num_pages, max_seq=32,
                           prefill_chunk=8, decode_horizon=4,
                           max_waiting=32, execution=execution,
                           kv_bits=kv_bits, mesh=mesh)
    # the demand bound would shed class-blind before brownout gets a say;
    # this soak isolates the controller's class-aware shedding
    eng.scheduler.oversubscribe = 100.0
    ctrl = None
    if overload:
        kw = dict(interval_s=0.0, up=0.6, down=0.2, up_ticks=1,
                  down_ticks=3, min_dwell_ticks=2, ladder=ladder)
        kw.update(ctrl_kw or {})
        ctrl = OverloadController(eng, **kw)
    cohort = _soak_cohort() if cohort is None else cohort
    idx_of = {}                        # engine rid -> cohort index
    outputs, ttft, shed, submit_step = {}, {}, set(), {}
    step_n, next_i = 0, 0
    while next_i < len(cohort) or eng.scheduler.has_work:
        # open-loop arrival: two submits per step until exhausted (the
        # pool drains slower than that -> sustained ~2x overload)
        for _ in range(2):
            if next_i >= len(cohort):
                break
            prompt, max_new, cls = cohort[next_i]
            try:
                rid = eng.submit(prompt, max_new, priority=cls,
                                 deadline_ms=120_000)
                idx_of[rid] = next_i
                submit_step[rid] = step_n
            except Saturated:
                shed.add(next_i)
            next_i += 1
        eng.step()
        step_n += 1
        assert step_n < 5000, "soak stalled: starvation"
        for rid, (new, done) in eng.stream_updates().items():
            i = idx_of[rid]
            if new and i not in ttft:
                ttft[i] = step_n - submit_step[rid]
            outputs.setdefault(i, []).extend(new)
        if ctrl is not None:
            ctrl.tick()
    st = eng.stats()
    eng.close(check=True)              # zero leaks after the soak
    return outputs, ttft, shed, st, cohort


def _soak_reference(model, params, cohort, **kw):
    """Unloaded run of the same cohort: ample pool, no controller."""
    eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                           num_pages=96, max_seq=32, prefill_chunk=8,
                           decode_horizon=4, **kw)
    rids = [eng.submit(p, n, priority=c) for p, n, c in cohort]
    out = eng.run()
    eng.close()
    return [out[r].tolist() for r in rids]


@pytest.mark.parametrize("execution", ["simulated", "packed"])
@pytest.mark.parametrize("kv_bits", [16, 8])
def test_overload_soak_token_identity(qsetup, execution, kv_bits):
    """2x-capacity mixed-priority soak: every admitted request completes
    (zero starvation), every completed output is token-identical to the
    unloaded run, sheds are explicit 429-path rejections (batch class
    only), and the pool ends clean."""
    model, params = qsetup
    outputs, ttft, shed, st, cohort = _drive_soak(
        model, params, overload=True, execution=execution, kv_bits=kv_bits)
    ref = _soak_reference(model, params, cohort, execution=execution,
                          kv_bits=kv_bits)
    admitted = [i for i in range(len(cohort)) if i not in shed]
    assert set(outputs) == set(admitted), "starved request"
    for i in admitted:
        assert outputs[i] == ref[i], \
            f"cohort[{i}] diverged under load ({execution}, kv{kv_bits})"
    for i in shed:
        assert cohort[i][2] == "batch", "only batch class may be shed"
    assert all(t >= 0 for t in ttft.values())
    assert st["sheds_by_class"]["interactive"] == 0


@pytest.mark.parametrize("kv_bits", [16, 8])
def test_overload_soak_tp2(qsetup, kv_bits):
    """The soak invariants hold on a 2-way tensor-parallel mesh."""
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count)")
    model, params = qsetup
    mesh = make_tp_mesh(2)
    outputs, _ttft, shed, _st, cohort = _drive_soak(
        model, params, overload=True, kv_bits=kv_bits, mesh=mesh)
    ref = _soak_reference(model, params, cohort, kv_bits=kv_bits,
                          mesh=make_tp_mesh(2))
    admitted = [i for i in range(len(cohort)) if i not in shed]
    assert set(outputs) == set(admitted), "starved request"
    for i in admitted:
        assert outputs[i] == ref[i], f"cohort[{i}] diverged under tp=2"


def test_soak_interactive_ttft_beats_uncontrolled(qsetup):
    """The point of the ladder: under a sustained batch flood, the
    controller sheds batch at the admission door so later interactive
    arrivals find free decode slots; uncontrolled, every interactive
    queues behind long batch decodes already holding the slots. TTFT p99
    is measured in engine steps — fully deterministic, no wall clock."""
    model, params = qsetup
    rng = np.random.default_rng(11)

    def _prompt():
        return rng.integers(1, 64, (int(rng.integers(5, 8)),)) \
            .astype(np.int32)

    # 12 long batch requests flood the slots first; then interactive
    # arrives at 2/step — faster than slots can turn over while admitted
    # batch still drains, so a backlog builds unless batch is shed
    cohort = [(_prompt(), 16, "batch") for _ in range(12)]
    cohort += [(_prompt(), 4, "interactive") for _ in range(12)]
    # shed-only ladder: no horizon/wave shrink, so any TTFT gap is
    # attributable purely to class-aware admission shedding
    shed_only = (BrownoutLevel(0), BrownoutLevel(1, shed=("batch",)))
    # pages fill incrementally as decodes run, so pool pressure climbs
    # slowly: a low trigger closes the door before the queue fills
    knobs = dict(up=0.15, down=0.05, up_ticks=1, down_ticks=100,
                 min_dwell_ticks=1)

    def interactive_p99(overload):
        _outputs, ttft, _shed, _st, _c = _drive_soak(
            model, params, overload=overload, cohort=cohort, num_pages=40,
            ladder=shed_only, ctrl_kw=knobs)
        vals = sorted(t for i, t in ttft.items()
                      if cohort[i][2] == "interactive")
        assert vals, "no interactive request got a first token"
        return vals[min(len(vals) - 1, int(np.ceil(0.99 * len(vals))) - 1)]

    controlled = interactive_p99(True)
    uncontrolled = interactive_p99(False)
    assert controlled < uncontrolled, (
        f"controller did not improve interactive TTFT p99: "
        f"{controlled} vs {uncontrolled} steps")


# -- HTTP front door ----------------------------------------------------------

def test_http_overload_end_to_end(qsetup):
    """Server-level integration: priority/deadline_ms accepted over HTTP,
    /healthz reports brownout_level, a stuck-at-max controller sheds batch
    requests with a load-derived Retry-After, and msb_* overload families
    render."""
    import http.client

    def req(host, port, method, path, payload=None):
        conn = http.client.HTTPConnection(host, port, timeout=120)
        try:
            body = None if payload is None else json.dumps(payload).encode()
            conn.request(method, path, body=body,
                         headers={"Content-Type": "application/json"})
            r = conn.getresponse()
            return r.status, dict(r.getheaders()), r.read()
        finally:
            conn.close()

    model, params = qsetup
    plan = FaultPlan([FaultEvent("controller", 0, "stuck")])
    srv = APIServer(
        ContinuousEngine(model, params, max_batch=4, page_size=4,
                         num_pages=64, max_seq=32, prefill_chunk=8),
        overload={"interval_s": 0.0, "up_ticks": 1, "down_ticks": 1,
                  "min_dwell_ticks": 1, "faults": plan})
    host, port = srv.serve_background()
    try:
        # normal completion with the new request fields
        status, _h, body = req(host, port, "POST", "/v1/completions",
                               {"prompt": [1, 2, 3], "max_tokens": 3,
                                "priority": "interactive",
                                "deadline_ms": 30_000})
        assert status == 200, body
        # bad priority -> 400 naming the param
        status, _h, body = req(host, port, "POST", "/v1/completions",
                               {"prompt": [1], "priority": "vip"})
        assert status == 400
        assert json.loads(body)["error"]["param"] == "priority"
        # the stuck fault pins the ladder at max within a few idle ticks
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            _s, _h, body = req(host, port, "GET", "/healthz")
            if json.loads(body)["brownout_level"] \
                    == len(DEFAULT_LADDER) - 1:
                break
            time.sleep(0.05)
        else:
            pytest.fail(f"brownout_level never reached max: {body}")
        # batch class is shed with a load-derived Retry-After
        status, headers, body = req(host, port, "POST", "/v1/completions",
                                    {"prompt": [5, 6], "max_tokens": 2,
                                     "priority": "batch"})
        assert status == 429, body
        assert 1 <= int(headers["Retry-After"]) <= 30
        assert json.loads(body)["error"]["type"] == "overloaded_error"
        # interactive still flows at max brownout
        status, _h, body = req(host, port, "POST", "/v1/completions",
                               {"prompt": [7, 8], "max_tokens": 2,
                                "priority": "interactive"})
        assert status == 200, body
        # the new families render, and the shed counter saw the batch 429
        _s, _h, metrics = req(host, port, "GET", "/metrics")
        text = metrics.decode()
        for fam in ("msb_brownout_level", "msb_brownout_transitions_total",
                    "msb_shed_total", "msb_admissions_total",
                    "msb_preemptions_total"):
            assert fam in text, fam
        assert 'msb_shed_total{class="batch"} 1' in text
    finally:
        srv.close()
