"""Quantize/dequantize API, packing, policies, storage accounting."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (QTensor, QuantPolicy, dequantize, dequantize_params,
                        double_quantize, pack_codes_int4, param_bits,
                        quantize_blockwise, quantize_params,
                        quantize_pertensor, reconstruction_mse,
                        storage_bits_per_weight, unpack_codes_int4, baselines)


def test_blockwise_roundtrip_mse_beats_rtn(rng):
    w = rng.standard_normal((16, 128)).astype(np.float32)
    q = quantize_blockwise(w, bits=4, block=64, solver="dp")
    mse_msb = float(reconstruction_mse(w, dequantize(q)))
    mse_rtn = float(reconstruction_mse(w, baselines.rtn_quantize(w, 4, 64)))
    assert mse_msb < mse_rtn


def test_pertensor_kmeans_beats_rtn(rng):
    w = rng.standard_normal((32, 64)).astype(np.float32)
    q = quantize_pertensor(w, bits=6, solver="kmeans")
    mse_msb = float(reconstruction_mse(w, dequantize(q)))
    mse_rtn = float(reconstruction_mse(w, baselines.rtn_quantize(w, 6, -1)))
    assert mse_msb < mse_rtn


@given(st.integers(2, 4))
@settings(max_examples=10, deadline=None)
def test_bits_monotonic(bits):
    rng = np.random.default_rng(1)
    w = rng.standard_normal((8, 128)).astype(np.float32)
    lo = quantize_blockwise(w, bits=bits, block=64, solver="dp")
    hi = quantize_blockwise(w, bits=bits + 1, block=64, solver="dp")
    assert float(reconstruction_mse(w, dequantize(hi))) <= \
        float(reconstruction_mse(w, dequantize(lo))) + 1e-5


def test_codes_range(rng):
    w = rng.standard_normal((8, 128)).astype(np.float32)
    q = quantize_blockwise(w, bits=4, block=64, solver="dp")
    codes = np.asarray(q.codes)
    assert codes.min() >= -8 and codes.max() <= 8
    # sign structure: dequant sign matches weight sign
    wd = np.asarray(dequantize(q))
    nz = w != 0
    assert (np.sign(wd[nz]) == np.sign(w[nz])).all()


def test_int4_packing_roundtrip(rng):
    w = rng.standard_normal((8, 128)).astype(np.float32)
    q = quantize_blockwise(w, bits=4, block=64, solver="dp")
    packed = pack_codes_int4(q.codes)
    assert packed.size == q.codes.size // 2
    codes2 = unpack_codes_int4(packed, q.codes.shape)
    nz = np.asarray(q.codes) != 0
    np.testing.assert_array_equal(np.asarray(codes2)[nz],
                                  np.asarray(q.codes)[nz])


def test_storage_accounting(rng):
    w = rng.standard_normal((64, 64)).astype(np.float32)
    q = quantize_blockwise(w, bits=4, block=64, solver="dp")
    assert storage_bits_per_weight(q) == pytest.approx(6.00, abs=0.01)
    assert storage_bits_per_weight(q, double_quant=True) == \
        pytest.approx(4.78, abs=0.01)


def test_double_quantize_small_degradation(rng):
    w = rng.standard_normal((64, 512)).astype(np.float32)
    q = quantize_blockwise(w, bits=4, block=64, solver="dp")
    qd = double_quantize(q, bits=6, block=512)
    m1 = float(reconstruction_mse(w, dequantize(q)))
    m2 = float(reconstruction_mse(w, dequantize(qd)))
    assert m2 >= m1                       # DQ can only lose accuracy
    assert m2 <= 2.0 * m1 + 1e-3          # ... but not catastrophically


def test_qtensor_is_pytree(rng):
    w = rng.standard_normal((8, 128)).astype(np.float32)
    q = quantize_blockwise(w, bits=4, block=64, solver="dp")
    leaves = jax.tree_util.tree_leaves(q)
    assert len(leaves) == 2
    q2 = jax.tree_util.tree_map(lambda x: x, q)
    assert isinstance(q2, QTensor)
    # flows through jit
    out = jax.jit(dequantize)(q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dequantize(q)))


def test_quantize_params_policy(rng):
    params = {
        "layer": {"wq": jnp.asarray(rng.standard_normal((128, 64)), jnp.float32),
                  "norm": jnp.ones((64,), jnp.float32),
                  "bias": jnp.zeros((64,), jnp.float32)},
        "embed": jnp.asarray(rng.standard_normal((128, 64)), jnp.float32),
    }
    tree, report = quantize_params(params, QuantPolicy(min_size=1024,
                                                       solver="dp"))
    assert isinstance(tree["layer"]["wq"], QTensor)
    assert isinstance(tree["embed"], QTensor)
    assert not isinstance(tree["layer"]["norm"], QTensor)
    assert not isinstance(tree["layer"]["bias"], QTensor)
    dense = dequantize_params(tree)
    assert dense["layer"]["wq"].shape == (128, 64)
    bits = param_bits(tree)
    bits_dense = param_bits(params)
    assert bits < 0.4 * bits_dense  # ~6/32 + fp leaves
