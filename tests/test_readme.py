"""The README front door is executable: every fenced ```python block runs.

CI executes this as its own step, so quickstart snippets cannot drift from
the code. Blocks share one namespace (later blocks may build on earlier
ones) and must be device-count agnostic — TP demos gate on
``len(jax.devices())``.
"""
import pathlib
import re


def test_readme_python_snippets_run():
    readme = pathlib.Path(__file__).resolve().parent.parent / "README.md"
    blocks = re.findall(r"```python\n(.*?)```", readme.read_text(), re.S)
    assert blocks, "README.md has no ```python blocks"
    ns = {}
    for i, block in enumerate(blocks):
        exec(compile(block, f"README.md[block {i}]", "exec"), ns)
    assert "outputs" in ns and ns["outputs"], \
        "quickstart produced no serving outputs"
