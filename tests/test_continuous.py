"""ContinuousEngine: continuous batching == static-engine greedy decoding."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core import QuantPolicy, quantize_params
from repro.models import Model
from repro.serve import ContinuousEngine, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return model, params


@pytest.fixture(scope="module")
def qsetup(setup):
    model, params = setup
    qparams, report = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="dp", min_size=1024))
    assert report
    return model, qparams


def _static_refs(model, params, requests):
    eng = ServeEngine(model, params, max_seq=64)
    return [np.asarray(eng.generate(jnp.asarray(p[None]),
                                    n_tokens=n))[0]
            for p, n in requests]


def _mixed_requests(rng, n):
    return [(rng.integers(0, 64, (int(rng.integers(3, 14)),))
             .astype(np.int32), int(rng.integers(2, 10)))
            for _ in range(n)]


def test_eight_concurrent_staggered_token_identical(qsetup, rng):
    """>= 8 concurrent requests, staggered arrivals, mixed prompt/output
    lengths: greedy output token-identical to the static engine on the same
    MSB-quantized model (the acceptance scenario)."""
    from repro.serve.continuous import _paged_step

    model, qparams = qsetup
    requests = _mixed_requests(rng, 9)
    refs = _static_refs(model, qparams, requests)
    _paged_step._clear_cache()      # the jit cache is shared across engines
    eng = ContinuousEngine(model, qparams, max_batch=8, page_size=4,
                           num_pages=64, max_seq=24, prefill_chunk=6)
    arrivals = [0, 0, 1, 2, 4, 6, 6, 9, 12]
    done, i, t = {}, 0, 0
    while i < len(requests) or eng.scheduler.has_work:
        while i < len(requests) and arrivals[i] <= t:
            assert eng.submit(*requests[i]) == i
            i += 1
        if not eng.step() and i < len(requests):
            t = arrivals[i]
            continue
        done.update(eng.collect())
        t += 1
    done.update(eng.collect())
    assert sorted(done) == list(range(9))
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(done[i], ref)
    # bucketed shapes: 1 prefill trace + at most log2(max_batch)+1 decode
    # bucket traces, regardless of request count
    assert _paged_step._cache_size() <= 5


def test_preemption_recompute_token_identical(setup, rng):
    """A pool too small for both sequences forces eviction + recompute; the
    greedy outputs are still identical to the static engine."""
    model, params = setup
    requests = _mixed_requests(rng, 2)
    requests = [(r[0][:4], 8) for r in requests]
    refs = _static_refs(model, params, requests)
    eng = ContinuousEngine(model, params, max_batch=4, page_size=2,
                           num_pages=11, prefill_chunk=4)
    for p, n in requests:
        eng.submit(p, n)
    done = eng.run()
    assert eng.scheduler.n_preemptions > 0, "pool sized to force preemption"
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(done[i], ref)
    # allocator drains clean: nothing referenced; every page is either free
    # or parked in the prefix-cache LRU (cached-but-alive, reclaimable)
    c = eng.cache
    assert c.n_free_pages + c.n_cached_pages == c.num_pages - 1
    assert (c.ref_counts[1:] == 0).all() and c.ref_counts[0] == 1


def test_eos_stops_early(setup, rng):
    model, params = setup
    prompt = rng.integers(0, 64, (6,)).astype(np.int32)
    eng0 = ContinuousEngine(model, params, max_batch=2, page_size=4,
                            num_pages=32, prefill_chunk=8)
    rid = eng0.submit(prompt, 12)
    full = eng0.run()[rid]
    eos = int(full[2])
    eng1 = ContinuousEngine(model, params, max_batch=2, page_size=4,
                            num_pages=32, prefill_chunk=8)
    rid = eng1.submit(prompt, 12, eos_id=eos)
    out = eng1.run()[rid]
    assert len(out) == 3 and out[-1] == eos


def test_oversized_request_rejected(setup):
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=2, page_size=2,
                           num_pages=5, prefill_chunk=4)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(6, np.int32), 8)   # 14 tokens > 8-token pool


def test_non_attention_arch_rejected():
    cfg = smoke_config("xlstm-125m")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ContinuousEngine(model, params)


def test_collect_drains_incrementally(setup, rng):
    model, params = setup
    eng = ContinuousEngine(model, params, max_batch=4, page_size=4,
                           num_pages=32, prefill_chunk=8)
    a = eng.submit(rng.integers(0, 64, (4,)).astype(np.int32), 2)
    b = eng.submit(rng.integers(0, 64, (4,)).astype(np.int32), 9)
    seen = {}
    while eng.scheduler.has_work:
        eng.step()
        got = eng.collect()
        assert not (set(got) & set(seen))      # never delivered twice
        seen.update(got)
    assert sorted(seen) == [a, b]
    assert len(seen[a]) == 2 and len(seen[b]) == 9
