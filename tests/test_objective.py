"""MSB objective identities (paper Sec. 3.2 / Appendix A)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import assume, given, settings, strategies as st

from repro.core import (grouping_cost, group_sse, lambda_bounds,
                        lambda_from_tilde, prefix_sums, xnor_closed_form)


def _arrays(min_size=2, max_size=64):
    # zero-free by construction (Eq. 1 assumes B in {-1,+1}: exact zeros
    # live in the paper's zero-loss special group)
    return st.lists(
        st.floats(0.0078125, 10, allow_nan=False, width=32).flatmap(
            lambda m: st.sampled_from([m, -m])),
        min_size=min_size, max_size=max_size).map(np.asarray)


@given(_arrays())
@settings(max_examples=50, deadline=None)
def test_xnor_closed_form_is_optimal(a):
    """alpha* = mean|A| minimizes ||A - alpha sign(A)||^2 over alpha."""
    alpha, b = xnor_closed_form(a)
    base = float(jnp.sum((jnp.asarray(a) - alpha * b) ** 2))
    for da in (-0.05, 0.05, 0.3):
        perturbed = float(jnp.sum((jnp.asarray(a) - (alpha + da) * b) ** 2))
        assert perturbed >= base - 1e-5


@given(_arrays())
@settings(max_examples=50, deadline=None)
def test_variance_identity(a):
    """||A - alpha* B*||^2 == |A| * Var(|A|) (Appendix A, zero-free)."""
    alpha, b = xnor_closed_form(a)
    sse = float(jnp.sum((jnp.asarray(a) - alpha * b) ** 2))
    mags = np.abs(a)
    assert sse == pytest.approx(a.size * mags.var(), rel=1e-4, abs=1e-5)
    assert float(group_sse(a)) == pytest.approx(sse, rel=1e-4, abs=1e-5)


def test_prefix_sums():
    v = jnp.asarray([1.0, 2.0, 3.0])
    s1, s2 = prefix_sums(v)
    np.testing.assert_allclose(s1, [0, 1, 3, 6])
    np.testing.assert_allclose(s2, [0, 1, 5, 14])


def test_grouping_cost_single_group_equals_sse(rng):
    a = rng.standard_normal(32)
    c = float(grouping_cost(a, [0, 32]))
    assert c == pytest.approx(float(group_sse(a)), rel=1e-5)


def test_grouping_cost_singletons_is_zero(rng):
    a = rng.standard_normal(8)
    c = float(grouping_cost(a, list(range(9))))
    assert c == pytest.approx(0.0, abs=1e-6)


def test_lambda_bounds_order(rng):
    a = rng.standard_normal(256)
    lo, hi = lambda_bounds(a)
    assert 0 <= lo < hi
    assert lambda_from_tilde(a, 0.0) == pytest.approx(lo)
    assert lambda_from_tilde(a, 1.0) == pytest.approx(hi)
