"""Serve an MSB-quantized model over the streaming HTTP front door.

    PYTHONPATH=src python examples/serve_api.py                # serve forever
    PYTHONPATH=src python examples/serve_api.py --self-check   # exercise + exit

Builds a smoke-size model, quantizes it at load (4-bit MSB, dynamic-grouping
DP solver — no calibration pass, so quantize-then-serve is one step), wraps
it in ``ContinuousEngine`` at the production decode config
(``decode_horizon=8``, prefix cache on), and exposes it through
``APIServer`` (DESIGN.md Sec. 13):

  * ``POST /v1/completions`` — OpenAI-style; ``prompt`` is token ids,
    streaming responses are SSE ``data:`` frames ending in ``data: [DONE]``
  * ``GET /v1/models`` / ``GET /healthz`` / ``GET /metrics``

The server starts with ``warmup=True``: the engine loop AOT-compiles the
full reachable dispatch set (DESIGN.md Sec. 16) before admitting traffic,
and ``/healthz`` answers 503 + ``Retry-After`` (``"warming"``) until it
finishes — steady-state serving then performs **zero** new jit traces.

``--self-check`` starts the server in-process and drives it like a client:
waits out the warming window (printing the warmup report), snapshots the
trace-count probe, then runs a streaming request (asserting the SSE
framing contract), a non-stream request (asserting token identity against
a direct ``ContinuousEngine`` run of the same prompt — the front door must
not change greedy tokens), a mid-stream disconnect (asserting the engine
aborts the request and the page pool drains back to baseline), asserts the
probe never moved (no steady-state retracing), then scrapes ``/metrics``
to ``--metrics-out``.

``--self-check --chaos`` instead wraps the engine in ``EngineSupervisor``
with a seeded ``FaultPlan`` (DESIGN.md Sec. 14) and drives concurrent
streaming clients through the injected crashes: clients retry on 503
(warming / recovery window) / 429, every final stream must be
byte-identical to a fault-free reference run, every rebuilt engine
incarnation is re-warmed inside its recovery window, and the page pool
must audit clean afterwards (``check_invariants(expect_idle=True)`` —
zero leaked pages).

In foreground mode (no ``--self-check``) SIGTERM/SIGINT triggers a
graceful drain: admissions answer 503 while in-flight requests run to
completion, then the server exits.
"""
import argparse
import dataclasses
import http.client
import json
import socket
import time

import numpy as np


def build_engine(seed=0, **eng_kw):
    import jax
    import jax.numpy as jnp

    from repro.configs import smoke_config
    from repro.core import QuantPolicy, quantize_params
    from repro.models import Model
    from repro.serve import ContinuousEngine

    cfg = smoke_config("internlm2-1.8b")
    cfg = dataclasses.replace(cfg, vocab_size=64, vocab_round=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    params = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    qparams, report = quantize_params(params, QuantPolicy(
        bits=4, block=64, solver="dp", min_size=1024))
    print(f"[serve_api] quantized {len(report)} tensors (4-bit MSB, "
          "dp solver, no calibration)")
    kw = dict(max_batch=8, page_size=4, num_pages=256, max_seq=128,
              prefill_chunk=8, decode_horizon=8, max_waiting=32)
    kw.update(eng_kw)
    return ContinuousEngine(model, qparams, **kw)


def _post(host, port, body):
    conn = http.client.HTTPConnection(host, port, timeout=120)
    conn.request("POST", "/v1/completions", json.dumps(body).encode(),
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    out = resp.status, json.loads(resp.read().decode())
    conn.close()
    return out


def _stream(host, port, body, hang_up_after=None):
    """Raw-socket SSE client. Returns (token_ids, finish_reason); if
    ``hang_up_after`` is set, closes the socket after that many frames
    (the mid-stream disconnect path)."""
    payload = json.dumps(dict(body, stream=True)).encode()
    s = socket.create_connection((host, port), timeout=120)
    s.sendall((f"POST /v1/completions HTTP/1.1\r\nHost: x\r\n"
               f"Content-Type: application/json\r\n"
               f"Content-Length: {len(payload)}\r\n\r\n").encode() + payload)
    buf = b""
    while b"\r\n\r\n" not in buf:
        buf += s.recv(65536)
    head, buf = buf.split(b"\r\n\r\n", 1)
    assert b"200 OK" in head and b"text/event-stream" in head, head
    toks, reason, n_frames = [], None, 0
    while True:
        while b"\n\n" in buf:
            frame, buf = buf.split(b"\n\n", 1)
            assert frame.startswith(b"data: "), f"bad SSE framing: {frame!r}"
            n_frames += 1
            if frame == b"data: [DONE]":
                s.close()
                return toks, reason
            chunk = json.loads(frame[6:])["choices"][0]
            toks.extend(chunk["token_ids"])
            reason = chunk["finish_reason"]
            if hang_up_after is not None and n_frames >= hang_up_after:
                s.close()                      # client walks away mid-stream
                return toks, None
        data = s.recv(65536)
        assert data, "server closed the stream before [DONE]"
        buf += data


def _healthz(host, port):
    conn = http.client.HTTPConnection(host, port, timeout=30)
    conn.request("GET", "/healthz")
    resp = conn.getresponse()
    body = json.loads(resp.read().decode())
    retry = resp.getheader("Retry-After")
    conn.close()
    return resp.status, body, retry


def _await_warm(srv, host, port, timeout=600.0):
    """Block until the startup warmup finishes. While the engine loop is
    warming, ``/healthz`` must answer 503 + ``Retry-After`` — the same
    come-back-later contract as the crash-recovery window."""
    loop = srv.engine_loop
    deadline = time.monotonic() + timeout
    while loop.warming or loop.engine.stats()["warmup_traces"] == 0:
        status, body, retry = _healthz(host, port)
        if body.get("status") == "warming":
            assert status == 503 and retry, (status, retry)
        assert time.monotonic() < deadline, "warmup did not finish"
        time.sleep(0.05)
    status, body, _ = _healthz(host, port)
    assert status == 200 and body["status"] == "ok", body
    # with the overload controller attached, an unloaded server reports
    # the brownout ladder parked at level 0
    assert body.get("brownout_level", 0) == 0, body


def self_check(srv, host, port, metrics_out):
    from repro.serve import ContinuousEngine, jit_trace_count

    eng = srv.engine_loop.engine
    _await_warm(srv, host, port)
    st = eng.stats()
    print(f"[self-check] warmup: {st['warmup_traces']} dispatch shapes "
          f"AOT-compiled in {st['warmup_seconds']:.2f}s; /healthz ok")
    traces0 = jit_trace_count()

    rng = np.random.default_rng(7)
    prompt = rng.integers(0, 64, (9,)).astype(np.int32)
    body = {"prompt": prompt.tolist(), "max_tokens": 24}

    # reference: the same prompt through a direct engine (same config)
    ref_eng = ContinuousEngine(eng.model, eng.params, max_batch=8,
                               page_size=4, num_pages=256, max_seq=128,
                               prefill_chunk=8, decode_horizon=8)
    rid = ref_eng.submit(prompt, 24)
    ref = ref_eng.run()[rid].tolist()

    toks, reason = _stream(host, port, body)
    assert toks == ref, "streamed tokens differ from direct engine"
    assert reason == "length", reason
    print(f"[self-check] stream: {len(toks)} tokens, SSE framing ok, "
          "token-identical to direct engine")

    status, resp = _post(host, port, body)
    assert status == 200 and \
        resp["choices"][0]["token_ids"] == ref, "non-stream mismatch"
    assert resp["usage"]["completion_tokens"] == len(ref)
    print(f"[self-check] non-stream: 200, usage={resp['usage']}")

    aborts0 = eng.scheduler.n_aborts
    partial, _ = _stream(host, port,
                         {"prompt": prompt.tolist(), "max_tokens": 100},
                         hang_up_after=2)
    deadline = time.monotonic() + 15
    cache = eng.cache
    while time.monotonic() < deadline and (
            eng.scheduler.n_aborts == aborts0
            or cache.n_free_pages + cache.n_cached_pages
            < cache.num_pages - 1):
        time.sleep(0.05)
    assert eng.scheduler.n_aborts == aborts0 + 1, "disconnect did not abort"
    assert (cache.n_free_pages + cache.n_cached_pages
            == cache.num_pages - 1), "pages leaked after disconnect"
    print(f"[self-check] disconnect after {len(partial)} tokens: engine "
          "aborted the request, page pool back to baseline")

    n_new = jit_trace_count() - traces0
    assert n_new == 0, f"steady-state serving retraced ({n_new} new traces)"
    print("[self-check] no-retrace: 0 new jit traces across the entire "
          "serving phase (stream + non-stream + disconnect)")

    conn = http.client.HTTPConnection(host, port, timeout=30)
    conn.request("GET", "/metrics")
    scrape = conn.getresponse().read().decode()
    conn.close()
    assert "msb_ttft_seconds_count" in scrape
    assert "msb_warmup_seconds" in scrape
    assert "msb_traces_compiled_total" in scrape
    assert "msb_brownout_level" in scrape
    if metrics_out:
        with open(metrics_out, "w") as f:
            f.write(scrape)
        print(f"[self-check] /metrics scrape -> {metrics_out} "
              f"({len(scrape.splitlines())} lines)")
    print("[self-check] all assertions passed")


def chaos_check(srv, sup, plan, host, port, prompts, refs, metrics_out):
    """Concurrent clients vs. the seeded fault plan: byte-identical
    streams, no hangs, zero leaked pages."""
    from concurrent.futures import ThreadPoolExecutor

    def client(i):
        payload = json.dumps({"prompt": prompts[i], "max_tokens": 16,
                              "stream": True}).encode()
        deadline = time.monotonic() + 180
        while True:
            assert time.monotonic() < deadline, f"chaos client {i} hung"
            conn = http.client.HTTPConnection(host, port, timeout=120)
            conn.request("POST", "/v1/completions", payload,
                         {"Content-Type": "application/json"})
            resp = conn.getresponse()
            body = resp.read()
            conn.close()
            if resp.status in (429, 503):      # saturated / recovering
                time.sleep(0.05)
                continue
            assert resp.status == 200, (resp.status, body)
            frames = [f for f in body.decode().split("\n\n") if f]
            assert all(f.startswith("data: ") for f in frames), "bad framing"
            assert frames[-1] == "data: [DONE]", "stream ended without [DONE]"
            chunks = [json.loads(f[6:])["choices"][0] for f in frames[:-1]]
            return [t for c in chunks for t in c["token_ids"]]

    with ThreadPoolExecutor(len(prompts)) as pool:
        streams = list(pool.map(client, range(len(prompts))))
    for i, toks in enumerate(streams):
        assert toks == refs[i], (
            f"chaos client {i} diverged from the fault-free run")
    assert plan.exhausted, f"plan only fired {len(plan.fired)}/{plan.n_events}"
    assert sup.n_restarts > 0, "no fault actually crashed the engine"
    deadline = time.monotonic() + 15
    cache = sup.engine.cache
    while time.monotonic() < deadline and (
            cache.n_free_pages + cache.n_cached_pages < cache.num_pages - 1):
        time.sleep(0.05)
    cache.check_invariants(expect_idle=True)   # zero leaked pages
    st = sup.stats()
    # warmup is sticky across crashes: every rebuilt incarnation re-warms
    # inside its recovery window, so warmup_traces accumulates one full
    # shape set per incarnation (restarts + the original)
    per_inc = sup.engine.warmup_entries
    assert per_inc > 0, "rebuilt incarnation was not re-warmed"
    assert st["warmup_traces"] >= per_inc * (st["restarts"] + 1), st
    print(f"[chaos] {len(prompts)} clients byte-identical through "
          f"{len(plan.fired)} injected faults ({st['restarts']} restarts, "
          f"{st['replayed_tokens']} tokens replayed, "
          f"{st['watchdog_trips']} watchdog trips); pool audit clean; "
          f"{st['restarts']} incarnations re-warmed "
          f"({st['warmup_traces']} shapes, {st['warmup_seconds']:.2f}s)")
    if metrics_out:
        conn = http.client.HTTPConnection(host, port, timeout=30)
        conn.request("GET", "/metrics")
        scrape = conn.getresponse().read().decode()
        conn.close()
        assert "msb_engine_restarts_total" in scrape
        with open(metrics_out, "w") as f:
            f.write(scrape)
        print(f"[chaos] /metrics scrape -> {metrics_out} "
              f"({len(scrape.splitlines())} lines)")
    print("[chaos] all assertions passed")


def run_chaos(args):
    from repro.serve import (APIServer, ContinuousEngine, EngineSupervisor,
                             FaultPlan)

    ref_eng = build_engine()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, 64, (int(n),)).astype(np.int32).tolist()
               for n in rng.integers(4, 12, (6,))]
    rids = [ref_eng.submit(np.asarray(p, np.int32), 16) for p in prompts]
    out = ref_eng.run()
    refs = [out[r].tolist() for r in rids]
    model, params = ref_eng.model, ref_eng.params
    ref_eng.close()
    print(f"[chaos] fault-free reference computed for {len(prompts)} prompts")

    # spread is small on purpose: decode_horizon=8 fuses 8 tokens per
    # engine step, so per-site indices advance slowly — a wide spread
    # would leave tail faults unfired by this short workload
    plan = FaultPlan.seeded(args.chaos_seed, n_faults=8,
                            sites=("step", "apply", "alloc"),
                            first=2, spread=10, stall_s=0.02)
    kw = dict(max_batch=8, page_size=4, num_pages=256, max_seq=128,
              prefill_chunk=8, decode_horizon=8, max_waiting=32)
    sup = EngineSupervisor(
        lambda: ContinuousEngine(model, params, faults=plan, **kw),
        watchdog=False, max_crashes_per_request=100)
    srv = APIServer(sup, host=args.host, port=0, max_timeout_s=300.0,
                    warmup=True)
    host, port = srv.serve_background()
    print(f"[chaos] seeded plan {plan} against http://{host}:{port}")
    try:
        chaos_check(srv, sup, plan, host, port, prompts, refs,
                    args.metrics_out)
    finally:
        srv.close()
        sup.close(check=False)


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--self-check", action="store_true",
                    help="start in-process, exercise the API, then exit")
    ap.add_argument("--metrics-out", default=None,
                    help="with --self-check: write the /metrics scrape here")
    ap.add_argument("--chaos", action="store_true",
                    help="with --self-check: run the fault-injection chaos "
                         "check (supervised engine + seeded FaultPlan)")
    ap.add_argument("--chaos-seed", type=int, default=42,
                    help="seed for the --chaos fault plan")
    args = ap.parse_args()

    from repro.serve import APIServer

    if args.chaos:
        if not args.self_check:
            ap.error("--chaos requires --self-check")
        run_chaos(args)
        return

    engine = build_engine()
    srv = APIServer(engine, host=args.host,
                    port=0 if args.self_check else args.port,
                    max_timeout_s=300.0, warmup=True, overload=True)
    if not args.self_check:
        srv.run()                               # blocks until interrupted
        return
    host, port = srv.serve_background()
    print(f"[serve_api] self-check against http://{host}:{port}")
    try:
        self_check(srv, host, port, args.metrics_out)
    finally:
        srv.close()


if __name__ == "__main__":
    main()
