"""End-to-end driver: train an LM -> MSB-quantize -> serve batched requests.

    PYTHONPATH=src python examples/train_quantize_serve.py            # tiny (CPU)
    PYTHONPATH=src python examples/train_quantize_serve.py --preset 100m --steps 300

The default preset trains a small Markov-chain LM in ~2 minutes on CPU; the
``100m`` preset is the assignment's "train a ~100M model for a few hundred
steps" configuration for real hardware. Fault tolerance is live: the run
checkpoints periodically, auto-resumes if re-launched, and drains cleanly on
SIGTERM/SIGINT (PreemptionHandler).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config, get_config
from repro.core import QuantPolicy, param_bits, quantize_params
from repro.data import MarkovStream, Prefetcher
from repro.models import Model
from repro.serve import ServeEngine
from repro.train import (AdamW, Checkpointer, OptConfig, PreemptionHandler,
                         StragglerMonitor, train_loop)


def build_model(preset):
    if preset == "tiny":
        cfg = smoke_config("qwen1.5-0.5b")
        cfg = dataclasses.replace(cfg, vocab_size=128, vocab_round=128,
                                  d_model=128, n_layers=2)
        batch, seq = 8, 64
    elif preset == "100m":
        cfg = dataclasses.replace(
            get_config("qwen1.5-0.5b"), n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=12, d_ff=2048, vocab_size=32000, head_dim=64)
        batch, seq = 32, 1024
    else:
        raise SystemExit(f"unknown preset {preset}")
    return Model(cfg), batch, seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--ckpt-dir", default="/tmp/msb_e2e_ckpt")
    args = ap.parse_args()

    model, batch, seq = build_model(args.preset)
    cfg = model.cfg
    data = MarkovStream(cfg.vocab_size, seq, batch, seed=7)
    print(f"[e2e] {args.preset}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}; chain entropy "
          f"{data.entropy():.3f} nats (loss floor)")

    opt = AdamW(OptConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps))
    ck = Checkpointer(args.ckpt_dir, keep=2)
    handler = PreemptionHandler()
    mon = StragglerMonitor()
    state, metrics = train_loop(
        model, opt, Prefetcher(iter(data)), steps=args.steps,
        rng=jax.random.PRNGKey(0), checkpointer=ck, checkpoint_every=25,
        straggler_monitor=mon, should_stop=handler.should_stop, log_every=10)
    ck.wait()
    print(f"[e2e] trained; median step {mon.median * 1e3:.1f} ms; "
          f"stragglers flagged: {len(mon.flagged)}")

    params = state["params"]
    bits_fp = param_bits(params)
    qparams, report = quantize_params(
        params, QuantPolicy(bits=4, block=64, solver="dp", min_size=4096))
    print(f"[e2e] MSB-quantized {len(report)} tensors: "
          f"{bits_fp / 8e6:.1f} MB -> {param_bits(qparams) / 8e6:.1f} MB")

    eval_batch = data.batch(10_000)
    nll_fp = float(jax.jit(model.loss)(
        params, {k: jnp.asarray(v) for k, v in eval_batch.items()})[0])
    nll_q = float(jax.jit(model.loss)(
        qparams, {k: jnp.asarray(v) for k, v in eval_batch.items()})[0])
    print(f"[e2e] held-out NLL: fp {nll_fp:.4f} | msb-4bit {nll_q:.4f} "
          f"(floor {data.entropy():.4f})")

    engine = ServeEngine(model, qparams, max_seq=seq + 32)
    prompts = jnp.asarray(
        np.stack([data.batch(20_000)["tokens"][0][:16]] * 4), jnp.int32)
    out = engine.generate(prompts, n_tokens=16, temperature=0.7)
    print(f"[e2e] served {out.shape[0]} requests x {out.shape[1]} tokens "
          f"from the 4-bit model: {np.asarray(out[0])[:8]}...")


if __name__ == "__main__":
    main()
