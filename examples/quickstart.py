"""Quickstart: MSB dynamic-grouping quantization in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Quantizes a synthetic LLM-like weight matrix with every solver in the
framework + the baselines the paper compares against, and prints the
reconstruction-MSE / storage table (paper Table 2 structure).
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (baselines, dequantize, quantize_blockwise,
                        quantize_pertensor, reconstruction_mse,
                        storage_bits_per_weight)


def main():
    rng = np.random.default_rng(0)
    w = rng.standard_t(4, size=(512, 2048)).astype(np.float32)
    w *= 0.02 / w.std()
    print(f"weight: {w.shape}, heavy-tailed (student-t df=4), std 0.02\n")

    rows = []

    def add(name, fn, bits_eff=None):
        t0 = time.perf_counter()
        out = fn()
        t = time.perf_counter() - t0
        if hasattr(out, "codes"):
            mse = float(reconstruction_mse(w, dequantize(out)))
            bits = storage_bits_per_weight(out)
        else:
            mse = float(reconstruction_mse(w, out))
            bits = bits_eff
        rows.append((name, bits, t, mse))

    # --- 4-bit block-wise (the paper's primary setting) ---
    add("MSB-DP  (exact, vmapped)", lambda: quantize_blockwise(w, 4, solver="dp"))
    add("MSB-WGM (paper Alg.3, CPU)", lambda: quantize_blockwise(w, 4, solver="wgm"))
    add("RTN 4b/64", lambda: baselines.rtn_quantize(w, 4, 64), 6.0)
    add("NF4 (BnB)", lambda: baselines.nf4_quantize(w, 4, 64), 4.5)
    add("HQQ 4b/64", lambda: baselines.hqq_quantize(w, 4, 64), 8.25)
    add("GPTQ 4b/64 (synthetic calib)", lambda: baselines.gptq_quantize(w, 4, 64), 4.5)

    # --- 6-bit per-tensor ---
    add("MSB-WDP 6b per-tensor", lambda: quantize_pertensor(w, 6, solver="wdp"))
    add("RTN 6b per-tensor", lambda: baselines.rtn_quantize(w, 6, -1), 6.0)

    print(f"{'method':32s} {'bits/wt':>8s} {'time':>8s} {'MSE':>12s}")
    for name, bits, t, mse in rows:
        b = f"{bits:.2f}" if bits else "-"
        print(f"{name:32s} {b:>8s} {t:7.2f}s {mse:12.5f}")

    print("\nMSB-DP is the exact optimum of the paper's objective — "
          "vmapped over blocks it runs in milliseconds on TPU where the "
          "paper's CPU oracle needed hours (Table 4).")


if __name__ == "__main__":
    main()
