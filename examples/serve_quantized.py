"""Serve a model with MSB 4-bit weights and compare against full precision.

    PYTHONPATH=src python examples/serve_quantized.py

Loads (here: inits + briefly trains) a small LM, quantizes-on-load with the
framework policy, and serves the same batched requests from the fp and the
4-bit engines, reporting agreement + the effective compression. The same
4-bit model then serves a staggered request stream through the
continuous-batching engine (paged KV cache, chunked prefill; DESIGN.md §8),
which must reproduce the static engine's greedy tokens exactly — here with
``execution="packed"``: weights rewritten once at load into the kernel
storage layout (two 4-bit codes per byte; DESIGN.md Sec. 9). On TPU that
layout feeds the Pallas fused dequant-matmul kernel; on CPU the jnp
fallback replays simulation math, so tokens stay identical either way.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core import QuantPolicy, param_bits, quantize_params
from repro.data import MarkovStream
from repro.models import Model
from repro.serve import ContinuousEngine, ServeEngine
from repro.train import AdamW, OptConfig, train_loop


def main():
    cfg = smoke_config("gemma2-2b")   # sliding-window + softcap features live
    cfg = dataclasses.replace(cfg, vocab_size=128, vocab_round=128,
                              d_model=128)
    model = Model(cfg)
    data = MarkovStream(cfg.vocab_size, 48, 8, seed=11)
    opt = AdamW(OptConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    state, _ = train_loop(model, opt, iter(data), steps=50,
                          rng=jax.random.PRNGKey(0), log_every=25)
    params = state["params"]

    qparams, report = quantize_params(
        params, QuantPolicy(bits=4, block=64, solver="dp", min_size=2048))
    print(f"[serve] quantized {len(report)} tensors; "
          f"{param_bits(params) / 8e6:.2f} MB -> "
          f"{param_bits(qparams) / 8e6:.2f} MB")

    prompts = jnp.asarray(data.batch(999)["tokens"][:4, :12], jnp.int32)
    eng_fp = ServeEngine(model, params, max_seq=96)
    eng_q = ServeEngine(model, qparams, max_seq=96)
    out_fp = np.asarray(eng_fp.generate(prompts, n_tokens=24))
    out_q = np.asarray(eng_q.generate(prompts, n_tokens=24))
    agree = (out_fp == out_q).mean()
    print(f"[serve] greedy-token agreement fp vs msb-4bit: {agree:.1%}")
    toks = jnp.asarray(data.batch(1234)["tokens"], jnp.int32)
    print(f"[serve] held-out NLL: fp {eng_fp.score(toks):.4f} | "
          f"4-bit {eng_q.score(toks):.4f} | floor {data.entropy():.4f}")

    # continuous batching: the same 4 requests arrive staggered; outputs
    # must match the static engine's greedy tokens row for row
    ce = ContinuousEngine(model, qparams, max_batch=4, page_size=8,
                          num_pages=64, max_seq=40, prefill_chunk=8,
                          execution="packed")
    arrivals = [0, 2, 4, 6]
    done, i, t = {}, 0, 0
    while i < len(arrivals) or ce.scheduler.has_work:
        while i < len(arrivals) and arrivals[i] <= t:
            ce.submit(np.asarray(prompts[i]), 24)
            i += 1
        ce.step()
        done.update(ce.collect())
        t += 1
    match = all((done[i] == out_q[i]).all() for i in range(4))
    print(f"[serve] continuous-batching vs static (4-bit, staggered "
          f"arrivals): token-identical={match} "
          f"steps={ce.n_steps} preemptions={ce.scheduler.n_preemptions}")


if __name__ == "__main__":
    main()
