"""Distributed MSB quantization: mesh-sharded solver, zero communication.

    PYTHONPATH=src python examples/distributed_quantize.py

Quantization is embarrassingly parallel across 64-element blocks, so on a
mesh each device solves exactly the blocks of its local weight shard. The
solve runs under ``shard_map`` — the compiled module is verified below to
contain **no collectives**. This is how arctic-480b's ~7.3e9 blocks get
quantized in one pass across a pod instead of weeks on a CPU
(DESIGN.md Sec. 2).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import re
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import quantize_blockwise, reconstruction_mse
from repro.core.quantize import QTensor, dequantize

shard_map = getattr(jax, "shard_map", None)
if shard_map is None:  # pragma: no cover
    from jax.experimental.shard_map import shard_map


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = np.random.default_rng(0)
    w = rng.standard_t(4, size=(512, 1024)).astype(np.float32) * 0.02
    w_sharded = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
    print(f"weight {w.shape} sharded over {dict(mesh.shape)}")

    def local_solve(ws):  # runs per shard — no communication
        q = quantize_blockwise(ws, bits=4, block=64, solver="dp")
        return q.codes, q.scales

    # check_vma off: the DP backtrack scan starts from constant carries,
    # which the varying-axes checker can't classify (solver is shard-pure)
    solve = jax.jit(shard_map(
        local_solve, mesh=mesh, in_specs=P("data", "model"),
        out_specs=(P("data", "model"), P(("data", "model"), None)),
        check_vma=False))

    with mesh:
        codes, scales = solve(w_sharded)
        jax.block_until_ready(codes)
        t0 = time.perf_counter()
        codes, scales = solve(w_sharded)
        jax.block_until_ready(codes)
        t = time.perf_counter() - t0
        hlo = solve.lower(w_sharded).compile().as_text()

    colls = re.findall(r"(all-reduce|all-gather|all-to-all|"
                       r"collective-permute)\(", hlo)
    print(f"quantized {w.size / 1e6:.2f}M weights in {t * 1e3:.0f} ms "
          f"across {len(jax.devices())} devices "
          f"({w.size / 64 / t:.0f} blocks/s; scales linearly with devices)")
    print(f"collectives in the compiled solve: {len(colls)} "
          f"({'NONE — embarrassingly parallel' if not colls else colls[:5]})")
    print(f"codes sharding: {codes.sharding.spec}")

    # verify: pair each shard's codes with its scales (shard-major order)
    def shard_major(arr):
        return np.concatenate(
            [np.asarray(arr)[i * 128:(i + 1) * 128, j * 512:(j + 1) * 512]
             .reshape(-1, 64) for i in range(4) for j in range(2)])

    q = QTensor(jnp.asarray(shard_major(codes)), jnp.asarray(scales),
                4, 64, jnp.float32)
    mse = float(reconstruction_mse(shard_major(w), dequantize(q)))
    print(f"reconstruction MSE: {mse:.4f} "
          f"(exact per-block optimum of the paper's objective)")


if __name__ == "__main__":
    main()
