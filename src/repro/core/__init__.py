"""MSB dynamic-grouping quantization — the paper's core contribution."""
from .objective import (grouping_cost, group_sse, lambda_bounds,
                        lambda_from_tilde, prefix_sums, reconstruction_mse,
                        xnor_closed_form)
from .grouping import (dp_boundaries, kmeans1d_boundaries, solve_blocks,
                       solve_flat, windowed_dp_boundaries)
from .quantize import (DQScales, KVQuantSpec, PackedQTensor, QTensor,
                       dequantize, double_quantize, kv_dequantize_pages,
                       kv_native_page_bytes, kv_quantize_pages,
                       pack_codes_int4, pack_qtensor, packed_dequantize,
                       packed_gather, quantize_blockwise, quantize_pertensor,
                       storage_bits_per_weight, unpack_codes_int4)
from .policy import (QuantPolicy, dequantize_params, pack_params, param_bits,
                     quantize_params, tp_localize, tp_partition_params)
from . import baselines, reference

__all__ = [
    "KVQuantSpec", "PackedQTensor", "QTensor", "QuantPolicy", "baselines",
    "dequantize", "dequantize_params", "double_quantize", "dp_boundaries",
    "grouping_cost", "group_sse", "kmeans1d_boundaries", "kv_dequantize_pages",
    "kv_native_page_bytes", "kv_quantize_pages", "lambda_bounds",
    "lambda_from_tilde",
    "pack_codes_int4", "pack_params", "pack_qtensor", "packed_dequantize",
    "packed_gather", "param_bits", "prefix_sums", "quantize_blockwise",
    "quantize_params", "quantize_pertensor", "reconstruction_mse",
    "reference", "solve_blocks", "solve_flat", "storage_bits_per_weight",
    "tp_localize", "tp_partition_params", "unpack_codes_int4",
    "windowed_dp_boundaries", "xnor_closed_form", "DQScales",
]
