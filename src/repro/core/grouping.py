"""Vectorized JAX solvers for the MSB dynamic-grouping objective.

TPU-native adaptation of the paper's CPU heap solvers (DESIGN.md Sec. 2):

* ``dp_boundaries``      — the *exact* DP (paper Alg. 1) as a dense masked-min
                           reduction. O(g n^2) fused vector ops, branch-free,
                           vmappable over millions of 64-element blocks. On
                           TPU this runs the paper's 8-hour oracle per matrix
                           in well under a second — and it is exact.
* ``kmeans1d_boundaries``— per-tensor solver: within-group-variance
                           minimization over sorted 1-D magnitudes == 1-D
                           k-means with contiguous clusters. Equal-range
                           binning init (paper Alg. 4 idea) + vectorized
                           Lloyd sweeps (deterministic, objective
                           non-increasing) instead of the stochastic local
                           search.

All functions operate on *sorted magnitudes* and return boundary indices
``b`` of length ``g+1`` with ``b[0] = 0``, ``b[g] = n``; group ``z`` covers
sorted positions ``[b[z], b[z+1])``. Empty trailing groups have repeated
boundaries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .objective import prefix_sums

_NEG = -1
_INF = jnp.inf


def _cost_matrix(v, lam=0.0):
    """C[i, j] = sse of sorted interval [i, j) (+ lam/(j-i)); +inf for i >= j."""
    n = v.shape[0]
    s1, s2 = prefix_sums(v)
    idx = jnp.arange(n + 1)
    m = (idx[None, :] - idx[:, None]).astype(v.dtype)
    d1 = s1[None, :] - s1[:, None]
    d2 = s2[None, :] - s2[:, None]
    msafe = jnp.maximum(m, 1.0)
    c = d2 - d1 * d1 / msafe
    if lam:
        c = c + lam / msafe
    return jnp.where(m >= 1.0, c, _INF)


def dp_boundaries(v, g, lam=0.0, choose_k=False):
    """Exact dynamic-grouping DP on sorted magnitudes ``v`` (paper Alg. 1).

    Returns (boundaries, cost). ``g`` is static (the 2^{b-1} codebook size).
    With ``choose_k`` the regularized objective picks k* <= g (Eq. 2);
    otherwise exactly g groups are used (fixed-codebook b-bit setting).
    """
    n = v.shape[0]
    C = _cost_matrix(v, lam)
    # D_k[j] = min cost of first j elements in exactly k groups.
    D = C[0]                       # k = 1
    args = [jnp.zeros(n + 1, jnp.int32)]
    finals = [D[n]]
    for _ in range(1, g):
        M = D[:, None] + C         # (n+1, n+1); invalid entries are +inf
        A = jnp.argmin(M, axis=0).astype(jnp.int32)
        D = jnp.min(M, axis=0)
        args.append(A)
        finals.append(D[n])
    args = jnp.stack(args)          # (g, n+1); args[k-1] = split table for k groups
    finals = jnp.stack(finals)      # (g,)
    if choose_k:
        k_star = jnp.argmin(finals).astype(jnp.int32) + 1
    else:
        k_star = jnp.int32(min(g, n))
    cost = finals[k_star - 1]

    # Vectorized backtrack: walk k = k_star..1 setting b[k-1] = A_k[b[k]].
    def step(carry, _):
        j, k, b = carry
        active = k >= 1
        j_new = jnp.where(active, args[jnp.maximum(k - 1, 0), j], j)
        b = jnp.where(active, b.at[jnp.maximum(k - 1, 0)].set(j_new), b)
        return (jnp.where(active, j_new, j), k - 1, b), None

    b0 = jnp.full((g + 1,), n, dtype=jnp.int32).at[0].set(0)
    (_, _, bounds), _ = jax.lax.scan(step, (jnp.int32(n), k_star, b0), None, length=g)
    bounds = bounds.at[0].set(0)
    return bounds, cost


def kmeans1d_boundaries(v, g, iters=30):
    """Vectorized Lloyd iterations on sorted magnitudes (per-tensor solver).

    Runs from both an equal-range init (paper Alg. 4) and an equal-mass
    (quantile) init and keeps the lower-objective solution — Lloyd is a
    local method and the two inits fail on different distributions
    (equal-range on heavy heads, equal-mass on heavy tails).
    """
    n = v.shape[0]
    s1, s2 = prefix_sums(v)
    lo, hi = v[0], v[-1]

    def lloyd(b):
        def body(_, b):
            bf = jnp.concatenate([jnp.zeros(1, jnp.int32), b,
                                  jnp.full((1,), n, jnp.int32)])
            cnt = (bf[1:] - bf[:-1]).astype(v.dtype)
            sums = s1[bf[1:]] - s1[bf[:-1]]
            c = sums / jnp.maximum(cnt, 1.0)
            # empty clusters inherit their left boundary's value; keep order
            fallback = v[jnp.clip(bf[:-1], 0, n - 1)]
            c = jnp.where(cnt > 0, c, fallback)
            c = jax.lax.associative_scan(jnp.maximum, c)
            mids = 0.5 * (c[:-1] + c[1:])
            return jnp.searchsorted(v, mids).astype(jnp.int32)

        b = jax.lax.fori_loop(0, iters, body, b)
        return jnp.concatenate([jnp.zeros(1, jnp.int32), b,
                                jnp.full((1,), n, jnp.int32)])

    def cost(bounds):
        cnt = (bounds[1:] - bounds[:-1]).astype(v.dtype)
        d1 = s1[bounds[1:]] - s1[bounds[:-1]]
        d2 = s2[bounds[1:]] - s2[bounds[:-1]]
        sse = d2 - jnp.where(cnt > 0, d1 * d1 / jnp.maximum(cnt, 1.0), 0.0)
        return jnp.sum(jnp.where(cnt > 0, sse, 0.0))

    edges = lo + (hi - lo) * jnp.arange(1, g, dtype=v.dtype) / g
    b_range = lloyd(jnp.searchsorted(v, edges).astype(jnp.int32))
    b_mass = lloyd((jnp.arange(1, g) * n // g).astype(jnp.int32))
    return jnp.where(cost(b_range) <= cost(b_mass), b_range, b_mass)


def windowed_dp_boundaries(v, g, n_windows=1024, lam=0.0, refine_iters=8):
    """Windowed exact DP (beyond-paper per-tensor solver; DESIGN.md Sec. 2).

    WGM's coarsening idea executed optimally: aggregate the sorted
    magnitudes into ``n_windows`` equal-count windows, run the *weighted*
    exact DP over window statistics — O(g W^2) fused vector ops — then
    polish boundaries with a few Lloyd sweeps at element granularity.
    Dominates plain Lloyd (which hits ~1.3x-optimal local minima on
    half-normal data) at a tiny fraction of the full DP's cost.
    """
    n = v.shape[0]
    w = min(n_windows, n)
    k = -(-n // w)
    pad = w * k - n
    vp = jnp.concatenate([v, jnp.full((pad,), v[-1], v.dtype)])
    mask = (jnp.arange(w * k) < n).astype(v.dtype).reshape(w, k)
    vw = vp.reshape(w, k)
    cnt = jnp.sum(mask, axis=1)
    s = jnp.sum(vw * mask, axis=1)
    q = jnp.sum(vw * vw * mask, axis=1)
    z = jnp.zeros((1,), v.dtype)
    C = jnp.concatenate([z, jnp.cumsum(cnt)])
    S = jnp.concatenate([z, jnp.cumsum(s)])
    Q = jnp.concatenate([z, jnp.cumsum(q)])

    m = C[None, :] - C[:, None]
    d1 = S[None, :] - S[:, None]
    d2 = Q[None, :] - Q[:, None]
    msafe = jnp.maximum(m, 1.0)
    cost = d2 - d1 * d1 / msafe
    if lam:
        cost = cost + lam / msafe
    Cmat = jnp.where(m >= 1.0, cost, _INF)

    D = Cmat[0]
    args = [jnp.zeros(w + 1, jnp.int32)]
    for _ in range(1, g):
        M = D[:, None] + Cmat
        args.append(jnp.argmin(M, axis=0).astype(jnp.int32))
        D = jnp.min(M, axis=0)
    args = jnp.stack(args)

    def step(carry, _):
        j, kk, b = carry
        active = kk >= 1
        j_new = jnp.where(active, args[jnp.maximum(kk - 1, 0), j], j)
        b = jnp.where(active, b.at[jnp.maximum(kk - 1, 0)].set(j_new), b)
        return (jnp.where(active, j_new, j), kk - 1, b), None

    b0 = jnp.full((g + 1,), w, dtype=jnp.int32).at[0].set(0)
    (_, _, wb), _ = jax.lax.scan(step, (jnp.int32(w), jnp.int32(min(g, w)),
                                        b0), None, length=g)
    wb = wb.at[0].set(0)
    bounds = jnp.minimum(wb * k, n).astype(jnp.int32)

    # Lloyd polish at element granularity from the near-optimal init
    if refine_iters:
        s1, _ = prefix_sums(v)

        def body(_, b):
            bf = b
            cnt = (bf[1:] - bf[:-1]).astype(v.dtype)
            sums = s1[bf[1:]] - s1[bf[:-1]]
            c = sums / jnp.maximum(cnt, 1.0)
            fallback = v[jnp.clip(bf[:-1], 0, n - 1)]
            c = jnp.where(cnt > 0, c, fallback)
            c = jax.lax.associative_scan(jnp.maximum, c)
            mids = 0.5 * (c[:-1] + c[1:])
            inner = jnp.searchsorted(v, mids).astype(jnp.int32)
            return jnp.concatenate([jnp.zeros(1, jnp.int32), inner,
                                    jnp.full((1,), n, jnp.int32)])

        bounds = jax.lax.fori_loop(0, refine_iters, body, bounds)
    return bounds


def boundaries_to_levels(bounds, n):
    """Level id per sorted position: level(p) = #{z in 1..g-1 : b[z] <= p}."""
    pos = jnp.arange(n)
    return jnp.searchsorted(bounds[1:-1], pos, side="right").astype(jnp.int32)


def scales_from_boundaries(v, bounds):
    """alpha_z = mean(|group z|); 0 for empty groups."""
    s1, _ = prefix_sums(v)
    cnt = (bounds[1:] - bounds[:-1]).astype(v.dtype)
    sums = s1[bounds[1:]] - s1[bounds[:-1]]
    return jnp.where(cnt > 0, sums / jnp.maximum(cnt, 1.0), 0.0)


@functools.partial(jax.jit, static_argnames=("g", "method", "choose_k", "iters"))
def solve_flat(a_flat, g, method="dp", lam=0.0, choose_k=False, iters=30):
    """Solve MSB grouping for one flat vector of weights.

    Returns (levels, scales) in the *original* element order:
      levels int32 in [0, g), scales (g,) f32; dequant = sign(a)*scales[levels].
    """
    a = a_flat.astype(jnp.float32)
    mags = jnp.abs(a)
    order = jnp.argsort(mags)
    v = mags[order]
    if method == "dp":
        bounds, _ = dp_boundaries(v, g, lam=lam, choose_k=choose_k)
    elif method == "kmeans":
        bounds = kmeans1d_boundaries(v, g, iters=iters)
    elif method == "wdp":
        bounds = windowed_dp_boundaries(v, g, lam=lam)
    else:
        raise ValueError(f"unknown solver method: {method}")
    levels_sorted = boundaries_to_levels(bounds, v.shape[0])
    scales = scales_from_boundaries(v, bounds)
    levels = jnp.zeros_like(levels_sorted).at[order].set(levels_sorted)
    return levels, scales


def solve_blocks(blocks, g, method="dp", lam=0.0, iters=30, chunk=4096):
    """vmapped solver over a (n_blocks, block_size) batch.

    ``chunk`` bounds peak memory of the (chunk, n+1, n+1) DP cost tensors via
    ``lax.map`` over block chunks — the HBM->VMEM streaming structure a TPU
    wants. Returns (levels (n_blocks, bs) int32, scales (n_blocks, g) f32).
    """
    nb, bs = blocks.shape
    single = functools.partial(_solve_block_single, g=g, method=method,
                               lam=lam, iters=iters)
    vsolve = jax.vmap(single)
    if nb <= chunk:
        return vsolve(blocks)
    pad = (-nb) % chunk
    padded = jnp.concatenate([blocks, jnp.zeros((pad, bs), blocks.dtype)])
    padded = padded.reshape(-1, chunk, bs)
    levels, scales = jax.lax.map(vsolve, padded)
    levels = levels.reshape(-1, bs)[:nb]
    scales = scales.reshape(-1, scales.shape[-1])[:nb]
    return levels, scales


def _solve_block_single(block, g, method, lam, iters):
    a = block.astype(jnp.float32)
    mags = jnp.abs(a)
    order = jnp.argsort(mags)
    v = mags[order]
    if method == "dp":
        bounds, _ = dp_boundaries(v, g, lam=lam)
    elif method == "kmeans":
        bounds = kmeans1d_boundaries(v, g, iters=iters)
    elif method == "wdp":
        bounds = windowed_dp_boundaries(v, g, lam=lam)
    else:
        raise ValueError(f"unknown solver method: {method}")
    levels_sorted = boundaries_to_levels(bounds, v.shape[0])
    scales = scales_from_boundaries(v, bounds)
    levels = jnp.zeros_like(levels_sorted).at[order].set(levels_sorted)
    return levels, scales
