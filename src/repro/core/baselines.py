"""Baseline weight-only PTQ methods the paper compares against (Sec. 4.1.2).

* RTN  — round-to-nearest with symmetric absmax scaling (per-block/tensor)
* BnB  — blockwise NF4 (normal-float quantile codebook + absmax), the
         bitsandbytes 4-bit format
* HQQ  — calibration-free half-quadratic zero-point optimization
* GPTQ — calibration-based second-order sequential rounding (mini
         implementation with synthetic calibration activations)

All return a dequantized bf16/f32 tensor (the paper's simulated-quantization
protocol) so benchmark tables compare reconstruction quality directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# RTN
# ---------------------------------------------------------------------------

def rtn_quantize(w, bits=4, block=64, symmetric=True):
    """Round-to-nearest. block=-1 -> per-tensor."""
    w = jnp.asarray(w, jnp.float32)
    shape = w.shape
    x = w.reshape(1, -1) if block == -1 else w.reshape(-1, block)
    if symmetric:
        qmax = 2.0 ** (bits - 1) - 1
        scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / qmax
        scale = jnp.where(scale == 0, 1.0, scale)
        q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
        out = q * scale
    else:
        qmax = 2.0 ** bits - 1
        lo = jnp.min(x, axis=1, keepdims=True)
        hi = jnp.max(x, axis=1, keepdims=True)
        scale = jnp.where(hi > lo, (hi - lo) / qmax, 1.0)
        q = jnp.clip(jnp.round((x - lo) / scale), 0, qmax)
        out = q * scale + lo
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# BnB-style NF4 (and NF-k generalization via normal quantiles)
# ---------------------------------------------------------------------------

# bitsandbytes NF4 codebook (Dettmers et al., QLoRA App. E)
_NF4 = np.array([
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0], dtype=np.float32)


def _normal_float_codebook(bits):
    if bits == 4:
        return jnp.asarray(_NF4)
    # general NF-k: quantiles of N(0,1) normalized to [-1, 1]
    from math import erf, sqrt

    def ppf(p):  # inverse CDF via bisection (offline, tiny)
        lo_, hi_ = -10.0, 10.0
        for _ in range(80):
            mid = 0.5 * (lo_ + hi_)
            if 0.5 * (1 + erf(mid / sqrt(2))) < p:
                lo_ = mid
            else:
                hi_ = mid
        return 0.5 * (lo_ + hi_)

    n = 2 ** bits
    offset = 0.9677083  # bnb convention
    neg = [ppf(offset * (1 - i / (n // 2)) + (1 - offset) * 0.5) for i in range(n // 2)]
    pos = [ppf(0.5 + (0.5 * offset) * (i / (n - n // 2 - 1))) for i in range(n - n // 2)]
    cb = np.array(sorted(set([x / max(abs(min(neg)), abs(max(pos))) for x in neg + pos])))
    if cb.size < n:
        cb = np.concatenate([cb, [1.0] * (n - cb.size)])
    return jnp.asarray(cb[:n], jnp.float32)


def nf4_quantize(w, bits=4, block=64):
    """Blockwise normal-float quantization (absmax scale per block)."""
    w = jnp.asarray(w, jnp.float32)
    shape = w.shape
    x = w.reshape(1, -1) if block == -1 else w.reshape(-1, block)
    cb = _normal_float_codebook(bits)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    amax = jnp.where(amax == 0, 1.0, amax)
    xn = x / amax
    idx = jnp.argmin(jnp.abs(xn[..., None] - cb[None, None, :]), axis=-1)
    return (cb[idx] * amax).reshape(shape)


# ---------------------------------------------------------------------------
# HQQ (Badri & Shaji 2023) — half-quadratic zero-point optimization
# ---------------------------------------------------------------------------

def hqq_quantize(w, bits=4, block=64, iters=20, lp_norm=0.7, beta=10.0,
                 kappa=1.01):
    """Calibration-free HQQ: argmin_{z} ||W - s(Q - z)||_p via half-quadratic
    splitting with a generalized soft-threshold prox (official formulation,
    axis-grouped). block=-1 -> per-tensor.
    """
    w = jnp.asarray(w, jnp.float32)
    shape = w.shape
    x = w.reshape(1, -1) if block == -1 else w.reshape(-1, block)
    qmax = 2.0 ** bits - 1
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.where(hi > lo, (hi - lo) / qmax, 1.0)
    zero = -lo / scale

    def shrink(e, b_):
        return jnp.sign(e) * jnp.maximum(
            jnp.abs(e) - (lp_norm / b_) * jnp.abs(e) ** (lp_norm - 1), 0.0)

    b_ = beta
    for _ in range(iters):
        q = jnp.clip(jnp.round(x / scale + zero), 0, qmax)
        wq = scale * (q - zero)
        e = shrink(x - wq, b_)
        zero = jnp.mean(q - (x - e) / scale, axis=1, keepdims=True)
        b_ *= kappa
    q = jnp.clip(jnp.round(x / scale + zero), 0, qmax)
    return (scale * (q - zero)).reshape(shape)


# ---------------------------------------------------------------------------
# mini-GPTQ (Frantar et al. 2022) — calibration-based, for baseline tables
# ---------------------------------------------------------------------------

def gptq_quantize(w, bits=4, block=64, n_calib=128, percdamp=0.01, seed=0,
                  calib=None):
    """Sequential column-wise quantization with Hessian error compensation.

    W: (out, in). Calibration activations X: (n_calib, in) — synthetic
    N(0,1) by default (no calibration data exists in this offline
    environment; documented in DESIGN.md). Quantization grid = symmetric RTN
    per `block` along the input dim, matching the default GPTQ setup.
    """
    W = np.asarray(w, dtype=np.float64)
    out_dim, in_dim = W.shape
    rng = np.random.default_rng(seed)
    X = np.asarray(calib, np.float64) if calib is not None else rng.standard_normal((n_calib, in_dim))
    H = 2.0 * X.T @ X
    damp = percdamp * np.mean(np.diag(H)) + 1e-8
    H[np.diag_indices_from(H)] += damp
    # H^{-1} upper-Cholesky as in the reference implementation
    Hinv = np.linalg.cholesky(np.linalg.inv(H), upper=True)

    qmax = 2.0 ** (bits - 1) - 1
    Q = np.zeros_like(W)
    Err = np.zeros_like(W)
    nblk = in_dim if block == -1 else block
    for b0 in range(0, in_dim, nblk):
        b1 = min(b0 + nblk, in_dim)
        Wb = W[:, b0:b1].copy()
        scale = np.abs(Wb).max(axis=1, keepdims=True) / qmax
        scale[scale == 0] = 1.0
        for j in range(b0, b1):
            c = j - b0
            d = Hinv[j, j]
            col = Wb[:, c]
            q = np.clip(np.round(col / scale[:, 0]), -qmax - 1, qmax) * scale[:, 0]
            err = (col - q) / d
            Q[:, j] = q
            if j + 1 < b1:
                Wb[:, c + 1:] -= np.outer(err, Hinv[j, j + 1:b1])
            Err[:, j] = err
        if b1 < in_dim:
            W[:, b1:] -= Err[:, b0:b1] @ Hinv[b0:b1, b1:]
    return jnp.asarray(Q, jnp.float32)


# ---------------------------------------------------------------------------
# XNOR baselines used in the paper's Appendix D figures
# ---------------------------------------------------------------------------

def xnor_quantize(w):
    """1 scale for the whole tensor (Rastegari et al. 2016)."""
    w = jnp.asarray(w, jnp.float32)
    alpha = jnp.mean(jnp.abs(w))
    return alpha * jnp.sign(w)


def blocked_xnor_quantize(w, block=64):
    w = jnp.asarray(w, jnp.float32)
    x = w.reshape(-1, block)
    alpha = jnp.mean(jnp.abs(x), axis=1, keepdims=True)
    return (alpha * jnp.sign(x)).reshape(w.shape)
