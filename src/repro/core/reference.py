"""Paper-faithful NumPy reference solvers (Algorithms 1-4).

These follow the paper's pseudocode (Sec 3.3 + Appendix B) line-by-line,
including the heap + ignore-array bookkeeping of Algorithms 2/3 and the
equal-range binning + stochastic local boundary search of Algorithm 4. They
run on CPU — the paper's own deployment mode ("CPU based solver") — and serve
as (a) oracles for property tests, (b) the paper-faithful baseline rows in
the benchmark tables, (c) an offline quantization path.

All solvers return ``(boundaries, order)`` where ``order`` is the argsort of
magnitudes and ``boundaries`` (length g+1, b[0]=0, b[-1]=n) delimits groups of
the *sorted* magnitudes. ``levels_from_boundaries`` converts back to per-
element level ids in the original layout.
"""
from __future__ import annotations

import heapq
import numpy as np


def _prep(a):
    v = np.abs(np.asarray(a, dtype=np.float64).ravel())
    order = np.argsort(v, kind="stable")
    return v[order], order


def _interval_sse(s1, s2, i, j):
    m = j - i
    if m <= 0:
        return 0.0
    d1 = s1[j] - s1[i]
    return (s2[j] - s2[i]) - d1 * d1 / m


def _psums(v):
    s1 = np.concatenate([[0.0], np.cumsum(v)])
    s2 = np.concatenate([[0.0], np.cumsum(v * v)])
    return s1, s2


# ---------------------------------------------------------------------------
# Algorithm 1: Dynamic Grouping (exact DP oracle)
# ---------------------------------------------------------------------------

def dynamic_grouping(a, max_groups, lam=0.0, choose_k=False):
    """Exact DP (Alg. 1). O(g * n^2); use only on small instances.

    With ``choose_k`` the number of groups g* <= max_groups is chosen by the
    lam-regularized objective (paper Eq. 2); otherwise exactly ``max_groups``
    groups are used (the fixed-codebook b-bit setting of Sec 4.1).
    """
    v, order = _prep(a)
    n = v.size
    g = min(max_groups, n)
    s1, s2 = _psums(v)
    INF = np.inf
    dp = np.full((g + 1, n + 1), INF)
    arg = np.zeros((g + 1, n + 1), dtype=np.int64)
    dp[0, 0] = 0.0
    for k in range(1, g + 1):
        for j in range(k, n + 1):
            best, bi = INF, k - 1
            for i in range(k - 1, j):
                c = dp[k - 1, i] + _interval_sse(s1, s2, i, j) + (lam / (j - i) if lam else 0.0)
                if c < best:
                    best, bi = c, i
            dp[k, j] = best
            arg[k, j] = bi
    if choose_k:
        k_star = int(np.argmin(dp[1:, n])) + 1
    else:
        k_star = g
    # backtrack
    bounds = [n]
    j = n
    for k in range(k_star, 0, -1):
        j = int(arg[k, j])
        bounds.append(j)
    boundaries = np.array(bounds[::-1], dtype=np.int64)
    return boundaries, order, float(dp[k_star, n])


# ---------------------------------------------------------------------------
# Heap-merge machinery shared by Algorithms 2 and 3
# ---------------------------------------------------------------------------

def _greedy_merge(v, s1, s2, starts, ends, target_groups, lam=0.0):
    """Greedy adjacent merging with a min-heap + ignore (lazy-invalidation)
    bookkeeping, exactly the structure of Alg. 2/3 pseudocode.

    Heap entries are (delta_cost, start, mid, end): merging groups
    [start, mid) and [mid, end). Stale entries are lazily skipped via a
    version map keyed on the boundary ``mid``.
    """
    n_groups = len(starts)
    # doubly-linked list over group boundaries
    left = {s: None for s in starts}
    right = {}
    for idx in range(n_groups - 1):
        right[starts[idx]] = starts[idx + 1]
        left[starts[idx + 1]] = starts[idx]
    right[starts[-1]] = None
    end_of = dict(zip(starts, ends))

    def cost(i, j):
        c = _interval_sse(s1, s2, i, j)
        if lam:
            c += lam / (j - i)
        return c

    def merge_delta(a_start, b_start):
        b_end = end_of[b_start]
        return (cost(a_start, b_end)
                - cost(a_start, end_of[a_start])
                - cost(b_start, b_end))

    heap = []
    alive = set(starts)
    for s in starts:
        r = right[s]
        if r is not None:
            heapq.heappush(heap, (merge_delta(s, r), s, r))

    cur_groups = n_groups
    while cur_groups > target_groups and heap:
        delta, a_s, b_s = heapq.heappop(heap)
        # lazy invalidation: entry stale if either group vanished or the
        # adjacency changed ("ignore array" of the pseudocode)
        if a_s not in alive or b_s not in alive or right.get(a_s) != b_s:
            continue
        # merge b into a
        end_of[a_s] = end_of[b_s]
        alive.discard(b_s)
        nr = right[b_s]
        right[a_s] = nr
        if nr is not None:
            left[nr] = a_s
        cur_groups -= 1
        # push two new neighbouring merges (updates); old ones invalidated lazily
        l = left.get(a_s)
        if l is not None:
            heapq.heappush(heap, (merge_delta(l, a_s), l, a_s))
        if nr is not None:
            heapq.heappush(heap, (merge_delta(a_s, nr), a_s, nr))

    bounds = sorted(alive) + [len(v)]
    return np.array(bounds, dtype=np.int64)


# ---------------------------------------------------------------------------
# Algorithm 2: Greedy Grouping (singleton init)
# ---------------------------------------------------------------------------

def greedy_grouping(a, max_groups, lam=0.0):
    v, order = _prep(a)
    s1, s2 = _psums(v)
    starts = list(range(v.size))
    ends = [s + 1 for s in starts]
    boundaries = _greedy_merge(v, s1, s2, starts, ends, max_groups, lam)
    return boundaries, order


# ---------------------------------------------------------------------------
# Algorithm 3: Windowed Greedy Merging (window-k init)
# ---------------------------------------------------------------------------

def windowed_greedy_merging(a, max_groups, window, lam=0.0):
    v, order = _prep(a)
    s1, s2 = _psums(v)
    n = v.size
    starts = list(range(0, n, window))
    ends = [min(s + window, n) for s in starts]
    if len(starts) <= max_groups:
        # degenerate case noted in Appendix D: window >= n collapses to XNOR
        boundaries = np.array(starts + [n], dtype=np.int64)
        return boundaries, order
    boundaries = _greedy_merge(v, s1, s2, starts, ends, max_groups, lam)
    return boundaries, order


# ---------------------------------------------------------------------------
# Algorithm 4: Local Optimizing Windowed Greedy Merging
# ---------------------------------------------------------------------------

def wgm_local_opt(a, max_groups, n_bins=256, local_range=8, max_iters=12,
                  tol=0.0, lam=0.0, seed=0):
    """Equal-range binning init + greedy merge + stochastic local search."""
    v, order = _prep(a)
    n = v.size
    s1, s2 = _psums(v)
    lo, hi = v[0], v[-1]
    if hi <= lo:
        return np.array([0, n], dtype=np.int64), order
    # equal-range binning over [w_min, w_max]
    delta = (hi - lo) / n_bins
    idx = np.minimum(n_bins - 1, np.floor((v - lo) / delta).astype(np.int64))
    # bin start positions (v sorted -> bins are contiguous); drop empty bins
    change = np.flatnonzero(np.diff(idx)) + 1
    starts = np.concatenate([[0], change]).tolist()
    ends = starts[1:] + [n]
    if len(starts) > max_groups:
        boundaries = _greedy_merge(v, s1, s2, starts, ends, max_groups, lam)
    else:
        boundaries = np.array(starts + [n], dtype=np.int64)

    # stochastic local boundary search (accept only improving moves)
    rng = np.random.default_rng(seed)
    b = boundaries.copy()

    def seg_cost(i, j):
        c = _interval_sse(s1, s2, i, j)
        if lam and j > i:
            c += lam / (j - i)
        return c

    no_improve = 0
    it = 0
    while it < max_iters and no_improve < 2 * max(1, len(b) - 2):
        improved = False
        for z in range(1, len(b) - 1):
            cur = b[z]
            lo_z, hi_z = b[z - 1] + 1, b[z + 1] - 1
            if hi_z < lo_z:
                continue
            cand = int(rng.integers(max(lo_z, cur - local_range),
                                    min(hi_z, cur + local_range) + 1))
            if cand == cur:
                continue
            before = seg_cost(b[z - 1], cur) + seg_cost(cur, b[z + 1])
            after = seg_cost(b[z - 1], cand) + seg_cost(cand, b[z + 1])
            if after < before - tol:
                b[z] = cand
                improved = True
        no_improve = 0 if improved else no_improve + 1
        it += 1
    return b, order


# ---------------------------------------------------------------------------
# Shared decode helpers
# ---------------------------------------------------------------------------

def levels_from_boundaries(n, boundaries):
    """Per-sorted-position group id (level) from boundary indices."""
    levels = np.zeros(n, dtype=np.int64)
    for z in range(len(boundaries) - 1):
        levels[boundaries[z]:boundaries[z + 1]] = z
    return levels


def reconstruct(a, boundaries, order, n_levels=None):
    """Dequantized tensor + (codes, scales) from a solver solution.

    scales[z] = mean(|group z|) (optimal alpha). Zero elements reconstruct to
    exactly 0 via sign() == 0 (paper's zero-loss special group).
    """
    a = np.asarray(a, dtype=np.float64)
    flat = a.ravel()
    v = np.abs(flat)[order]
    n = flat.size
    g = len(boundaries) - 1
    n_levels = n_levels or g
    levels_sorted = levels_from_boundaries(n, boundaries)
    scales = np.zeros(n_levels, dtype=np.float64)
    for z in range(g):
        i, j = boundaries[z], boundaries[z + 1]
        if j > i:
            scales[z] = v[i:j].mean()
    levels = np.empty(n, dtype=np.int64)
    levels[order] = levels_sorted
    signs = np.sign(flat)
    w_hat = (signs * scales[levels]).reshape(a.shape)
    return w_hat, levels.reshape(a.shape), scales
