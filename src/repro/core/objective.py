"""MSB optimization objective (paper Sec. 3.2, Appendix A).

The MSB objective for a grouping ``G = {A_i}`` of a weight tensor ``A``:

    cost(G) = sum_i ( |A_i| * Var(|A_i|) + lam / |A_i| )        (un-normalized)
    cost(G) = sum_i ( |A_i|/|A| * Var(|A_i|) + lam / |A_i| )    (normalized, Sec 3.4)

where ``|A_i| * Var(|A_i|) == ||A_i - alpha_i* B_i*||_2^2`` with the optimal
scale ``alpha_i* = mean(|A_i|)`` and sign matrix ``B_i* = sign(A_i)``
(Appendix A identity). All solver code works on these interval costs over the
*sorted magnitudes*, evaluated in O(1) from prefix sums.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def xnor_closed_form(a):
    """XNOR-Net closed form (Eq. 1): alpha* = ||A||_1/|A|, B* = sign(A)."""
    a = jnp.asarray(a)
    alpha = jnp.mean(jnp.abs(a))
    b = jnp.sign(a)
    return alpha, b


def group_sse(a):
    """||A - alpha* B*||^2 for a single group = |A| * Var(|A|)."""
    a = jnp.asarray(a)
    mag = jnp.abs(a)
    return jnp.sum((mag - jnp.mean(mag)) ** 2)


def prefix_sums(sorted_mags):
    """Inclusive-exclusive prefix sums s1[i] = sum(v[:i]), s2[i] = sum(v[:i]**2).

    Returns arrays of length n+1 (s[0] == 0) so interval sums over [i, j) are
    ``s[j] - s[i]``.
    """
    v = sorted_mags
    z = jnp.zeros((1,), v.dtype)
    s1 = jnp.concatenate([z, jnp.cumsum(v)])
    s2 = jnp.concatenate([z, jnp.cumsum(v * v)])
    return s1, s2


def interval_cost(i, j, s1, s2, lam=0.0, n_total=None):
    """cost of grouping sorted positions [i, j) into one group.

    sse = (s2[j]-s2[i]) - (s1[j]-s1[i])^2 / (j-i)   == |A_i| Var(|A_i|)
    plus the regularization term lam/(j-i); if ``n_total`` is given the sse is
    normalized by it (Sec 3.4 form).
    """
    m = (j - i).astype(s1.dtype) if hasattr(j - i, "astype") else float(j - i)
    d1 = s1[j] - s1[i]
    d2 = s2[j] - s2[i]
    sse = d2 - d1 * d1 / jnp.maximum(m, 1)
    if n_total is not None:
        sse = sse / n_total
    return sse + lam / jnp.maximum(m, 1)


def grouping_cost(sorted_mags, boundaries, lam=0.0, normalized=False):
    """Total MSB objective for contiguous groups given boundary indices.

    ``boundaries`` has length g+1 with b[0]=0, b[g]=n; group z covers
    [b[z], b[z+1]). Empty groups contribute zero.
    """
    v = jnp.sort(jnp.abs(jnp.ravel(sorted_mags)))
    s1, s2 = prefix_sums(v)
    b = jnp.asarray(boundaries)
    i, j = b[:-1], b[1:]
    m = (j - i).astype(v.dtype)
    d1 = s1[j] - s1[i]
    d2 = s2[j] - s2[i]
    sse = d2 - jnp.where(m > 0, d1 * d1 / jnp.maximum(m, 1), 0.0)
    n_total = v.shape[0] if normalized else None
    total = sse / n_total if n_total else sse
    reg = jnp.where(m > 0, lam / jnp.maximum(m, 1), 0.0)
    return jnp.sum(jnp.where(m > 0, total + reg, 0.0))


def lambda_bounds(a):
    """(lambda_min, lambda_max) estimates from Appendix C, Eq. (10).

    lambda_min ~ (|a_(1)| - |a_(2)|)^2 / (3n)  (two smallest sorted magnitudes)
    lambda_max ~ n (mu_1 - mu_2)^2 / 12        (half-split group means)
    """
    v = np.sort(np.abs(np.ravel(np.asarray(a))))
    n = v.size
    lam_min = (v[0] - v[1]) ** 2 / (3.0 * n) if n >= 2 else 0.0
    k = n // 2
    mu1 = float(v[:k].mean()) if k else 0.0
    mu2 = float(v[k:].mean()) if n - k else 0.0
    lam_max = n * (mu1 - mu2) ** 2 / 12.0
    return float(lam_min), float(lam_max)


def lambda_from_tilde(a, lam_tilde):
    """Monotone reparameterization lambda = Lambda(lam_tilde) in [0, 1]."""
    lo, hi = lambda_bounds(a)
    return lo + float(lam_tilde) * (hi - lo)


def reconstruction_mse(w, w_hat):
    """Frobenius MSE proxy used throughout the paper's tables."""
    w = jnp.asarray(w, jnp.float32)
    w_hat = jnp.asarray(w_hat, jnp.float32)
    return jnp.sum((w - w_hat) ** 2)
