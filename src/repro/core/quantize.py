"""MSB quantized-tensor representation and quantize/dequantize API.

Representation (paper Sec. 4.1): each weight is ``w_hat = alpha_z * s`` with
``s in {-1, 0, +1}`` (0 only for exact-zero weights — the paper's zero-loss
special group) and ``z`` indexing ``2^{b-1}`` per-group positive scales.

Stored form:
  codes : int8, same shape as w; code = sign * (level + 1), 0 for zeros
  scales: (n_blocks, 2^{b-1}) — one codebook row per block (block-wise) or a
          single row (per-tensor)

``QTensor`` is a pytree, so quantized params flow through jit / device_put /
sharding like any array. A packed int4 form (two codes per byte) feeds the
Pallas fused dequant-matmul kernel (kernels/msb_matmul).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import grouping


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QTensor:
    """MSB-quantized tensor. Behaves as a pytree (codes/scales are leaves).

    ``shard`` is static tensor-parallel metadata set by
    ``core.policy.tp_partition_params`` (None for single-device tensors):
    ``"n"`` = output (column) dim sharded, ``"k"`` = reduction (row) dim
    sharded — the consumer must ``psum`` the partial products — ``"e"`` =
    expert dim sharded, ``"v"`` = vocab rows of an unembedding table
    sharded (logits need an ``all_gather``). See DESIGN.md Sec. 10.
    """
    codes: jax.Array          # int8, logical shape of w
    scales: jax.Array         # (n_blocks, n_levels) f32/bf16
    bits: int                 # target bit-width b
    block: int                # block size (64) or -1 for per-tensor
    dtype: object             # dequantized dtype
    shard: Optional[str] = None   # None | "n" | "k" | "e" | "v"

    @property
    def shape(self):
        return self.codes.shape

    @property
    def n_levels(self):
        return self.scales.shape[-1]

    def tree_flatten(self):
        return ((self.codes, self.scales),
                (self.bits, self.block, self.dtype, self.shard))

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scales = children
        bits, block, dtype, shard = aux
        return cls(codes, scales, bits, block, dtype, shard)

    def dequantize(self):
        return dequantize(self)


def _solve(blocks, g, solver, lam, iters):
    if solver in ("dp", "kmeans", "wdp"):
        return grouping.solve_blocks(blocks, g, method=solver, lam=lam, iters=iters)
    if solver in ("wgm", "gg", "dg", "wgm_lo"):
        # paper-faithful CPU solvers (offline path) — NumPy, per block
        from . import reference
        blocks_np = np.asarray(blocks, dtype=np.float64)
        levels = np.zeros(blocks_np.shape, dtype=np.int32)
        scales = np.zeros((blocks_np.shape[0], g), dtype=np.float32)
        for i, blk in enumerate(blocks_np):
            if solver == "gg":
                b, order = reference.greedy_grouping(blk, g, lam=lam)
            elif solver == "dg":
                b, order, _ = reference.dynamic_grouping(blk, g, lam=lam)
            elif solver == "wgm_lo":
                b, order = reference.wgm_local_opt(blk, g, lam=lam)
            else:
                w = max(1, blk.size // 256) if blk.size > 4096 else 1
                b, order = reference.windowed_greedy_merging(blk, g, window=w, lam=lam)
            _, lv, sc = reference.reconstruct(blk, b, order, n_levels=g)
            levels[i] = lv
            scales[i, : len(sc)] = sc
        return jnp.asarray(levels), jnp.asarray(scales)
    raise ValueError(f"unknown solver: {solver}")


def _nearest_refine(blocks, levels, scales):
    """Re-encode each weight to the *nearest* codebook scale.

    Deployment encode given a fixed codebook {alpha_z}: interval assignment
    from a heuristic solver can be improved by nearest-scale assignment
    (~15% MSE for the greedy solvers; a no-op at the DP optimum, which is a
    Lloyd fixed point). Beyond-paper refinement, property-tested to never
    increase the error.
    """
    mags = jnp.abs(blocks)                                 # (nb, bs)
    d = jnp.abs(mags[:, :, None] - scales[:, None, :])     # (nb, bs, g)
    # empty groups carry scale 0 — exclude them unless the weight is 0
    valid = (scales > 0)[:, None, :] | (mags[:, :, None] == 0)
    d = jnp.where(valid, d, jnp.inf)
    return jnp.argmin(d, axis=-1).astype(levels.dtype)


def quantize_blockwise(w, bits=4, block=64, solver="dp", lam=0.0, iters=30,
                       scale_dtype=jnp.float32, refine=False):
    """4-bit (default) block-wise MSB quantization: 64-element groups per row.

    ``w`` is reshaped to (n_blocks, block) along its last axis (the paper's
    64-elements-per-row blocks). Last axis must be divisible by ``block``.
    ``refine`` re-encodes each weight to its nearest codebook scale
    (improves the heuristic solvers; no-op for the exact DP).
    """
    w = jnp.asarray(w)
    if w.shape[-1] % block:
        raise ValueError(f"last dim {w.shape[-1]} not divisible by block {block}")
    g = 2 ** (bits - 1)
    blocks = w.reshape(-1, block).astype(jnp.float32)
    levels, scales = _solve(blocks, g, solver, lam, iters)
    if refine:
        levels = _nearest_refine(blocks, levels, scales)
    signs = jnp.sign(blocks).astype(jnp.int8)
    codes = (signs * (levels.astype(jnp.int8) + 1)).reshape(w.shape)
    # scales keep the weight's batch dims so stacked (scan-over-layers)
    # params stay scannable: (..., last//block, g)
    scales = scales.reshape(*w.shape[:-1], w.shape[-1] // block, g)
    return QTensor(codes, scales.astype(scale_dtype), bits, block, w.dtype)


def quantize_pertensor(w, bits=6, solver="wdp", lam=0.0, iters=50,
                       scale_dtype=jnp.float32):
    """6-bit (default) per-tensor MSB quantization: one global codebook."""
    w = jnp.asarray(w)
    g = 2 ** (bits - 1)
    flat = w.reshape(1, -1).astype(jnp.float32)
    levels, scales = _solve(flat, g, solver, lam, iters)
    signs = jnp.sign(flat).astype(jnp.int8)
    codes = (signs * (levels.astype(jnp.int8) + 1)).reshape(w.shape)
    return QTensor(codes, scales.astype(scale_dtype), bits, -1, w.dtype)


def dequantize(q: QTensor):
    """w_hat = sign(code) * scales[block, |code| - 1]; exact 0 for code 0.

    Works for any scales layout (..., g): each scale row covers
    codes.size / n_rows consecutive codes (64 block-wise, a whole matrix
    per-tensor, a layer slice for stacked per-tensor params).
    """
    codes = q.codes
    g = q.scales.shape[-1]
    rows = q.scales.size // g
    blocks = codes.reshape(rows, -1)
    lv = jnp.abs(blocks).astype(jnp.int32)
    scales2d = q.scales.reshape(rows, g).astype(jnp.float32)
    mag = jnp.take_along_axis(scales2d, jnp.maximum(lv - 1, 0), axis=1)
    mag = jnp.where(lv > 0, mag, 0.0)
    out = jnp.sign(blocks).astype(jnp.float32) * mag
    return out.reshape(codes.shape).astype(q.dtype)


# ---------------------------------------------------------------------------
# int4 packing (deployment path for the Pallas kernel)
# ---------------------------------------------------------------------------

def pack_codes_int4(codes):
    """Pack 4-bit MSB codes two-per-byte.

    nibble = (sign_bit << 3) | level, level in [0, 8). Exact zeros (code 0)
    pack as level 0 / sign + (they dequantize to +alpha_0 — the packed path
    trades the zero special-case for density; see DESIGN.md Sec. 7).
    Element 2i -> low nibble, 2i+1 -> high nibble.
    """
    flat = codes.reshape(-1)
    if flat.shape[0] % 2:
        raise ValueError("packing requires an even element count")
    lv = jnp.maximum(jnp.abs(flat).astype(jnp.int32) - 1, 0)
    sign_bit = (flat < 0).astype(jnp.int32)
    nib = (sign_bit << 3) | lv
    lo, hi = nib[0::2], nib[1::2]
    return ((hi << 4) | lo).astype(jnp.uint8)


def unpack_codes_int4(packed, shape):
    packed = packed.astype(jnp.int32)
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=-1).reshape(-1)
    lv = (nib & 0x7).astype(jnp.int8) + 1
    sign = jnp.where((nib >> 3) & 1 > 0, jnp.int8(-1), jnp.int8(1))
    return (sign * lv).reshape(shape)


# ---------------------------------------------------------------------------
# Packed execution format (deployment path; DESIGN.md Sec. 9)
# ---------------------------------------------------------------------------

PACK_BLOCK = 64      # MSB block size — matches kernels/msb_matmul BLOCK
PACK_LEVELS = 8      # 2^(4-1) codebook entries per block


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedQTensor:
    """Kernel-layout MSB tensor: 4-bit codes two-per-byte + 3-D codebooks.

    Storage is in *matmul orientation* ``(..., K, N)``: ``y = x @ dequant``.
    The last dim is padded to a multiple of ``PACK_BLOCK`` at pack time
    (``n`` keeps the logical width; padded columns carry zero scales and
    dequantize to exact 0, so the matmul wrapper just slices them off).

    Two scale layouts, both one codebook row per 64-element block:
      * n-blocked (default): blocks run along N — ``scales`` is
        ``(..., K, N_pad // 64, 8)``. This is every dense (in, out) weight.
      * k-blocked (``kblocked=True``): blocks run along K — ``scales`` is
        ``(..., K // 64, N_pad, 8)``. Produced by ``transpose=True`` packing
        of a ``(V, D)`` table so the unembedding projection
        ``x (B, D) @ table^T (D, V)`` hits the fused kernel without
        re-quantizing: the original block-along-D grouping *is* the
        block-along-K grouping of the transposed operand.

    Like ``QTensor`` it is a pytree (packed/scales leaves; bits/block/dtype/
    n/kblocked/shard static), so stacked scan-over-layers params slice
    cleanly and the static aux never retraces.

    ``shard`` is static tensor-parallel metadata (see ``QTensor``): ``"n"``
    = N (output) sharded across the mesh's model axis, ``"k"`` = K (row)
    sharded (consumer psums partial products), ``"e"`` = expert dim
    sharded. Inside ``shard_map`` the leaves are per-rank slices while the
    static ``n`` still records the *global* padded width — the engines run
    ``core.policy.tp_localize`` on the local tree to rebind ``n`` to the
    local shard width before any matmul.
    """
    packed: jax.Array         # uint8 (..., K, N_pad // 2)
    scales: jax.Array         # see class docstring
    bits: int
    block: int
    dtype: object
    n: int                    # logical N before padding
    kblocked: bool = False
    shard: Optional[str] = None   # None | "n" | "k" | "e"

    @property
    def shape(self):
        return self.packed.shape[:-1] + (self.n,)

    @property
    def n_pad(self):
        return self.packed.shape[-1] * 2

    def tree_flatten(self):
        return ((self.packed, self.scales),
                (self.bits, self.block, self.dtype, self.n, self.kblocked,
                 self.shard))

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scales = children
        bits, block, dtype, n, kblocked, shard = aux
        return cls(packed, scales, bits, block, dtype, n, kblocked, shard)

    def dequantize(self):
        return packed_dequantize(self)


def _pad_last(a, to):
    pad = (-a.shape[-1]) % to
    if not pad:
        return a
    widths = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return jnp.pad(a, widths)


def pack_qtensor(q: QTensor, *, transpose=False) -> PackedQTensor:
    """QTensor -> kernel storage layout, once at load time.

    ``transpose=True`` packs a 2-D ``(V, D)`` table as its transpose
    ``(D, V)`` with k-blocked scales (unembedding orientation). Requires
    4-bit block-64 quantization; N not divisible by the block is padded
    with zero-scale columns.
    """
    if q.bits != 4 or q.block != PACK_BLOCK:
        raise ValueError(f"packing needs 4-bit block-{PACK_BLOCK} "
                         f"quantization, got {q.bits}-bit block {q.block}")
    codes, scales = q.codes, q.scales
    if transpose:
        if codes.ndim != 2:
            raise ValueError("transpose packing is for 2-D tables")
        v, d = codes.shape
        codes = codes.T                                     # (D, V)
        n = v
        codes = _pad_last(codes, PACK_BLOCK)
        # (V, D//64, 8) -> (D//64, V, 8), pad V with zero-scale columns
        scales = jnp.moveaxis(scales, 0, 1)
        pad = codes.shape[-1] - v
        if pad:
            scales = jnp.pad(scales, ((0, 0), (0, pad), (0, 0)))
    else:
        n = codes.shape[-1]
        codes = _pad_last(codes, PACK_BLOCK)
        pad_blocks = (codes.shape[-1] - n) // PACK_BLOCK
        if pad_blocks:
            widths = [(0, 0)] * (scales.ndim - 2) + [(0, pad_blocks), (0, 0)]
            scales = jnp.pad(scales, widths)
    n_pad = codes.shape[-1]
    packed = pack_codes_int4(codes).reshape(*codes.shape[:-1], n_pad // 2)
    return PackedQTensor(packed, scales, q.bits, q.block, q.dtype, n,
                         kblocked=transpose)


def _unpack_nibbles(packed):
    """uint8 (..., half) -> (level int32, sign f32), both (..., 2*half)."""
    p32 = packed.astype(jnp.int32)
    lo = p32 & 0xF
    hi = (p32 >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1],
                                               packed.shape[-1] * 2)
    level = nib & 0x7
    sign = (1 - 2 * ((nib >> 3) & 1)).astype(jnp.float32)
    return level, sign


def packed_dequantize(pq: PackedQTensor):
    """Dense weights in matmul orientation ``(..., K, n)``.

    jnp fallback for backends without the fused kernel; mirrors the exact
    f32 take-along-axis math of ``dequantize`` so packed and simulated
    execution agree bit-for-bit (up to the packed-zero caveat, DESIGN.md
    Sec. 7).
    """
    level, sign = _unpack_nibbles(pq.packed)       # (..., K, N_pad)
    n_pad = level.shape[-1]
    sc = pq.scales.astype(jnp.float32)
    if pq.kblocked:
        # sc (..., K//64, N_pad, 8): block index = k // 64. Gather per
        # (k-block, n) with levels brought to (..., K//64, N_pad, 64).
        k = level.shape[-2]
        lv = level.reshape(*level.shape[:-2], k // PACK_BLOCK, PACK_BLOCK,
                           n_pad)
        lvb = jnp.moveaxis(lv, -2, -1)             # (..., K//64, N_pad, 64)
        magb = jnp.take_along_axis(sc, lvb, axis=-1)
        mag = jnp.moveaxis(magb, -1, -2).reshape(*level.shape)
    else:
        # sc (..., K, N_pad//64, 8): block index = n // 64
        lv = level.reshape(*level.shape[:-1], n_pad // PACK_BLOCK, PACK_BLOCK)
        mag = jnp.take_along_axis(sc, lv, axis=-1).reshape(*level.shape)
    w = sign * mag
    return w[..., : pq.n].astype(pq.dtype)


def packed_gather(pq: PackedQTensor, idx):
    """Rows ``idx`` of a natural-orientation packed table, dequantized.

    The packed-weight analogue of ``dequantize(q)[idx]``: unpacks and
    dequantizes *only the gathered rows*, so the embedding lookup never
    materializes the full bf16 table (the old simulation path did, every
    step)."""
    if pq.kblocked:
        raise ValueError("packed_gather needs natural (n-blocked) layout")
    rows = jnp.take(pq.packed, idx, axis=0)        # (..., N_pad//2)
    srow = jnp.take(pq.scales, idx, axis=0)        # (..., N_pad//64, 8)
    level, sign = _unpack_nibbles(rows)
    n_pad = level.shape[-1]
    lv = level.reshape(*level.shape[:-1], n_pad // PACK_BLOCK, PACK_BLOCK)
    mag = jnp.take_along_axis(srow.astype(jnp.float32), lv,
                              axis=-1).reshape(*level.shape)
    return (sign * mag)[..., : pq.n].astype(pq.dtype)


# ---------------------------------------------------------------------------
# KV-page codec (serving path; DESIGN.md Sec. 15)
#
# MSB block-wise quantization applied to *committed* KV-cache pages: one
# (page_size, head_dim) payload per KV head is split into groups of
# KV_BLOCK elements covering whole token rows, and each group gets its own
# codebook (4-bit: a 2^{b-1}-entry MSB codebook from the exact DP solver;
# 8-bit: a single absmax scale with sign-magnitude int8 codes). Groups
# never cross heads, so head-sharded pools quantize identically per shard,
# and the functions are pure and deterministic — the supervisor's
# token-identical replay holds on quantized pools.
# ---------------------------------------------------------------------------

KV_BLOCK = 128       # max elements per KV quantization group


def _kv_tokens_per_block(page_size, head_dim):
    """Token rows per group: whole-hd rows, <= KV_BLOCK elements, dividing
    the page. Static python ints — resolved at trace time."""
    tpb = max(1, min(int(page_size), KV_BLOCK // int(head_dim)))
    while page_size % tpb:
        tpb -= 1
    return tpb


@dataclasses.dataclass(frozen=True)
class KVQuantSpec:
    """Static shape/byte schema of a quantized KV page pool (hashable, so
    it can ride through jit as a static argument). ``kv_heads`` is the
    *local* head count under tensor parallelism — grouping is per head, so
    the schema shards trivially along the head dim."""
    bits: int            # 8 or 4 (16 = unquantized native pools, no spec)
    page_size: int
    kv_heads: int
    head_dim: int

    def __post_init__(self):
        if self.bits not in (4, 8):
            raise ValueError(f"kv_bits must be 4 or 8, got {self.bits}")
        if self.bits == 4 and self.head_dim % 2:
            raise ValueError("4-bit KV packing needs an even head_dim")

    @property
    def tokens_per_block(self):
        return _kv_tokens_per_block(self.page_size, self.head_dim)

    @property
    def block(self):
        return self.tokens_per_block * self.head_dim

    @property
    def n_blocks(self):
        return self.page_size // self.tokens_per_block

    @property
    def n_levels(self):
        return 2 ** (self.bits - 1) if self.bits == 4 else 1

    @property
    def codes_tail(self):
        """Trailing dims of the codes leaf for one page."""
        hd = self.head_dim // 2 if self.bits == 4 else self.head_dim
        return (self.page_size, self.kv_heads, hd)

    @property
    def scales_tail(self):
        return (self.kv_heads, self.n_blocks, self.n_levels)

    @property
    def scale_dtype(self):
        # 4-bit keeps the paper's 16-bit codebook entries (Sec. 4.1
        # storage math); the 8-bit absmax is one f32 per 128 elements —
        # negligible, and it keeps the round-trip error centred.
        return jnp.bfloat16 if self.bits == 4 else jnp.float32

    def page_bytes(self):
        """Allocated bytes per page per K-or-V tensor (one layer period)."""
        codes = int(np.prod(self.codes_tail))
        scales = (int(np.prod(self.scales_tail))
                  * jnp.dtype(self.scale_dtype).itemsize)
        return codes + scales


def kv_native_page_bytes(page_size, kv_heads, head_dim, dtype):
    """Pool bytes per page per K-or-V tensor at ``kv_bits=16`` (native)."""
    return (int(page_size) * int(kv_heads) * int(head_dim)
            * jnp.dtype(dtype).itemsize)


def _kv_to_blocks(x, tpb):
    """(..., ps, kv, hd) -> (..., kv, n_blocks, tpb*hd)."""
    *lead, ps, kv, hd = x.shape
    y = x.reshape(*lead, ps // tpb, tpb, kv, hd)
    y = jnp.moveaxis(y, -2, -4)                 # (..., kv, nb, tpb, hd)
    return y.reshape(*lead, kv, ps // tpb, tpb * hd)


def _kv_from_blocks(y, tpb, head_dim):
    """(..., kv, n_blocks, tpb*hd) -> (..., ps, kv, hd)."""
    *lead, kv, nb, blk = y.shape
    y = y.reshape(*lead, kv, nb, tpb, head_dim)
    y = jnp.moveaxis(y, -4, -2)                 # (..., nb, tpb, kv, hd)
    return y.reshape(*lead, nb * tpb, kv, head_dim)


def kv_quantize_pages(x, bits):
    """Quantize KV pages. x: (..., page_size, KV, head_dim) f32/bf16.

    Returns ``(codes, scales)``:
      * 4-bit: codes uint8 (..., ps, KV, hd//2) — two MSB nibbles per byte,
        ``(sign << 3) | level`` with element 2i in the low nibble; scales
        bf16 (..., KV, n_blocks, 8), the per-group DP codebook sorted
        ascending (exact zeros group at level 0 with scale 0, so they
        survive the round trip exactly whenever they form their own group).
      * 8-bit: codes int8 (..., ps, KV, hd) sign-magnitude
        ``round(x * 127 / absmax)``; scales f32 (..., KV, n_blocks, 1)
        holding the group absmax (so 0 and +-absmax round-trip exactly).

    Pure and deterministic: same page bytes in, same code bytes out, every
    call — required for supervisor replay (DESIGN.md Sec. 14).
    """
    *lead, ps, kv, hd = x.shape
    tpb = _kv_tokens_per_block(ps, hd)
    nb, blk = ps // tpb, tpb * hd
    xf = x.astype(jnp.float32)
    blocks = _kv_to_blocks(xf, tpb)                     # (..., kv, nb, blk)
    if bits == 8:
        amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
        q = jnp.clip(jnp.round(blocks * (127.0 / jnp.maximum(amax, 1e-30))),
                     -127.0, 127.0)
        codes = _kv_from_blocks(q, tpb, hd).astype(jnp.int8)
        return codes, amax
    if bits != 4:
        raise ValueError(f"kv_bits must be 4 or 8, got {bits}")
    g = 8
    levels, scales = grouping.solve_blocks(blocks.reshape(-1, blk), g,
                                           method="dp")
    levels = levels.reshape(blocks.shape)
    scales = scales.reshape(*lead, kv, nb, g).astype(jnp.bfloat16)
    nib = jnp.where(blocks < 0, levels | 8, levels)     # sign<<3 | level
    nib = _kv_from_blocks(nib, tpb, hd)                 # (..., ps, kv, hd)
    pair = nib.reshape(*nib.shape[:-1], hd // 2, 2)
    codes = ((pair[..., 1] << 4) | pair[..., 0]).astype(jnp.uint8)
    return codes, scales


def kv_dequantize_pages(codes, scales, bits, dtype):
    """Inverse of ``kv_quantize_pages`` -> (..., ps, KV, hd) in ``dtype``."""
    if bits == 8:
        *lead, ps, kv, hd = codes.shape
        tpb = _kv_tokens_per_block(ps, hd)
        blocks = _kv_to_blocks(codes.astype(jnp.float32), tpb)
        out = blocks * (scales.astype(jnp.float32) / 127.0)
        return _kv_from_blocks(out, tpb, hd).astype(dtype)
    if bits != 4:
        raise ValueError(f"kv_bits must be 4 or 8, got {bits}")
    *lead, ps, kv, hdc = codes.shape
    hd = hdc * 2
    tpb = _kv_tokens_per_block(ps, hd)
    p32 = codes.astype(jnp.int32)
    nib = jnp.stack([p32 & 0xF, (p32 >> 4) & 0xF],
                    axis=-1).reshape(*lead, ps, kv, hd)
    nb_ = _kv_to_blocks(nib, tpb)                       # (..., kv, nb, blk)
    level = nb_ & 0x7
    sign = (1 - 2 * ((nb_ >> 3) & 1)).astype(jnp.float32)
    mag = jnp.take_along_axis(scales.astype(jnp.float32), level, axis=-1)
    return _kv_from_blocks(sign * mag, tpb, hd).astype(dtype)


# ---------------------------------------------------------------------------
# Tensor-parallel padding (DESIGN.md Sec. 10)
#
# Sharding a quantized matmul dim across a mesh axis needs every rank's
# slice to hold whole 64-element MSB blocks (N) or whole rows (K). When the
# stored width does not divide, the storage is widened with *exact-zero*
# columns/rows (zero scales for packed codes, code 0 for int8 codes), which
# contribute nothing to any matmul — the padded tree computes the same
# function as the original on every path, sharded or not.
# ---------------------------------------------------------------------------

def _pad_axis(a, axis, to):
    cur = a.shape[axis]
    if cur == to:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, to - cur)
    return jnp.pad(a, widths)


def tp_pad_packed_n(pq: PackedQTensor, n_to: int) -> PackedQTensor:
    """Widen a PackedQTensor's stored N to ``n_to`` with zero-scale columns.

    ``n`` is rebound to ``n_to``: the padded columns dequantize to exact 0,
    so consumers see extra all-zero output features (harmless for an MLP
    hidden dim, masked for a vocab dim) and downstream K-padded row-parallel
    partners line up with them. Requires whole 64-element blocks
    (``n_to % block == 0`` — block size is even, so byte packing is safe).
    """
    if n_to % pq.block:
        raise ValueError(f"n_to={n_to} must be a multiple of {pq.block}")
    if pq.n_pad > n_to:
        raise ValueError(f"cannot shrink storage {pq.n_pad} -> {n_to}")
    packed = _pad_axis(pq.packed, -1, n_to // 2)
    if pq.kblocked:
        scales = _pad_axis(pq.scales, -2, n_to)
    else:
        scales = _pad_axis(pq.scales, -2, n_to // PACK_BLOCK)
    return dataclasses.replace(pq, packed=packed, scales=scales, n=n_to)


def tp_pad_packed_k(pq: PackedQTensor, k_to: int) -> PackedQTensor:
    """Widen a PackedQTensor's K (row) dim to ``k_to`` with zero-scale rows."""
    if pq.kblocked:
        raise ValueError("K-padding needs the natural n-blocked layout")
    if pq.packed.shape[-2] > k_to:
        raise ValueError(f"cannot shrink K {pq.packed.shape[-2]} -> {k_to}")
    return dataclasses.replace(pq,
                               packed=_pad_axis(pq.packed, -2, k_to),
                               scales=_pad_axis(pq.scales, -3, k_to))


def tp_pad_q_n(q: QTensor, n_to: int) -> QTensor:
    """Widen a block-wise QTensor's last (output) dim with zero-code columns."""
    if n_to % q.block:
        raise ValueError(f"n_to={n_to} must be a multiple of block {q.block}")
    return dataclasses.replace(q,
                               codes=_pad_axis(q.codes, -1, n_to),
                               scales=_pad_axis(q.scales, -2, n_to // q.block))


def tp_pad_q_k(q: QTensor, k_to: int) -> QTensor:
    """Widen a block-wise QTensor's second-to-last (row) dim with zero rows."""
    return dataclasses.replace(q,
                               codes=_pad_axis(q.codes, -2, k_to),
                               scales=_pad_axis(q.scales, -3, k_to))


# ---------------------------------------------------------------------------
# Double quantization (paper Appendix G)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DQScales:
    """Scales quantized once more with WGM/MSB at 6 bits over 2048-blocks."""
    q: QTensor
    shape: tuple

    def dequantize(self):
        return self.q.dequantize().reshape(self.shape)


def double_quantize(q: QTensor, bits=6, block=2048, solver="kmeans"):
    """Quantize the scale table itself (recursive MSB), per Appendix G.

    Storage: 4 + 8*6.25/64 ~ 4.78 bits/weight for the default setting.
    """
    scales = q.scales.reshape(-1)
    pad = (-scales.shape[0]) % block
    padded = jnp.concatenate([scales, jnp.zeros((pad,), scales.dtype)])
    sq = quantize_blockwise(padded.reshape(-1, block), bits=bits, block=block,
                            solver=solver)
    return dataclasses.replace(
        q, scales=sq.dequantize().reshape(-1)[: scales.shape[0]]
                   .reshape(q.scales.shape))


# ---------------------------------------------------------------------------
# Storage accounting (paper Sec. 4.1)
# ---------------------------------------------------------------------------

def storage_bits_per_weight(q, double_quant=False,
                            scale_bits=16, dq_bits=6, dq_block=2048):
    """Effective bits/weight incl. codebook metadata.

    4-bit block-64, bf16 scales: 4 + 8*16/64 = 6.00 (paper).  With DQ:
    4 + 8*(6 + 32*16/2048)/64 = 4.78 (paper App. G). Per-tensor: ~b bits.

    For a ``PackedQTensor`` the answer is the *real allocated footprint*
    (uint8 codes + scale table, incl. any N-padding) over the logical
    element count — what HBM actually holds, not the formula.
    """
    if isinstance(q, PackedQTensor):
        n = float(np.prod(q.shape))
        scale_bits = jnp.dtype(q.scales.dtype).itemsize * 8
        return (q.packed.size * 8 + q.scales.size * scale_bits) / n
    n = float(np.prod(q.shape))
    if q.block == -1:
        return q.bits + q.n_levels * scale_bits / n
    per_scale = (dq_bits + 32 * scale_bits / dq_block) if double_quant else scale_bits
    return q.bits + q.n_levels * per_scale / q.block
