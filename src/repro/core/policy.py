"""Quantization policy: apply MSB PTQ to a model parameter pytree.

``QuantPolicy`` is the framework-level integration point of the paper's
technique: it selects which parameter leaves get quantized (by path regex +
rank/size thresholds), at what bits/granularity/solver, and rewrites the
params pytree in place with ``QTensor`` leaves. Layer stacks produced by
scan-over-layers (leading layer dim) are handled by folding the layer dim
into the block batch — blocks never straddle rows, so the grouping is
identical to quantizing each layer separately.

Quantization is *local to each weight shard* on a mesh (no collectives); see
examples/distributed_quantize.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import jax
import jax.numpy as jnp

from .quantize import (PackedQTensor, QTensor, dequantize, pack_qtensor,
                       quantize_blockwise, quantize_pertensor, tp_pad_packed_k,
                       tp_pad_packed_n, tp_pad_q_k, tp_pad_q_n)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    bits: int = 4
    block: int = 64              # -1 => per-tensor
    solver: str = "dp"           # dp | kmeans | wgm | gg | wgm_lo (CPU)
    lam: float = 0.0
    include: str = r".*"
    exclude: str = (r".*(norm|scale_param|bias|ln|rope|router|conv_w|"
                    r"dt_bias|a_log|d_skip|f_bias|w_rec).*")
    min_size: int = 1 << 12      # skip tiny leaves (norm scales etc.)
    double_quant: bool = False

    def selects(self, path: str, leaf) -> bool:
        if not hasattr(leaf, "ndim") or leaf.ndim < 2 or leaf.size < self.min_size:
            return False
        if re.match(self.exclude, path, re.I):
            return False
        if self.block != -1 and leaf.shape[-1] % abs(self.block):
            return False
        return re.match(self.include, path, re.I) is not None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def quantize_params(params, policy: QuantPolicy = QuantPolicy(), verbose=False):
    """Rewrite matching leaves of ``params`` as QTensor. Returns (tree, report)."""
    report = {}

    def visit(path, leaf):
        p = _path_str(path)
        if isinstance(leaf, QTensor) or not policy.selects(p, leaf):
            return leaf
        if policy.block == -1:
            solver = "wdp" if policy.solver == "dp" else policy.solver
            if leaf.ndim >= 3:
                # stacked (scan-over-layers) params: per-tensor = per layer
                # matrix; vmap keeps the leading layer dim scannable
                q = jax.vmap(lambda w: quantize_pertensor(
                    w, bits=policy.bits, solver=solver, lam=policy.lam))(leaf)
            else:
                q = quantize_pertensor(leaf, bits=policy.bits, solver=solver,
                                       lam=policy.lam)
        else:
            q = quantize_blockwise(leaf, bits=policy.bits, block=policy.block,
                                   solver=policy.solver, lam=policy.lam)
        if policy.double_quant:
            from .quantize import double_quantize
            q = double_quantize(q)
        report[p] = (leaf.shape, policy.bits)
        if verbose:
            print(f"  quantized {p}: {leaf.shape} -> {policy.bits}b/"
                  f"{'tensor' if policy.block == -1 else policy.block}")
        return q

    tree = jax.tree_util.tree_map_with_path(visit, params)
    return tree, report


def pack_params(params, verbose=False):
    """QTensor leaves -> PackedQTensor (packed execution; DESIGN.md Sec. 9).

    The one-time load pass behind ``execution="packed"``: every 4-bit
    block-64 ``QTensor`` becomes a kernel-layout ``PackedQTensor`` so no
    forward ever re-packs. The ``unembed`` table packs *transposed*
    (k-blocked scales) so the unembedding projection runs through the fused
    kernel; ``embed`` packs in natural orientation for the row-gather path.
    Leaves the pass cannot pack (per-tensor QTensors, other bit-widths,
    plain arrays) stay as-is and keep their simulation-mode execution.
    Returns (tree, report).

    Contract: packing is value-preserving — ``dequantize_params`` of the
    packed tree equals the unpacked tree's dequantization exactly, except
    that stored exact-zero codes re-emerge as ``+alpha_0`` (the packed
    format trades the zero special-case for density; DESIGN.md Sec. 7).
    The input tree is not mutated. Run this *before*
    ``tp_partition_params`` — the TP planner consumes packed layouts.
    """
    report = {}

    def visit(path, leaf):
        if not isinstance(leaf, QTensor):
            return leaf
        p = _path_str(path)
        if leaf.bits != 4 or leaf.block != 64:
            return leaf                      # no packed layout — simulate
        transpose = p == "unembed" and leaf.codes.ndim == 2
        pq = pack_qtensor(leaf, transpose=transpose)
        report[p] = (leaf.shape, "kblocked" if transpose else "nblocked")
        if verbose:
            print(f"  packed {p}: {leaf.shape} -> "
                  f"{'transposed/kblocked' if transpose else 'nblocked'} "
                  f"uint8 {pq.packed.shape}")
        return pq

    tree = jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, QTensor))
    return tree, report


# ---------------------------------------------------------------------------
# Tensor-parallel partitioning (DESIGN.md Sec. 10)
# ---------------------------------------------------------------------------

def _is_q(x):
    return isinstance(x, (QTensor, PackedQTensor))


def _storage_n(leaf):
    """Stored output-dim width (incl. pack padding)."""
    return leaf.n_pad if isinstance(leaf, PackedQTensor) else leaf.codes.shape[-1]


def _storage_k(leaf):
    a = leaf.packed if isinstance(leaf, PackedQTensor) else leaf.codes
    return a.shape[-2]


def _roundup(x, m):
    return -(-x // m) * m


def _axis_spec(ndim, pos, axis):
    from jax.sharding import PartitionSpec as P
    parts = [None] * ndim
    parts[pos] = axis
    return P(*parts)


def _leaf_spec(leaf, kind, axis):
    """PartitionSpec subtree matching one (marked) leaf's pytree structure.

    For Q/Packed leaves the returned object is the *same dataclass* with its
    array fields replaced by PartitionSpecs, so a spec tree built this way
    flattens leaf-for-leaf against the params tree (shard_map in_specs,
    device_put shardings).
    """
    from jax.sharding import PartitionSpec as P
    if isinstance(leaf, PackedQTensor):
        pd, sd = leaf.packed.ndim, leaf.scales.ndim
        dims = {None: None, "n": (pd - 1, sd - 2), "k": (pd - 2, sd - 3),
                "e": (pd - 3, sd - 4)}[kind]
        if dims is None:
            return dataclasses.replace(leaf, packed=P(), scales=P())
        return dataclasses.replace(leaf, packed=_axis_spec(pd, dims[0], axis),
                                   scales=_axis_spec(sd, dims[1], axis))
    if isinstance(leaf, QTensor):
        cd, sd = leaf.codes.ndim, leaf.scales.ndim
        dims = {None: None, "n": (cd - 1, sd - 2), "k": (cd - 2, sd - 3),
                "v": (cd - 2, sd - 3), "e": (cd - 3, sd - 4)}[kind]
        if dims is None:
            return dataclasses.replace(leaf, codes=P(), scales=P())
        return dataclasses.replace(leaf, codes=_axis_spec(cd, dims[0], axis),
                                   scales=_axis_spec(sd, dims[1], axis))
    if kind == "n" and hasattr(leaf, "ndim"):          # column-parallel bias
        return _axis_spec(leaf.ndim, leaf.ndim - 1, axis)
    return P()


def _n_shardable_exact(leaf, tp):
    """Can this leaf's output dim split into whole per-rank blocks with NO
    padding (required for head-sharded attention projections)?"""
    if isinstance(leaf, PackedQTensor):
        return (not leaf.kblocked and leaf.n == leaf.n_pad
                and leaf.n_pad % (64 * tp) == 0)
    if isinstance(leaf, QTensor):
        return leaf.block == 64 and leaf.codes.shape[-1] % (64 * tp) == 0
    return False


def tp_partition_params(params, tp_size, cfg=None, axis="model",
                        verbose=False):
    """Partition a (quantized/packed) params tree for tensor parallelism.

    The Megatron-style plan, applied to MSB storage (DESIGN.md Sec. 10):

      * attention ``wq/wk/wv`` (+ qkv biases) column-parallel along heads,
        ``wo`` row-parallel with a psum — only when every projection is
        quantized and ``n_heads``/``n_kv_heads`` divide ``tp_size`` with
        64-block-aligned per-rank widths (no padding is ever introduced
        inside a head); otherwise the whole attention layer replicates and
        the engines fall back to slicing *computed* heads for the paged
        cache.
      * MLP ``wg/wi`` column-parallel, ``wo`` row-parallel. The shared
        hidden width is padded to a multiple of ``64*tp_size`` with
        exact-zero columns/rows, so any ``d_ff`` shards.
      * MoE expert tensors shard along the expert dim when it divides
        ``tp_size`` (each rank runs its resident experts; the combine is a
        psum). The router replicates.
      * ``unembed`` shards along vocab (column-parallel logits + an
        all_gather); ``embed`` replicates (it also backs the tied-embedding
        logits path and the row-gather embedding lookup).
      * Everything else (norms, plain arrays, per-tensor QTensors)
        replicates.

    Marks each sharded leaf's ``shard`` aux so the model code knows where
    to psum/all_gather, and returns ``(params, specs, report)`` where
    ``specs`` is a PartitionSpec pytree flattening leaf-for-leaf against
    ``params`` (feed to ``shard_map`` in_specs / ``device_put``) and
    ``report`` maps group paths to what was done. ``tp_size == 1`` returns
    the tree unchanged with fully replicated specs.
    """
    from jax.sharding import PartitionSpec as P
    report = {}
    tp = int(tp_size)

    def repl(node):
        if isinstance(node, dict):
            return {k: repl(v) for k, v in node.items()}
        return _leaf_spec(node, None, None)

    def mark(leaf, kind):
        return dataclasses.replace(leaf, shard=kind) if _is_q(leaf) else leaf

    def attn_group(group, path):
        names = ("wq", "wk", "wv", "wo")
        ok = (tp > 1 and cfg is not None and not cfg.is_encdec
              and cfg.n_heads % tp == 0 and cfg.n_kv_heads % tp == 0
              and all(_is_q(group.get(nm)) for nm in names)
              and all(_n_shardable_exact(group[nm], tp)
                      for nm in ("wq", "wk", "wv"))
              and _storage_k(group["wo"]) % tp == 0)
        if not ok:
            return dict(group), repl(group)
        new, spec = {}, {}
        for key, leaf in group.items():
            kind = {"wq": "n", "wk": "n", "wv": "n", "wo": "k",
                    "bq": "n", "bk": "n", "bv": "n"}.get(key)
            new[key] = mark(leaf, kind)
            spec[key] = _leaf_spec(new[key], kind, axis)
        report[path] = "heads"
        return new, spec

    def mlp_group(group, path):
        ws = ("wg", "wi", "wo")
        if tp <= 1 or not all(_is_q(group.get(w)) for w in ws) or any(
                isinstance(group[w], QTensor) and group[w].block != 64
                for w in ws):
            return dict(group), repl(group)
        f_to = _roundup(max(_storage_n(group["wg"]), _storage_n(group["wi"])),
                        64 * tp)
        new, spec = {}, {}
        for key, leaf in group.items():
            if key in ("wg", "wi"):
                leaf = (tp_pad_packed_n(leaf, f_to)
                        if isinstance(leaf, PackedQTensor)
                        else tp_pad_q_n(leaf, f_to))
                kind = "n"
            elif key == "wo":
                leaf = (tp_pad_packed_k(leaf, f_to)
                        if isinstance(leaf, PackedQTensor)
                        else tp_pad_q_k(leaf, f_to))
                kind = "k"
            else:
                kind = None
            new[key] = mark(leaf, kind)
            spec[key] = _leaf_spec(new[key], kind, axis)
        report[path] = f"column/row hidden->{f_to}"
        return new, spec

    def moe_group(group, path):
        ws = ("wg", "wi", "wo")
        ok = (tp > 1 and all(_is_q(group.get(w)) for w in ws)
              and all(group[w].codes.ndim >= 3 if isinstance(group[w], QTensor)
                      else group[w].packed.ndim >= 3 for w in ws))
        if ok:
            e = (group["wg"].packed.shape[-3]
                 if isinstance(group["wg"], PackedQTensor)
                 else group["wg"].codes.shape[-3])
            ok = e % tp == 0
        if not ok:
            return dict(group), repl(group)
        new, spec = {}, {}
        for key, leaf in group.items():
            kind = "e" if key in ws else None
            new[key] = mark(leaf, kind)
            spec[key] = _leaf_spec(new[key], kind, axis)
        report[path] = "experts"
        return new, spec

    def unembed_leaf(leaf, path):
        if tp <= 1 or not _is_q(leaf):
            return leaf, _leaf_spec(leaf, None, None)
        if isinstance(leaf, PackedQTensor):
            if not leaf.kblocked:
                return leaf, _leaf_spec(leaf, None, None)
            v_to = _roundup(leaf.n_pad, 64 * tp)
            leaf = tp_pad_packed_n(leaf, v_to)
            kind = "n"
        else:
            if leaf.block == -1:
                return leaf, _leaf_spec(leaf, None, None)
            v_to = _roundup(leaf.codes.shape[-2], tp)
            leaf = tp_pad_q_k(leaf, v_to)
            kind = "v"
        report[path] = f"vocab->{v_to}"
        leaf = mark(leaf, kind)
        return leaf, _leaf_spec(leaf, kind, axis)

    def walk(node, path):
        if not isinstance(node, dict):
            return node, repl(node)
        new, spec = {}, {}
        for key, val in node.items():
            p = f"{path}/{key}" if path else key
            if key in ("attn", "xattn") and isinstance(val, dict):
                new[key], spec[key] = attn_group(val, p)
            elif key == "mlp" and isinstance(val, dict):
                new[key], spec[key] = mlp_group(val, p)
            elif key == "moe" and isinstance(val, dict):
                new[key], spec[key] = moe_group(val, p)
            elif key == "unembed":
                new[key], spec[key] = unembed_leaf(val, p)
            else:
                new[key], spec[key] = walk(val, p)
        return new, spec

    new_params, specs = walk(params, "")
    if verbose:
        for p, what in sorted(report.items()):
            print(f"  tp-sharded {p}: {what}")
    return new_params, specs, report


def tp_localize(params):
    """Rebind each n-sharded PackedQTensor's static ``n`` to its local width.

    Inside ``shard_map`` the array leaves are per-rank slices but the pytree
    aux still carries the *global* padded width; run this on the local tree
    before any matmul so ``packed_matmul`` does not slice past the shard.
    K-, expert- and vocab-sharded leaves keep their aux unchanged (their
    ``n``/logical width is not the sharded dim). No-op outside shard_map.
    """
    def fix(leaf):
        if isinstance(leaf, PackedQTensor) and leaf.shard == "n":
            return dataclasses.replace(leaf, n=leaf.packed.shape[-1] * 2)
        return leaf

    return jax.tree_util.tree_map(
        fix, params, is_leaf=lambda x: isinstance(x, PackedQTensor))


def dequantize_params(params, dtype=None):
    """Materialize all quantized leaves back to dense arrays (simulation mode).

    PackedQTensor leaves come back in their *original* orientation (a
    transposed unembedding pack is transposed back to ``(V, D)``)."""
    def visit(leaf):
        if isinstance(leaf, PackedQTensor):
            w = leaf.dequantize()
            if leaf.kblocked:
                w = w.T
        elif isinstance(leaf, QTensor):
            w = dequantize(leaf)
        else:
            return leaf
        return w.astype(dtype) if dtype is not None else w
    return jax.tree_util.tree_map(
        visit, params,
        is_leaf=lambda x: isinstance(x, (QTensor, PackedQTensor)))


def param_bits(params):
    """Total storage bits of a (possibly mixed) params tree.

    PackedQTensor leaves report their real allocated footprint: uint8
    packed codes (8 bits/byte) + the scale table, N-padding included."""
    total = 0

    def visit(leaf):
        nonlocal total
        if isinstance(leaf, PackedQTensor):
            scale_bits = jnp.dtype(leaf.scales.dtype).itemsize * 8
            total += leaf.packed.size * 8 + leaf.scales.size * scale_bits
        elif isinstance(leaf, QTensor):
            scale_bits = jnp.dtype(leaf.scales.dtype).itemsize * 8
            total += leaf.codes.size * leaf.bits + leaf.scales.size * scale_bits
        elif hasattr(leaf, "size"):
            total += leaf.size * jnp.dtype(leaf.dtype).itemsize * 8
        return leaf

    jax.tree_util.tree_map(visit, params,
                           is_leaf=lambda x: isinstance(x, (QTensor,
                                                            PackedQTensor)))
    return total
