"""Quantization policy: apply MSB PTQ to a model parameter pytree.

``QuantPolicy`` is the framework-level integration point of the paper's
technique: it selects which parameter leaves get quantized (by path regex +
rank/size thresholds), at what bits/granularity/solver, and rewrites the
params pytree in place with ``QTensor`` leaves. Layer stacks produced by
scan-over-layers (leading layer dim) are handled by folding the layer dim
into the block batch — blocks never straddle rows, so the grouping is
identical to quantizing each layer separately.

Quantization is *local to each weight shard* on a mesh (no collectives); see
examples/distributed_quantize.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import jax
import jax.numpy as jnp

from .quantize import (PackedQTensor, QTensor, dequantize, pack_qtensor,
                       quantize_blockwise, quantize_pertensor)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    bits: int = 4
    block: int = 64              # -1 => per-tensor
    solver: str = "dp"           # dp | kmeans | wgm | gg | wgm_lo (CPU)
    lam: float = 0.0
    include: str = r".*"
    exclude: str = (r".*(norm|scale_param|bias|ln|rope|router|conv_w|"
                    r"dt_bias|a_log|d_skip|f_bias|w_rec).*")
    min_size: int = 1 << 12      # skip tiny leaves (norm scales etc.)
    double_quant: bool = False

    def selects(self, path: str, leaf) -> bool:
        if not hasattr(leaf, "ndim") or leaf.ndim < 2 or leaf.size < self.min_size:
            return False
        if re.match(self.exclude, path, re.I):
            return False
        if self.block != -1 and leaf.shape[-1] % abs(self.block):
            return False
        return re.match(self.include, path, re.I) is not None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def quantize_params(params, policy: QuantPolicy = QuantPolicy(), verbose=False):
    """Rewrite matching leaves of ``params`` as QTensor. Returns (tree, report)."""
    report = {}

    def visit(path, leaf):
        p = _path_str(path)
        if isinstance(leaf, QTensor) or not policy.selects(p, leaf):
            return leaf
        if policy.block == -1:
            solver = "wdp" if policy.solver == "dp" else policy.solver
            if leaf.ndim >= 3:
                # stacked (scan-over-layers) params: per-tensor = per layer
                # matrix; vmap keeps the leading layer dim scannable
                q = jax.vmap(lambda w: quantize_pertensor(
                    w, bits=policy.bits, solver=solver, lam=policy.lam))(leaf)
            else:
                q = quantize_pertensor(leaf, bits=policy.bits, solver=solver,
                                       lam=policy.lam)
        else:
            q = quantize_blockwise(leaf, bits=policy.bits, block=policy.block,
                                   solver=policy.solver, lam=policy.lam)
        if policy.double_quant:
            from .quantize import double_quantize
            q = double_quantize(q)
        report[p] = (leaf.shape, policy.bits)
        if verbose:
            print(f"  quantized {p}: {leaf.shape} -> {policy.bits}b/"
                  f"{'tensor' if policy.block == -1 else policy.block}")
        return q

    tree = jax.tree_util.tree_map_with_path(visit, params)
    return tree, report


def pack_params(params, verbose=False):
    """QTensor leaves -> PackedQTensor (packed execution; DESIGN.md Sec. 9).

    The one-time load pass behind ``execution="packed"``: every 4-bit
    block-64 ``QTensor`` becomes a kernel-layout ``PackedQTensor`` so no
    forward ever re-packs. The ``unembed`` table packs *transposed*
    (k-blocked scales) so the unembedding projection runs through the fused
    kernel; ``embed`` packs in natural orientation for the row-gather path.
    Leaves the pass cannot pack (per-tensor QTensors, other bit-widths,
    plain arrays) stay as-is and keep their simulation-mode execution.
    Returns (tree, report).
    """
    report = {}

    def visit(path, leaf):
        if not isinstance(leaf, QTensor):
            return leaf
        p = _path_str(path)
        if leaf.bits != 4 or leaf.block != 64:
            return leaf                      # no packed layout — simulate
        transpose = p == "unembed" and leaf.codes.ndim == 2
        pq = pack_qtensor(leaf, transpose=transpose)
        report[p] = (leaf.shape, "kblocked" if transpose else "nblocked")
        if verbose:
            print(f"  packed {p}: {leaf.shape} -> "
                  f"{'transposed/kblocked' if transpose else 'nblocked'} "
                  f"uint8 {pq.packed.shape}")
        return pq

    tree = jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, QTensor))
    return tree, report


def dequantize_params(params, dtype=None):
    """Materialize all quantized leaves back to dense arrays (simulation mode).

    PackedQTensor leaves come back in their *original* orientation (a
    transposed unembedding pack is transposed back to ``(V, D)``)."""
    def visit(leaf):
        if isinstance(leaf, PackedQTensor):
            w = leaf.dequantize()
            if leaf.kblocked:
                w = w.T
        elif isinstance(leaf, QTensor):
            w = dequantize(leaf)
        else:
            return leaf
        return w.astype(dtype) if dtype is not None else w
    return jax.tree_util.tree_map(
        visit, params,
        is_leaf=lambda x: isinstance(x, (QTensor, PackedQTensor)))


def param_bits(params):
    """Total storage bits of a (possibly mixed) params tree.

    PackedQTensor leaves report their real allocated footprint: uint8
    packed codes (8 bits/byte) + the scale table, N-padding included."""
    total = 0

    def visit(leaf):
        nonlocal total
        if isinstance(leaf, PackedQTensor):
            scale_bits = jnp.dtype(leaf.scales.dtype).itemsize * 8
            total += leaf.packed.size * 8 + leaf.scales.size * scale_bits
        elif isinstance(leaf, QTensor):
            scale_bits = jnp.dtype(leaf.scales.dtype).itemsize * 8
            total += leaf.codes.size * leaf.bits + leaf.scales.size * scale_bits
        elif hasattr(leaf, "size"):
            total += leaf.size * jnp.dtype(leaf.dtype).itemsize * 8
        return leaf

    jax.tree_util.tree_map(visit, params,
                           is_leaf=lambda x: isinstance(x, (QTensor,
                                                            PackedQTensor)))
    return total
