"""Prometheus-text-format metrics for the serving front door.

A tiny, dependency-free subset of the Prometheus client model — counters,
gauges and histograms with optional labels, rendered in text exposition
format 0.0.4 (the format every Prometheus/VictoriaMetrics/Grafana-agent
scraper speaks) — plus ``ServeMetrics``, the registry wired to the counters
the engine already exposes.

Design constraints, in order:

* **Hot-loop cheap.** ``observe``/``inc``/``set`` are a dict lookup and a
  float add; no locks (the engine step-loop thread is the only writer of
  engine-derived series, and scrape-time readers tolerate torn-but-recent
  values — each individual Python float read is atomic under the GIL).
* **Monotonic counters.** Prometheus ``rate()`` treats any decrease as a
  counter reset. ``Counter.inc`` rejects negative deltas and
  ``Counter.set_to`` (the bridge from the engine's own monotonic counters,
  e.g. ``n_decode_steps``) rejects regressions, so a wiring bug fails
  loudly here instead of silently corrupting dashboards.
* **Deterministic render.** Families render in registration order, children
  in sorted-label order, so the text output is stable enough to golden-test.

Timing semantics (used by ``lifecycle.RequestLifecycle``): TTFT is observed
once per request, at the arrival of the event carrying its first token —
chunked prefill just makes that event later. Inter-token latency observes
**one value per token-bearing arrival gap**, not per token: with
``decode_horizon=H`` the engine delivers up to H tokens per dispatch, and
recording H identical gaps would fabricate H-1 latencies no client ever
saw. The ITL histogram therefore measures the stall a streaming consumer
actually experiences between flushes.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

# Prometheus-recommended latency buckets, extended down to 1ms: CPU smoke
# runs sit in the 1-50ms/token range, real accelerators below that.
LATENCY_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)

_LABEL_ESC = str.maketrans({"\\": r"\\", '"': r"\"", "\n": r"\n"})


def _fmt(v: float) -> str:
    """Prometheus sample-value formatting: integers without the trailing
    ``.0``, infinities as ``+Inf``/``-Inf``."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    if f.is_integer():
        return str(int(f))
    return repr(f)


def _labelstr(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{str(v).translate(_LABEL_ESC)}"'
                     for k, v in labels)
    return "{" + inner + "}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[Tuple[str, str], ...], object] = {}

    def _key(self, labels: Dict[str, str]):
        if set(labels) != set(self.labelnames):
            raise ValueError(f"{self.name}: expected labels "
                             f"{self.labelnames}, got {tuple(labels)}")
        return tuple((k, str(labels[k])) for k in self.labelnames)

    def _child(self, labels):
        key = self._key(labels)
        child = self._children.get(key)
        if child is None:
            child = self._children[key] = self._new_child()
        return child

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self._children):
            lines.extend(self._render_child(key, self._children[key]))
        return lines


class Counter(_Metric):
    kind = "counter"

    def _new_child(self):
        return [0.0]

    def inc(self, amount: float = 1.0, **labels):
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up "
                             f"(inc {amount})")
        self._child(labels)[0] += amount

    def set_to(self, value: float, **labels):
        """Ratchet to an externally-maintained monotonic value (the bridge
        from engine counters like ``n_decode_steps``); a regression is a
        wiring bug and raises."""
        child = self._child(labels)
        if value < child[0]:
            raise ValueError(f"{self.name}: monotonic counter cannot go "
                             f"from {child[0]} to {value}")
        child[0] = float(value)

    def value(self, **labels) -> float:
        return self._child(labels)[0]

    def _render_child(self, key, child):
        return [f"{self.name}{_labelstr(key)} {_fmt(child[0])}"]


class Gauge(_Metric):
    kind = "gauge"

    def _new_child(self):
        return [0.0]

    def set(self, value: float, **labels):
        self._child(labels)[0] = float(value)

    def inc(self, amount: float = 1.0, **labels):
        self._child(labels)[0] += amount

    def dec(self, amount: float = 1.0, **labels):
        self._child(labels)[0] -= amount

    def value(self, **labels) -> float:
        return self._child(labels)[0]

    def _render_child(self, key, child):
        return [f"{self.name}{_labelstr(key)} {_fmt(child[0])}"]


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help, labelnames=(),
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        super().__init__(name, help, labelnames)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError(f"{name}: need at least one bucket")
        self.buckets = bs

    def _new_child(self):
        # per-bucket non-cumulative counts + [sum, count]
        return {"counts": [0] * (len(self.buckets) + 1), "sum": 0.0,
                "count": 0}

    def observe(self, value: float, **labels):
        child = self._child(labels)
        i = len(self.buckets)
        for j, b in enumerate(self.buckets):    # ~13 buckets: linear is fine
            if value <= b:
                i = j
                break
        child["counts"][i] += 1
        child["sum"] += float(value)
        child["count"] += 1

    def count(self, **labels) -> int:
        return self._child(labels)["count"]

    def sum(self, **labels) -> float:
        return self._child(labels)["sum"]

    def percentile(self, q: float, **labels) -> Optional[float]:
        """Bucket-resolution percentile (upper bound of the bucket holding
        the q-quantile observation) — what a PromQL ``histogram_quantile``
        would report. None with no observations."""
        child = self._child(labels)
        if child["count"] == 0:
            return None
        rank = q * child["count"]
        cum = 0
        for j, b in enumerate(self.buckets):
            cum += child["counts"][j]
            if cum >= rank:
                return b
        return math.inf

    def _render_child(self, key, child):
        lines, cum = [], 0
        for j, b in enumerate(self.buckets):
            cum += child["counts"][j]
            lk = key + (("le", _fmt(b)),)
            lines.append(f"{self.name}_bucket{_labelstr(lk)} {cum}")
        cum += child["counts"][-1]
        lk = key + (("le", "+Inf"),)
        lines.append(f"{self.name}_bucket{_labelstr(lk)} {cum}")
        lines.append(f"{self.name}_sum{_labelstr(key)} {_fmt(child['sum'])}")
        lines.append(f"{self.name}_count{_labelstr(key)} {cum}")
        return lines


class Registry:
    """Ordered collection of metric families with one text renderer."""

    CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _register(self, metric):
        if metric.name in self._metrics:
            raise ValueError(f"duplicate metric {metric.name}")
        self._metrics[metric.name] = metric
        return metric

    def counter(self, name, help, labelnames=()) -> Counter:
        return self._register(Counter(name, help, labelnames))

    def gauge(self, name, help, labelnames=()) -> Gauge:
        return self._register(Gauge(name, help, labelnames))

    def histogram(self, name, help, labelnames=(),
                  buckets=LATENCY_BUCKETS) -> Histogram:
        return self._register(Histogram(name, help, labelnames, buckets))

    def render(self) -> str:
        lines: List[str] = []
        for metric in self._metrics.values():
            lines.extend(metric.render())
        return "\n".join(lines) + "\n"


class ServeMetrics:
    """The serving front door's metric set, wired from the counters the
    engine/scheduler/cache already maintain plus the per-request timing the
    lifecycle layer records.

    Two write paths: the request path (``ttft``/``itl``/``requests``,
    written by ``RequestLifecycle`` as events happen) and ``sync_engine``,
    called by the engine step-loop each tick and at scrape time to ratchet
    the engine's own monotonic counters into Prometheus families. Both run
    on the engine-loop thread, so no locking."""

    def __init__(self, registry: Optional[Registry] = None):
        r = self.registry = registry or Registry()
        self.ttft = r.histogram(
            "msb_ttft_seconds",
            "Time from request acceptance to its first generated token")
        self.itl = r.histogram(
            "msb_inter_token_seconds",
            "Gap between consecutive token-bearing stream flushes of one "
            "request (one observation per gap, however many tokens a "
            "decode-horizon dispatch delivers at once)")
        self.queue_depth = r.gauge(
            "msb_queue_depth", "Requests waiting for admission")
        self.running = r.gauge(
            "msb_running_requests", "Requests holding a KV-cache slot")
        self.requests = r.counter(
            "msb_requests_total", "Completed API requests by outcome",
            labelnames=("outcome",))
        self.tokens = r.counter(
            "msb_tokens_generated_total", "Tokens sampled by the engine")
        self.dispatches = r.counter(
            "msb_dispatches_total", "Jitted device dispatches (any kind)")
        self.decode_dispatches = r.counter(
            "msb_decode_dispatches_total", "Decode dispatches (any horizon)")
        self.host_syncs = r.counter(
            "msb_host_syncs_total", "Blocking device-to-host transfers")
        self.preemptions = r.counter(
            "msb_preemptions_total",
            "Sequences evicted for recompute, by priority class",
            labelnames=("class",))
        self.aborts = r.counter(
            "msb_aborts_total", "Requests cancelled before finishing")
        self.prefix_hits = r.counter(
            "msb_prefix_hits_total",
            "Admissions that longest-prefix-matched the page registry")
        self.prefix_positions_saved = r.counter(
            "msb_prefix_positions_saved_total",
            "Token positions adopted from the prefix cache, not prefilled")
        self.prefix_hit_rate = r.gauge(
            "msb_prefix_hit_rate",
            "Fraction of admissions that hit the prefix cache")
        # packed prefill + AOT warmup (DESIGN.md Sec. 16)
        self.prefill_dispatches = r.counter(
            "msb_prefill_dispatches_total",
            "Prefill dispatches (packed waves count once, however many "
            "segments they carry)")
        self.prefill_segments = r.counter(
            "msb_prefill_segments_total",
            "Prompt segments prefetched across all prefill dispatches")
        self.admission_waves = r.counter(
            "msb_admission_waves_total",
            "Scheduler admission waves that admitted at least one request")
        self.packed_segments = r.histogram(
            "msb_prefill_packed_segments",
            "Segments per packed prefill dispatch",
            buckets=(1, 2, 4, 8, 16, 32))
        self.admission_depth = r.histogram(
            "msb_admission_queue_depth",
            "Waiting-queue depth at the start of each admission wave "
            "(one observation per wave, not per chunk)",
            buckets=(0, 1, 2, 4, 8, 16, 32, 64, 128))
        self.warmup_seconds = r.counter(
            "msb_warmup_seconds",
            "Wall time spent in AOT trace warmup, summed across engine "
            "incarnations")
        self.traces_compiled = r.counter(
            "msb_traces_compiled_total",
            "Process-wide jitted-dispatch traces entered (the steady-state "
            "serving delta after warmup should be zero)")
        # supervision (DESIGN.md Sec. 14) — zero-valued until a supervised
        # engine syncs, so dashboards can alert on them unconditionally
        self.restarts = r.counter(
            "msb_engine_restarts_total",
            "Engine incarnations discarded and rebuilt after crash or hang")
        self.watchdog_trips = r.counter(
            "msb_watchdog_trips_total",
            "Steps abandoned for exceeding the watchdog deadline")
        self.replayed_tokens = r.counter(
            "msb_replayed_tokens_total",
            "Already-generated tokens re-admitted as prompt after recovery")
        self.quarantined = r.counter(
            "msb_quarantined_requests_total",
            "Requests failed for exhausting their crash-blame budget")
        self.detok_restarts = r.counter(
            "msb_detok_restarts_total",
            "Detokenize-thread deaths detected and restarted")
        self.recovery = r.histogram(
            "msb_recovery_seconds",
            "Wall time of one crash recovery (blame + rebuild), excluding "
            "replay re-prefill")
        # overload control plane (DESIGN.md Sec. 17)
        self.admissions = r.counter(
            "msb_admissions_total",
            "Requests admitted to a KV slot, by priority class",
            labelnames=("class",))
        self.sheds = r.counter(
            "msb_shed_total",
            "Requests turned away with 429, by priority class (written by "
            "the HTTP front door, not synced from the engine)",
            labelnames=("class",))
        self.brownout_level = r.gauge(
            "msb_brownout_level",
            "Current rung of the overload brownout ladder (0 = normal)")
        self.brownout_transitions = r.counter(
            "msb_brownout_transitions_total",
            "Brownout ladder level changes (either direction)")
        self.health = r.gauge(
            "msb_health_state",
            "One-hot server health (exactly one state is 1)",
            labelnames=("state",))
        for s in ("ok", "warming", "degraded", "draining", "dead"):
            self.health.set(1.0 if s == "ok" else 0.0, state=s)
        self._recovery_seen = 0       # recovery_log entries already observed

    def sync_engine(self, engine):
        """Ratchet engine counters and refresh gauges from the engine's
        ``stats()`` snapshot. Works identically for a raw
        ``ContinuousEngine`` and an ``EngineSupervisor`` — the supervisor
        aggregates counters across engine rebuilds (a fresh incarnation's
        counters restart at zero; feeding them here raw would trip the
        ``set_to`` monotonicity check) and adds the supervision families.
        Counters are monotonic by construction; ``set_to`` enforces it."""
        st = engine.stats()
        self.queue_depth.set(st["queue_depth"])
        self.running.set(st["running"])
        self.tokens.set_to(st["tokens_out"])
        self.dispatches.set_to(st["steps"])
        self.decode_dispatches.set_to(st["decode_steps"])
        self.host_syncs.set_to(st["host_syncs"])
        # per-class families ratchet from the engine's by-class dicts
        # (`class` is a keyword, hence the **{} spelling)
        for c, v in st.get("preemptions_by_class", {}).items():
            self.preemptions.set_to(v, **{"class": c})
        for c, v in st.get("admissions_by_class", {}).items():
            self.admissions.set_to(v, **{"class": c})
        self.aborts.set_to(st["aborts"])
        self.prefix_hits.set_to(st["prefix_hits"])
        self.prefix_positions_saved.set_to(st["prefix_positions_saved"])
        self.prefix_hit_rate.set(
            st["prefix_hits"] / max(st["admissions"], 1))
        if "prefill_dispatches" in st:
            self.prefill_dispatches.set_to(st["prefill_dispatches"])
            self.prefill_segments.set_to(st["prefill_segments"])
            self.admission_waves.set_to(st["admission_waves"])
            self.warmup_seconds.set_to(st["warmup_seconds"])
        # process-wide trace probe: module-level jits share their compile
        # cache, so this ratchets even across sibling engines
        from .continuous import jit_trace_count
        self.traces_compiled.set_to(jit_trace_count())
        drain = getattr(engine, "drain_observations", None)
        if drain is not None:
            obs = drain()
            for d in obs.get("admission_queue_depth", ()):
                self.admission_depth.observe(d)
            for n in obs.get("packed_segments", ()):
                self.packed_segments.observe(n)
        if "restarts" in st:          # supervised engine
            self.restarts.set_to(st["restarts"])
            self.watchdog_trips.set_to(st["watchdog_trips"])
            self.replayed_tokens.set_to(st["replayed_tokens"])
            self.quarantined.set_to(st["quarantined"])
            for t in st["recovery_log"][self._recovery_seen:]:
                self.recovery.observe(t)
            self._recovery_seen = len(st["recovery_log"])
            self.set_health(st["health"])

    def set_health(self, state: str):
        for s in ("ok", "warming", "degraded", "draining", "dead"):
            self.health.set(1.0 if s == state else 0.0, state=s)

    def render(self) -> str:
        return self.registry.render()
