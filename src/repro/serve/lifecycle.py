"""Request lifecycle for the serving front door: validation, state,
per-request timing.

This module is transport-agnostic — no HTTP, no asyncio — so the request
state machine and the TTFT/ITL timing rules are testable with a fake clock
and reusable by any front end (the HTTP server, the benchmark client, a
future gRPC door).

**Validation** (``parse_completion_request``) turns an untrusted JSON body
into typed ``CompletionParams`` or raises ``ValidationError`` carrying the
offending ``param`` — the server maps that to an OpenAI-style 400 error
object. The model here has no text tokenizer (the repo serves token ids),
so ``prompt`` is a list of int token ids (or a string of
whitespace-separated ints, for curl ergonomics), each validated against
the vocabulary.

**Timing** (``RequestLifecycle``): TTFT is observed once, at the arrival
of the first token-bearing event — chunked prefill only delays that event.
Inter-token latency observes **one gap per token-bearing arrival**, never
one per token: a ``decode_horizon=H`` dispatch delivers up to H tokens in
one event, and the only latency a streaming client experienced is the
single gap since the previous flush. Recording H copies (or H-1 zeros)
would fabricate latencies nobody saw; the histogram's count therefore
tracks flushes, not tokens (tokens have their own counter).

States: ``QUEUED`` (accepted, engine-side) -> ``STREAMING`` (first token
seen) -> ``DONE`` with a finish reason in {``stop``, ``length``,
``cancelled``, ``timeout``}. Requests rejected before acceptance (4xx/429)
never get a lifecycle — they are counted by the server directly.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

QUEUED, STREAMING, DONE = "queued", "streaming", "done"

FINISH_STOP = "stop"            # hit an eos/stop token (token included)
FINISH_LENGTH = "length"        # exhausted max_tokens
FINISH_CANCELLED = "cancelled"  # client disconnect / explicit abort
FINISH_TIMEOUT = "timeout"      # server-side deadline exceeded
FINISH_ERROR = "error"          # engine-side failure (quarantine, death)
FINISH_REASONS = (FINISH_STOP, FINISH_LENGTH, FINISH_CANCELLED,
                  FINISH_TIMEOUT, FINISH_ERROR)


class ValidationError(ValueError):
    """A request field failed validation. ``param`` names the field; the
    server renders it as an OpenAI-style ``invalid_request_error``."""

    def __init__(self, message: str, param: Optional[str] = None):
        super().__init__(message)
        self.param = param


PRIORITY_CLASSES = ("interactive", "standard", "batch")


@dataclasses.dataclass(frozen=True)
class CompletionParams:
    """A validated ``/v1/completions`` request body."""
    prompt: np.ndarray              # (P,) int32 token ids
    max_tokens: int
    temperature: float
    stop_ids: Tuple[int, ...]       # generation stops on any of these
    stream: bool
    timeout_s: Optional[float]      # per-request server-side deadline
    # overload control plane (DESIGN.md Sec. 17): the scheduling class and
    # an optional soft deadline. The deadline orders admission within a
    # class (EDF) and protects a nearly-due sequence from preemption; it
    # never aborts work — `timeout` owns hard cancellation.
    priority: str = "standard"
    deadline_ms: Optional[float] = None

    @property
    def eos_id(self) -> Optional[int]:
        """The engine-native stop token: with exactly one stop id the
        engine's own eos path handles it (including mid-horizon on-device
        retirement); multiple stop ids are monitored by the server loop."""
        return self.stop_ids[0] if len(self.stop_ids) == 1 else None


def _require_int(value, param, lo=None, hi=None):
    if isinstance(value, bool) or not isinstance(value, int):
        raise ValidationError(f"{param} must be an integer, got "
                              f"{type(value).__name__}", param=param)
    if lo is not None and value < lo:
        raise ValidationError(f"{param} must be >= {lo}, got {value}",
                              param=param)
    if hi is not None and value > hi:
        raise ValidationError(f"{param} must be <= {hi}, got {value}",
                              param=param)
    return value


def _parse_token_list(raw, param, vocab_size):
    if isinstance(raw, str):
        try:
            raw = [int(t) for t in raw.split()]
        except ValueError:
            raise ValidationError(
                f"{param} string form must be whitespace-separated integer "
                "token ids (this model serves token ids, not text)",
                param=param)
    if not isinstance(raw, (list, tuple)):
        raise ValidationError(f"{param} must be a list of integer token ids "
                              "or a string of whitespace-separated ids",
                              param=param)
    toks = [_require_int(t, param, lo=0, hi=vocab_size - 1) for t in raw]
    return toks


def parse_completion_request(body, *, vocab_size, default_max_tokens=16,
                             max_tokens_cap=2048,
                             max_timeout_s=None) -> CompletionParams:
    """Validate an OpenAI-style completions body. Raises ValidationError
    (maps to 400) naming the offending param. Notes vs stock OpenAI:
    ``prompt`` is token ids; ``temperature`` must be 0 (the continuous
    engine samples greedily on host and on device — reproducibility is the
    contract; non-zero sampling is a ROADMAP item) and defaults to 0;
    ``stop`` is up to 4 token ids; ``timeout`` (seconds) is an extension,
    capped at the server's configured maximum. ``priority``
    ("interactive" | "standard" | "batch", default "standard") and
    ``deadline_ms`` (positive, relative to arrival) are the overload
    control plane's extensions — see DESIGN.md Sec. 17."""
    if not isinstance(body, dict):
        raise ValidationError("request body must be a JSON object")
    if "n" in body and body["n"] != 1:
        raise ValidationError("n must be 1 (use fork_request for n-best)",
                              param="n")

    if "prompt" not in body:
        raise ValidationError("prompt is required", param="prompt")
    toks = _parse_token_list(body["prompt"], "prompt", vocab_size)
    if not toks:
        raise ValidationError("prompt must not be empty", param="prompt")

    max_tokens = _require_int(body.get("max_tokens", default_max_tokens),
                              "max_tokens", lo=1, hi=max_tokens_cap)

    temperature = body.get("temperature", 0.0)
    if isinstance(temperature, bool) or \
            not isinstance(temperature, (int, float)):
        raise ValidationError("temperature must be a number",
                              param="temperature")
    if temperature != 0:
        raise ValidationError(
            "temperature must be 0: this engine decodes greedily (on host "
            "and fused on device) so outputs are reproducible; sampled "
            "decoding is not implemented yet", param="temperature")

    stop_raw = body.get("stop", [])
    if isinstance(stop_raw, int) and not isinstance(stop_raw, bool):
        stop_raw = [stop_raw]
    stop_ids = tuple(_parse_token_list(stop_raw, "stop", vocab_size))
    if len(stop_ids) > 4:
        raise ValidationError("stop supports at most 4 token ids",
                              param="stop")

    stream = body.get("stream", False)
    if not isinstance(stream, bool):
        raise ValidationError("stream must be a boolean", param="stream")

    timeout_s = body.get("timeout")
    if timeout_s is not None:
        if isinstance(timeout_s, bool) or \
                not isinstance(timeout_s, (int, float)) or timeout_s <= 0:
            raise ValidationError("timeout must be a positive number of "
                                  "seconds", param="timeout")
        timeout_s = float(timeout_s)
    if max_timeout_s is not None:
        timeout_s = min(timeout_s or max_timeout_s, max_timeout_s)

    priority = body.get("priority", "standard")
    if priority not in PRIORITY_CLASSES:
        raise ValidationError(
            f"priority must be one of {list(PRIORITY_CLASSES)}, "
            f"got {priority!r}", param="priority")

    deadline_ms = body.get("deadline_ms")
    if deadline_ms is not None:
        if isinstance(deadline_ms, bool) or \
                not isinstance(deadline_ms, (int, float)) or deadline_ms <= 0:
            raise ValidationError("deadline_ms must be a positive number of "
                                  "milliseconds", param="deadline_ms")
        deadline_ms = float(deadline_ms)

    return CompletionParams(
        prompt=np.asarray(toks, np.int32), max_tokens=max_tokens,
        temperature=float(temperature), stop_ids=stop_ids, stream=stream,
        timeout_s=timeout_s, priority=priority, deadline_ms=deadline_ms)


class RequestLifecycle:
    """Timing + state for one accepted request.

    Driven by the engine loop: ``on_accepted(now)`` when the engine takes
    the submit, ``on_tokens(n, now)`` per token-bearing drain,
    ``on_finish(reason, now)`` exactly once. Metrics (a ``ServeMetrics``)
    are optional so the class unit-tests with a fake clock and no registry.
    """

    _ids = iter(range(1, 1 << 62))

    def __init__(self, params: CompletionParams, metrics=None,
                 request_id: Optional[str] = None):
        self.params = params
        self.metrics = metrics
        self.request_id = request_id or f"cmpl-{next(self._ids)}"
        self.engine_id: Optional[int] = None      # set at engine submit
        self.state = QUEUED
        self.finish_reason: Optional[str] = None
        self.n_tokens = 0
        self.token_ids: List[int] = []
        self.accepted_at: Optional[float] = None
        self.deadline: Optional[float] = None
        self.first_token_at: Optional[float] = None
        self.last_flush_at: Optional[float] = None
        # transport event sink, owned by the server: the asyncio loop and
        # per-request queue the engine loop forwards events into (None for
        # non-HTTP drivers, e.g. the unit tests), plus the wall-clock
        # `created` stamp shared by every response chunk of this request
        self.loop = None
        self.queue = None
        self.created = None

    def on_accepted(self, now: float):
        self.accepted_at = now
        if self.params.timeout_s is not None:
            self.deadline = now + self.params.timeout_s

    def timed_out(self, now: float) -> bool:
        return (self.state != DONE and self.deadline is not None
                and now >= self.deadline)

    def on_tokens(self, tokens: Sequence[int], now: float):
        """Record a token-bearing arrival: TTFT on the first, exactly one
        ITL gap observation per subsequent arrival (see module docstring
        for why horizon bursts must not multi-count)."""
        if not tokens:
            return
        self.token_ids.extend(int(t) for t in tokens)
        self.n_tokens += len(tokens)
        if self.first_token_at is None:
            self.first_token_at = now
            self.state = STREAMING
            if self.metrics is not None and self.accepted_at is not None:
                self.metrics.ttft.observe(now - self.accepted_at)
        else:
            if self.metrics is not None:
                self.metrics.itl.observe(now - self.last_flush_at)
        self.last_flush_at = now

    def on_finish(self, reason: str, now: float):
        if self.state == DONE:
            return
        if reason not in FINISH_REASONS:
            raise ValueError(f"unknown finish reason {reason!r}")
        self.state = DONE
        self.finish_reason = reason
        if self.metrics is not None:
            self.metrics.requests.inc(outcome=reason)
