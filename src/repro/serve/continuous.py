"""Continuous-batching serving engine over a paged KV cache.

Step-driven API: ``submit()`` enqueues requests, each ``step()`` runs one
scheduler-chosen unit of work (a prefill chunk or a packed decode batch),
``collect()`` drains finished outputs. The data plane is a handful of jit
traces of one function (``Model.paged_step``):

  * prefill trace:  tokens (1, prefill_chunk) — one sequence, chunked
  * decode  traces: tokens (2^k, 1), 2^k <= max_batch — the decoding set
    padded to the next power of two (bucketed shapes bound retraces at
    log2(max_batch)+1 while keeping padding waste under 2x at low
    concurrency)

All shapes are static; inactive rows / chunk tails carry q_pos == -1 and
scatter into the reserved scratch page, so no retracing ever happens once
the buckets are warm. With the default ``decode_horizon=1`` greedy sampling
happens on host from the returned last-token logits, which is what makes
output token-identical to the static ``ServeEngine`` (same model math, same
argmax).

``decode_horizon=H`` (DESIGN.md Sec. 12) inverts that host/device contract
on the decode hot path: one jitted dispatch runs H decode iterations as a
``lax.scan`` with greedy sampling *on device*, each iteration feeding its
argmax back through the carry and writing K/V through the paged path.
Per-row stop masks retire rows that hit eos or exhaust their budget
mid-horizon (their remaining iterations are exact no-ops via the scratch-
page convention), the scheduler reserves the whole horizon lease up front
so page boundaries are crossed without host help, and only (B, H) sampled
tokens + done masks cross back — never (B, vocab) logits. Greedy outputs
are token-identical to ``decode_horizon=1`` for every execution mode and
mesh size (tested); preemption, prefix registration and ``fork_request``
semantics are unchanged.

Automatic prefix caching (on by default; DESIGN.md Sec. 11): committed
full KV pages register under a rolling content hash of their token chain,
admission longest-prefix-matches new requests against the registry, and
``fork_request()`` shares a live request's pages n ways by refcount. A
thousand requests behind one system prompt prefill it once; the metrics
``n_prefix_hits`` / ``n_prefix_positions_saved`` account for the reuse.
Greedy outputs are token-identical with the cache on or off — matched
pages hold exactly the K/V the skipped prefill would have written.

``kv_bits=8|4`` (DESIGN.md Sec. 15) stores committed KV pages MSB-quantized
(per-head block-wise group scales, the paper's codec applied to the cache):
pages are quantized on device the moment a dispatch completes them, the one
partial page per sequence stays full-precision in a per-slot hot row, and
attention dequantizes fused into the page gather. A 4-bit page pool holds
~4-6x the sequences of a bf16 pool before preemption; 8-bit is greedy
token-identical on the smoke models, 4-bit bounded-drift. Scheduling,
prefix caching (token-hash keyed, so matching is representation-agnostic),
forks, preemption and supervision are unchanged.

``mesh=`` runs the whole data plane tensor-parallel (DESIGN.md Sec. 10):
params partition along N/K/experts/vocab, the page pools by KV head, and
every step is one ``shard_map`` dispatch with manual psum/all_gather
collectives. The scheduler, allocator and sampling stay on host and
unsharded — greedy output is token-identical across TP sizes.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .paged_cache import PagedKVCache
from .scheduler import DECODE, FINISHED, Request, Scheduler, Sequence

# Module-level jit, model static (frozen dataclass, hashable): every engine
# for the same model shares one compile cache, and the pools are donated so
# the per-step cache update is in place (donation is a no-op warning on
# backends without buffer aliasing, e.g. CPU, so it's gated). kv_bits is
# static (16 native pools, 8|4 the dual quantized pools of DESIGN.md
# Sec. 15); slots maps batch rows to engine slots so quantized writes hit
# the right hot row (-1 pads land on the scratch row).
_DONATE = (2,) if jax.default_backend() in ("tpu", "gpu") else ()

# Trace-count probe (DESIGN.md Sec. 16): a Python-side counter bumped at
# the top of every traced dispatch body. jit runs the Python body once per
# trace, never per call, so after AOT warmup a steady-state serving run
# must leave this number unchanged — the property the warmup tests and the
# ``msb_traces_compiled_total`` metric assert. Process-global on purpose:
# module-level jits share their compile cache across engines.
_N_TRACES = 0


def _note_trace():
    global _N_TRACES
    _N_TRACES += 1


def jit_trace_count() -> int:
    """Traced-dispatch events so far in this process (monotonic)."""
    return _N_TRACES


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=_DONATE)
def _paged_step(model, kv_bits, pools, params, tokens, q_pos, kv_lens,
                block_tables, slots):
    _note_trace()
    return model.paged_step(params, pools, tokens, q_pos, kv_lens,
                            block_tables, kv_bits=kv_bits, slots=slots)


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=_DONATE)
def _paged_prefill_packed(model, kv_bits, pools, params, tokens, seg_ids,
                          q_pos, kv_lens, block_tables, slots, last_idx,
                          seg_off):
    _note_trace()
    return model.paged_prefill_packed(params, pools, tokens, seg_ids, q_pos,
                                      kv_lens, block_tables, slots, last_idx,
                                      seg_off, kv_bits=kv_bits)


# decode-horizon dispatch: pools is positional arg 3 here (model, the
# static horizon and kv_bits precede it), hence the shifted donation index
_DONATE_H = (3,) if jax.default_backend() in ("tpu", "gpu") else ()


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=_DONATE_H)
def _paged_horizon_step(model, horizon, kv_bits, pools, params, tokens,
                        start_pos, n_left, eos_ids, block_tables, slots):
    _note_trace()
    return model.paged_decode_horizon(params, pools, tokens, start_pos,
                                      block_tables, n_left, eos_ids, horizon,
                                      kv_bits=kv_bits, slots=slots)


@dataclasses.dataclass
class ContinuousEngine:
    model: object
    params: object
    max_batch: int = 8
    page_size: int = 16
    num_pages: int = 128
    max_seq: Optional[int] = None          # bounds block-table width
    max_pages_per_seq: Optional[int] = None
    prefill_chunk: int = 32
    parallel: object = None
    execution: Optional[str] = None   # "packed" | "simulated" | None=auto
    mesh: object = None               # tensor-parallel device mesh
    prefix_cache: bool = True         # automatic cross-request prefix reuse
    decode_horizon: int = 1           # fused decode steps per dispatch
    kv_bits: int = 16                 # committed-page precision: 16 | 8 | 4
    max_waiting: Optional[int] = None  # backpressure: bound on waiting queue
    faults: object = None             # FaultPlan (testing); None = NO_FAULTS
    prefill_packing: bool = True      # pack prompts into ragged dispatches
    prefill_buckets: object = None    # packed lengths; None = derived ladder

    def __post_init__(self):
        from .engine import resolve_execution
        if self.mesh is not None and self.parallel is not None:
            raise ValueError("pass either mesh= (manual TP) or parallel= "
                             "(GSPMD), not both")
        # reject unsupported models before the O(params) pack pass
        if not self.model.supports_paged():
            raise ValueError(
                f"{self.model.cfg.name}: paged serving needs a decoder-only "
                "attention stack (ssm/xlstm/enc-dec caches are not paged)")
        self.execution, self.params = resolve_execution(self.execution,
                                                        self.params)
        self.decode_horizon = int(self.decode_horizon)
        if self.decode_horizon < 1:
            raise ValueError(f"decode_horizon must be >= 1, "
                             f"got {self.decode_horizon}")
        self.kv_bits = int(self.kv_bits)
        if self.kv_bits not in (16, 8, 4):
            raise ValueError(f"kv_bits must be 16, 8 or 4, "
                             f"got {self.kv_bits}")
        if self.faults is None:
            from .faults import NO_FAULTS
            self.faults = NO_FAULTS
        mpps = self.max_pages_per_seq
        if mpps is None and self.max_seq is not None:
            mpps = -(-self.max_seq // self.page_size)
        self.cache = PagedKVCache(
            self.model, num_pages=self.num_pages, page_size=self.page_size,
            max_seqs=self.max_batch, max_pages_per_seq=mpps,
            prefix_cache=self.prefix_cache, faults=self.faults,
            kv_bits=self.kv_bits)
        # packed ragged prefill (DESIGN.md Sec. 16): the bucket ladder is
        # the set of packed token lengths — each is one jit trace, so the
        # default caps it at three powers-of-two steps from prefill_chunk;
        # pass prefill_buckets= to widen/narrow the set explicitly
        if self.prefill_packing:
            if self.prefill_buckets is None:
                c = self.prefill_chunk
                self.prefill_buckets = (c, 2 * c, 4 * c)
            self.prefill_buckets = tuple(
                sorted(int(b) for b in self.prefill_buckets))
            if self.prefill_buckets[0] < 1:
                raise ValueError("prefill_buckets must be positive")
        else:
            self.prefill_buckets = None
        self.scheduler = Scheduler(self.cache, self.max_batch,
                                   self.prefill_chunk,
                                   decode_horizon=self.decode_horizon,
                                   max_waiting=self.max_waiting,
                                   prefill_buckets=self.prefill_buckets)
        if self.mesh is not None:
            self._init_tensor_parallel()
        elif self.parallel is None:
            self._step_fn = functools.partial(_paged_step, self.model,
                                              self.kv_bits)
            self._horizon_fn = functools.partial(
                _paged_horizon_step, self.model, self.decode_horizon,
                self.kv_bits)
            self._prefill_fn = functools.partial(
                _paged_prefill_packed, self.model, self.kv_bits)
        else:                              # parallel objects aren't hashable
            def _gspmd_step(pools, p, toks, qpos, kvl, bt, sl):
                _note_trace()
                return self.model.paged_step(
                    p, pools, toks, qpos, kvl, bt, self.parallel,
                    kv_bits=self.kv_bits, slots=sl)

            def _gspmd_horizon(pools, p, toks, sp, nl, eos, bt, sl):
                _note_trace()
                return self.model.paged_decode_horizon(
                    p, pools, toks, sp, bt, nl, eos, self.decode_horizon,
                    self.parallel, kv_bits=self.kv_bits, slots=sl)

            def _gspmd_prefill(pools, p, toks, segs, qpos, kvl, bt, sl, li,
                               so):
                _note_trace()
                return self.model.paged_prefill_packed(
                    p, pools, toks, segs, qpos, kvl, bt, sl, li, so,
                    self.parallel, kv_bits=self.kv_bits)

            self._step_fn = jax.jit(_gspmd_step)
            self._horizon_fn = jax.jit(_gspmd_horizon)
            self._prefill_fn = jax.jit(_gspmd_prefill)
        self._next_id = 0
        self._seqs: Dict[int, Sequence] = {}
        self._finished: Dict[int, np.ndarray] = {}
        self._stream_off: Dict[int, int] = {}   # tokens already streamed
        self.n_aborts = 0             # abort_request cancellations
        self.n_steps = 0
        self.n_decode_steps = 0       # decode dispatches (any horizon)
        self.n_host_syncs = 0         # blocking device->host transfers
        self.n_tokens_out = 0
        self.n_work_positions = 0     # device token-positions incl. padding
        self.n_forks = 0              # fork_request children that shared pages
        self.n_prefill_dispatches = 0  # prefill device dispatches (any kind)
        self.n_prefill_segments = 0    # sequences served across them
        # segments-per-packed-dispatch samples, drained by the metrics sync
        # into the msb_prefill_packed_segments histogram (bounded if no one
        # drains, e.g. a bench driving the engine directly)
        self.packed_segment_obs: List[int] = []
        self.warmup_seconds = 0.0     # wall seconds spent in warmup()
        self.warmup_entries = 0       # dispatch shapes warmed
        # crash blame: request ids in the work unit the current (or most
        # recently crashed) step dispatched — a prefill names one sequence,
        # a decode names the batch; () before any work is scheduled. The
        # supervisor reads this to attribute a crash (DESIGN.md Sec. 14).
        self.last_step_rids: Tuple[int, ...] = ()
        self.last_step_kind: str = ""

    def _init_tensor_parallel(self):
        """Shard params + page pools over ``mesh`` and build the shard_map
        step (DESIGN.md Sec. 10).

        Packed codes/codebooks partition along N (column-parallel QKV/up/
        gate, vocab) and K (row-parallel o/down, psum inside the step); the
        K/V page pools partition along the KV-head dim whenever the head
        counts divide the mesh's model axis; the block tables, token batch
        and logits stay replicated, so the scheduler/allocator control
        plane is untouched.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..core.policy import tp_localize, tp_partition_params
        from ..parallel.sharding import TPShard, from_mesh, shard_map_compat
        ctx = from_mesh(self.mesh)
        tp = TPShard(axis=ctx.tp_axis, size=ctx.tp_size)
        self.tp = tp
        cfg = self.model.cfg
        self.params, pspecs, self.tp_report = tp_partition_params(
            self.params, tp.size, cfg=cfg, axis=tp.axis)
        self.params = jax.device_put(
            self.params,
            jax.tree_util.tree_map(lambda s: NamedSharding(self.mesh, s),
                                   pspecs))
        heads_ok = (tp.size > 1 and cfg.n_heads % tp.size == 0
                    and cfg.n_kv_heads % tp.size == 0)

        # per-leaf pool specs: groups never cross heads in the KV codec, so
        # every pool representation shards cleanly along its KV-head dim —
        # native k/v and quantized codes/hot rows carry it at axis 3
        # (n_periods, pages|rows, page_size, KV, hd), the per-page scale
        # codebooks at axis 2 (n_periods, pages, KV, n_blocks, G)
        def leaf_spec(path, leaf):
            if not heads_ok:
                return P()
            name = str(getattr(path[-1], "key", ""))
            if name.endswith("_scales"):
                return P(None, None, tp.axis, None, None)
            return P(None, None, None, tp.axis, None)

        pool_spec = jax.tree_util.tree_map_with_path(leaf_spec,
                                                     self.cache.pools)
        self.cache.pools = jax.tree_util.tree_map(
            lambda leaf, s: jax.device_put(leaf, NamedSharding(self.mesh, s)),
            self.cache.pools, pool_spec)
        model, rep, kv_bits = self.model, P(), self.kv_bits

        def local_step(pools, params, tokens, q_pos, kv_lens, bt, slots):
            _note_trace()
            return model.paged_step(tp_localize(params), pools, tokens,
                                    q_pos, kv_lens, bt, parallel=tp,
                                    kv_bits=kv_bits, slots=slots)

        fn = shard_map_compat(
            local_step, self.mesh,
            in_specs=(pool_spec, pspecs, rep, rep, rep, rep, rep),
            out_specs=(rep, pool_spec))
        donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
        self._step_fn = jax.jit(fn, donate_argnums=donate)

        # packed ragged prefill under the same mesh: control arrays (seg
        # ids, positions, tables, offsets) are replicated; only the pools
        # and params are sharded, so the packed gather runs per rank on its
        # KV-head slice exactly like the unpacked step
        def local_prefill(pools, params, tokens, seg_ids, q_pos, kv_lens,
                          bt, slots, last_idx, seg_off):
            _note_trace()
            return model.paged_prefill_packed(
                tp_localize(params), pools, tokens, seg_ids, q_pos, kv_lens,
                bt, slots, last_idx, seg_off, parallel=tp, kv_bits=kv_bits)

        pfn = shard_map_compat(
            local_prefill, self.mesh,
            in_specs=(pool_spec, pspecs, rep, rep, rep, rep, rep, rep, rep,
                      rep),
            out_specs=(rep, pool_spec))
        self._prefill_fn = jax.jit(pfn, donate_argnums=donate)

        # the decode-horizon scan lives *inside* the shard_map body, so H
        # fused iterations (collectives included) are still one dispatch
        horizon = self.decode_horizon

        def local_horizon(pools, params, tokens, start_pos, n_left, eos, bt,
                          slots):
            _note_trace()
            return model.paged_decode_horizon(
                tp_localize(params), pools, tokens, start_pos, bt, n_left,
                eos, horizon, parallel=tp, kv_bits=kv_bits, slots=slots)

        hfn = shard_map_compat(
            local_horizon, self.mesh,
            in_specs=(pool_spec, pspecs, rep, rep, rep, rep, rep, rep),
            out_specs=(rep, rep, pool_spec))
        self._horizon_fn = jax.jit(hfn, donate_argnums=donate)

    # -- API ----------------------------------------------------------------
    def submit(self, prompt, max_new_tokens, eos_id=None,
               priority="standard", deadline_ms=None,
               deadline_at=None) -> int:
        """Enqueue one request; returns its id (the ``collect()`` key).

        Non-blocking and device-free: nothing is scheduled or transferred
        until a ``step()``. Raises ``ValueError`` if ``prompt`` plus
        ``max_new_tokens`` can never fit the page pool (admission control —
        an accepted request is guaranteed to eventually complete, through
        preemption if need be), and ``Saturated`` when backpressure is on
        (``max_waiting=``) and the waiting queue or page-demand bound is
        exceeded, or a brownout level sheds ``priority``'s class — a
        transient condition the caller should retry (HTTP 429). Generation
        stops after ``max_new_tokens`` or on the first ``eos_id`` (which is
        included in the output).

        ``priority`` ("interactive" | "standard" | "batch") orders
        admission, preemption victims and brownout shedding (DESIGN.md
        Sec. 17); ``deadline_ms`` (relative, from now) orders admission
        within the class (EDF) and protects a nearly-due sequence from
        preemption — it never aborts work. ``deadline_at`` is the absolute
        ``time.monotonic()`` form the supervisor uses on replay so a crash
        does not extend a request's deadline.
        """
        req_id = self._next_id
        self._next_id += 1
        now = time.monotonic()
        if deadline_at is None and deadline_ms is not None:
            deadline_at = now + float(deadline_ms) / 1000.0
        req = Request(req_id, np.asarray(prompt, np.int32).reshape(-1),
                      int(max_new_tokens), eos_id, priority=priority,
                      deadline=deadline_at, submitted_at=now)
        self._seqs[req_id] = self.scheduler.submit(req)
        return req_id

    def would_accept(self, prompt_len, max_new_tokens,
                     priority="standard") -> Optional[Exception]:
        """Mutation-free admission probe: ``None`` when a ``submit`` of this
        size issued right now would be accepted, else the exception it would
        raise (``ValueError`` = can never fit, ``scheduler.Saturated`` =
        busy or class shed under brownout, retry later). Safe to call from
        a thread other than the one driving ``step()`` — it only reads
        counters, and ``submit`` re-validates, so a stale answer costs one
        exception, never state."""
        return self.scheduler.would_accept(
            int(prompt_len) + int(max_new_tokens), priority=priority)

    def step(self) -> bool:
        """Run one scheduler-chosen unit of work (one prefill chunk or one
        packed decode batch = one jitted device dispatch, covering up to
        ``decode_horizon`` fused decode iterations per row); returns False
        when no submitted work remains. Safe to interleave with ``submit``
        — new requests join from the next step. Greedy sampling happens on
        host at ``decode_horizon=1`` and on device inside the fused scan
        otherwise; both are the same f32 argmax, so outputs are
        reproducible across ``execution`` modes, TP meshes and horizons."""
        # blame is reset *before* the step fault-site fires so a crash here
        # (pre-schedule) attributes to no specific request
        self.last_step_rids = ()
        self.last_step_kind = ""
        if self.faults.armed:
            self.faults.fire("step")
        work = self.scheduler.schedule()
        if work is None:
            return False
        self.last_step_kind = work[0]
        if work[0] == "prefill":
            self.last_step_rids = (work[1].req.req_id,)
        elif work[0] == "prefill_packed":
            self.last_step_rids = tuple(s.req.req_id for s, _, _ in work[1])
        else:
            self.last_step_rids = tuple(s.req.req_id for s in work[1])
        self.n_steps += 1
        if work[0] == "prefill":
            self._run_prefill(*work[1:])
        elif work[0] == "prefill_packed":
            self._run_prefill_packed(*work[1:])
        else:
            self._run_decode(work[1])
        return True

    def fork_request(self, req_id, n=1, max_new_tokens=None,
                     eos_id=None) -> List[int]:
        """Fork an unfinished request into ``n`` independent continuations;
        returns their new request ids (``collect()`` keys).

        Each child continues from the parent's current position — prompt
        plus everything sampled so far — with a fresh ``max_new_tokens``
        budget (the parent's unless overridden) and keeps decoding
        independently of the parent. A running parent's KV pages are shared
        by refcount via ``PagedKVCache.fork`` (device copy only for the
        final partial page); if the pool cannot host a fork right now the
        child falls back to the waiting queue as a plain resubmission of
        the parent's tokens, where admission-time prefix matching recovers
        the sharing — either way no slot or page leaks. Under greedy
        sampling every child reproduces the parent's own continuation,
        which is what makes the API testable; it exists for divergent
        continuations (n-best with different budgets/eos, speculative
        branches) once non-greedy sampling lands."""
        seq = self._seqs.get(req_id)
        if seq is None:
            raise KeyError(f"unknown request id {req_id}")
        if seq.state == FINISHED:
            raise ValueError(f"request {req_id} already finished; its pages "
                             "are released (resubmit its tokens instead)")
        budget = (seq.req.max_new_tokens if max_new_tokens is None
                  else int(max_new_tokens))
        # same admission-control gate as submit(): a child whose fork-point
        # prompt plus fresh budget can never fit would otherwise be admitted
        # on pool headroom alone and self-preempt forever at the
        # max_pages_per_seq reserve, livelocking the queue behind it
        why = self.cache.capacity_error(len(seq.tokens) + budget)
        if why is not None:
            raise ValueError(f"fork of request {req_id}: {why}")
        new_ids: List[int] = []
        for _ in range(int(n)):
            new_id = self._next_id
            self._next_id += 1
            req = Request(new_id, seq.tokens.copy(), budget,
                          seq.req.eos_id if eos_id is None else eos_id,
                          priority=seq.req.priority,
                          submitted_at=time.monotonic())
            child = Sequence(req)
            dst = self.cache.fork(seq.slot) if seq.slot >= 0 else None
            if dst is not None:
                child.slot = dst
                child.cache_len = int(self.cache.seq_lens[dst])
                self.scheduler.running.append(child)
                self.n_forks += 1
            else:
                self.scheduler.waiting.append(child)
            self._seqs[new_id] = child
            new_ids.append(new_id)
        return new_ids

    def abort_request(self, req_id) -> bool:
        """Cancel a request at any point in its lifecycle (client
        disconnect, server timeout). Frees everything it holds: a waiting
        request leaves the queue; a running one releases its slot — all its
        pages, including any outstanding decode-horizon lease, return to
        the allocator, and pages it adopted from (or registered into) the
        prefix cache are decref'd onto the reclaimable LRU. Pool accounting
        returns to baseline: nothing leaks (negative-tested).

        Returns True if the request was cancelled, False if it had already
        finished — in which case its uncollected output is *dropped* (the
        caller no longer wants it). Raises ``KeyError`` for ids never
        submitted or already collected/streamed. Must be called from the
        thread driving ``step()`` (the engine is single-threaded; a server
        serializes aborts through its engine loop)."""
        seq = self._seqs.pop(req_id, None)
        if seq is None:
            raise KeyError(f"unknown request id {req_id}")
        self._stream_off.pop(req_id, None)
        self._finished.pop(req_id, None)
        ok = self.scheduler.abort(seq)
        if ok:
            self.n_aborts += 1
        return ok

    def collect(self) -> Dict[int, np.ndarray]:
        """Drain outputs finished since the last ``collect()``: a dict
        ``req_id -> int32 generated tokens`` (prompt not included). Each
        finished request is returned exactly once; uncollected results are
        held, never dropped."""
        out, self._finished = self._finished, {}
        return out

    def stream_updates(self) -> Dict[int, Tuple[List[int], bool]]:
        """Per-token streaming drain: ``{req_id: (new_tokens, finished)}``
        for every request that produced tokens (or finished) since the last
        call. The streaming complement to ``collect()`` — call it after
        each ``step()`` to observe tokens as they are sampled instead of
        waiting for completion. Tokens are reported exactly once and in
        order (``generated`` is append-only, even across preemption, so
        offsets never rewind); with ``decode_horizon=H`` up to H tokens
        arrive per call. A finished request is reported with
        ``finished=True`` exactly once and then fully retired: it leaves
        the ``collect()`` buffer too, so use one drain style per request,
        not both."""
        out: Dict[int, Tuple[List[int], bool]] = {}
        for rid in list(self._seqs):
            seq = self._seqs[rid]
            off = self._stream_off.get(rid, 0)
            new = [int(t) for t in seq.generated[off:]]
            done = seq.state == FINISHED
            if new or done:
                out[rid] = (new, done)
            if done:
                del self._seqs[rid]
                self._stream_off.pop(rid, None)
                self._finished.pop(rid, None)
            elif new:
                self._stream_off[rid] = off + len(new)
        return out

    # -- metrics -------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        """True while any submitted request is unfinished. The generic
        driving predicate for engine owners (``EngineLoop``, benches) —
        the ``EngineSupervisor`` mirrors it, so loops written against
        ``has_work`` drive a raw engine and a supervised one alike."""
        return self.scheduler.has_work

    def stats(self) -> Dict[str, object]:
        """Counter snapshot for metrics exporters. Monotonic counts plus
        the two instantaneous gauges (``queue_depth``, ``running``). The
        ``EngineSupervisor`` exposes the same schema aggregated across
        engine rebuilds — ``ServeMetrics.sync_engine`` consumes either."""
        s = self.scheduler
        return {
            "tokens_out": self.n_tokens_out,
            "steps": self.n_steps,
            "decode_steps": self.n_decode_steps,
            "host_syncs": self.n_host_syncs,
            "work_positions": self.n_work_positions,
            "aborts": self.n_aborts,
            "preemptions": s.n_preemptions,
            "admissions": s.n_admissions,
            "prefix_hits": s.n_prefix_hits,
            "prefix_positions_saved": s.n_prefix_tokens,
            "forks": self.n_forks,
            "prefill_dispatches": self.n_prefill_dispatches,
            "prefill_segments": self.n_prefill_segments,
            "admission_waves": s.n_admission_waves,
            "warmup_seconds": self.warmup_seconds,
            "warmup_traces": self.warmup_entries,
            "preemptions_by_class": dict(s.n_preemptions_by_class),
            "admissions_by_class": dict(s.n_admissions_by_class),
            "sheds_by_class": dict(s.n_sheds_by_class),
            "queue_depth": len(s.waiting),
            "running": len(s.running),
        }

    def drain_observations(self) -> Dict[str, List[int]]:
        """Histogram samples accumulated since the last drain:
        ``admission_queue_depth`` (one per admission wave — NOT per prefill
        chunk) and ``packed_segments`` (one per packed prefill dispatch).
        The metrics sync consumes these; each sample is returned once."""
        s = self.scheduler
        out = {"admission_queue_depth": s.queue_depth_obs,
               "packed_segments": self.packed_segment_obs}
        s.queue_depth_obs = []
        self.packed_segment_obs = []
        return out

    def warmup(self) -> Dict[str, object]:
        """AOT-warm every dispatch shape reachable from this engine's
        config (serve/warmup.py) so steady-state serving never traces.
        Returns the warmup report and records it in ``stats()``."""
        from .warmup import warm_engine
        report = warm_engine(self)
        self.warmup_seconds += report["seconds"]
        self.warmup_entries += report["entries"]
        return report

    def close(self, check: bool = True):
        """Tear down the engine. With ``check=True`` (default) the page
        allocator's full invariant suite runs first — refcounts, free
        list, lease extents, registry/LRU consistency — and, when no
        request is live, the pool must be back at its post-init baseline:
        zero leaked pages (``PageStateError`` otherwise)."""
        if check:
            self.cache.check_invariants(
                expect_idle=not self.scheduler.has_work)

    @property
    def n_prefix_hits(self):
        """Admissions that longest-prefix-matched the page registry."""
        return self.scheduler.n_prefix_hits

    @property
    def n_prefix_positions_saved(self):
        """Token positions adopted from the prefix cache instead of being
        prefilled (``cache_len`` started at the matched page boundary)."""
        return self.scheduler.n_prefix_tokens

    def run(self):
        """Drive until all submitted work is complete; return all outputs."""
        done: Dict[int, np.ndarray] = {}
        while self.scheduler.has_work:
            if not self.step():
                break
            done.update(self.collect())
        done.update(self.collect())
        return done

    # -- work kinds ----------------------------------------------------------
    def _run_prefill(self, seq, chunk_tokens, start):
        c = self.prefill_chunk
        n = len(chunk_tokens)
        self.n_prefill_dispatches += 1
        self.n_prefill_segments += 1
        tokens = np.zeros((1, c), np.int32)
        tokens[0, :n] = chunk_tokens
        q_pos = np.full((1, c), -1, np.int32)
        q_pos[0, :n] = start + np.arange(n)
        kv_lens = np.asarray([start + n], np.int32)
        logits = self._dispatch([seq.slot], tokens, q_pos, kv_lens)
        if self.faults.armed:
            self.faults.fire("apply")   # device written, host not yet
        seq.cache_len = start + n
        self.cache.commit(seq.slot, seq.cache_len)
        self.cache.register_prefix(seq.slot, seq.tokens[:seq.cache_len])
        if seq.cache_len == len(seq.tokens):        # prompt fully in cache
            if not seq.is_done():                   # e.g. max_new_tokens=0
                self._sample_and_advance(seq, logits[0])
            seq.state = DECODE
            self._maybe_finish(seq)

    def _run_prefill_packed(self, segs, bucket):
        """One ragged dispatch over up to ``max_batch`` segments' chunks
        (DESIGN.md Sec. 16). The packed row concatenates each segment's
        tokens (per-token seg ids route KV writes and gathers to the
        segment's own pages), the bucket pads the row to a pre-compiled
        length, and each segment's next-token logits come back at its
        ``last_idx``. Post-dispatch bookkeeping is the unpacked path's, per
        segment: commit, prefix registration, and sample-or-continue."""
        t = bucket
        s_max = self.max_batch
        tokens = np.zeros((t,), np.int32)
        seg_ids = np.full((t,), -1, np.int32)
        q_pos = np.full((t,), -1, np.int32)
        kv_lens = np.zeros((s_max,), np.int32)
        slots = np.full((s_max,), -1, np.int32)
        last_idx = np.zeros((s_max,), np.int32)
        seg_off = np.zeros((s_max,), np.int32)
        off = 0
        for i, (seq, start, n) in enumerate(segs):
            toks = seq.tokens
            tokens[off:off + n] = toks[start:start + n]
            seg_ids[off:off + n] = i
            q_pos[off:off + n] = start + np.arange(n)
            kv_lens[i] = start + n
            slots[i] = seq.slot
            seg_off[i] = off
            last_idx[i] = off + n - 1
            off += n
        self.n_prefill_dispatches += 1
        self.n_prefill_segments += len(segs)
        if len(self.packed_segment_obs) < 4096:
            self.packed_segment_obs.append(len(segs))
        self.n_work_positions += t
        bt = self.cache.table_rows([int(s) for s in slots])
        logits, self.cache.pools = self._prefill_fn(
            self.cache.pools, self.params, jnp.asarray(tokens),
            jnp.asarray(seg_ids), jnp.asarray(q_pos), jnp.asarray(kv_lens),
            bt, jnp.asarray(slots), jnp.asarray(last_idx),
            jnp.asarray(seg_off))
        self.n_host_syncs += 1          # blocking (S, vocab) logits fetch
        logits = np.asarray(logits)
        if self.faults.armed:
            self.faults.fire("apply")   # device written, host not yet
        for i, (seq, start, n) in enumerate(segs):
            seq.cache_len = start + n
            self.cache.commit(seq.slot, seq.cache_len)
            self.cache.register_prefix(seq.slot, seq.tokens[:seq.cache_len])
            if seq.cache_len == len(seq.tokens):    # prompt fully in cache
                if not seq.is_done():               # e.g. max_new_tokens=0
                    self._sample_and_advance(seq, logits[i])
                seq.state = DECODE
                self._maybe_finish(seq)

    def _decode_bucket(self, seqs):
        """Shared decode-batch shape policy: pad to the next power of two
        and gather each row's slot + last sampled (not-yet-cached) token.
        Both decode paths build on this so the bucket rounding and the
        last-token convention have one source of truth."""
        b = 1
        while b < len(seqs):
            b *= 2
        slots = [-1] * b
        last = np.zeros((b,), np.int32)
        for i, seq in enumerate(seqs):
            slots[i] = seq.slot
            last[i] = seq.generated[-1]
        return b, slots, last

    def _run_decode(self, seqs):
        self.n_decode_steps += 1
        if self.decode_horizon > 1:
            return self._run_decode_horizon(seqs)
        b, slots, last = self._decode_bucket(seqs)
        tokens = last[:, None]
        q_pos = np.full((b, 1), -1, np.int32)
        kv_lens = np.zeros((b,), np.int32)
        for i, seq in enumerate(seqs):
            q_pos[i, 0] = seq.n_total - 1
            kv_lens[i] = seq.n_total
        logits = self._dispatch(slots, tokens, q_pos, kv_lens)
        if self.faults.armed:
            self.faults.fire("apply")   # device written, host not yet
        for i, seq in enumerate(seqs):
            seq.cache_len = seq.n_total
            self.cache.commit(seq.slot, seq.cache_len)
            # decode advances one position per step, so a page fills (and
            # becomes registrable) exactly on the boundary commits
            if self.prefix_cache and seq.cache_len % self.page_size == 0:
                self.cache.register_prefix(seq.slot,
                                           seq.tokens[:seq.cache_len])
            self._sample_and_advance(seq, logits[i])
            self._maybe_finish(seq)

    def _run_decode_horizon(self, seqs):
        """One fused dispatch = up to ``decode_horizon`` decode iterations
        with on-device greedy sampling (DESIGN.md Sec. 12). Only (B, H)
        tokens + done masks come back; the host applies them in bulk —
        commit to the row's final extent, registration catches every page
        boundary crossed inside the horizon (``register_prefix`` is
        incremental over newly filled pages), and finish/eos semantics are
        unchanged because ``valid`` row masks are exact prefix masks."""
        h = self.decode_horizon
        # brownout horizon clamp (DESIGN.md Sec. 17): the *static* trace
        # horizon h never changes — a reduced effective horizon only lowers
        # the dynamic per-row budget below, so the same compiled scan
        # retires fewer tokens per dispatch. Schedule-only, trace-free,
        # and token-identical (greedy output is horizon-independent).
        eff = self.scheduler.effective_horizon
        b, slots, tokens = self._decode_bucket(seqs)
        start_pos = np.full((b,), -1, np.int32)
        n_left = np.zeros((b,), np.int32)
        eos = np.full((b,), -1, np.int32)
        for i, seq in enumerate(seqs):
            start_pos[i] = seq.n_total - 1
            n_left[i] = min(seq.req.max_new_tokens - len(seq.generated), eff)
            if seq.req.eos_id is not None:
                eos[i] = seq.req.eos_id
        self.n_work_positions += b * h
        bt = self.cache.table_rows(slots)
        out_tok, valid, self.cache.pools = self._horizon_fn(
            self.cache.pools, self.params, jnp.asarray(tokens),
            jnp.asarray(start_pos), jnp.asarray(n_left), jnp.asarray(eos),
            bt, jnp.asarray(np.asarray(slots, np.int32)))
        out_tok, valid = np.asarray(out_tok), np.asarray(valid)
        self.n_host_syncs += 1
        if self.faults.armed:
            self.faults.fire("apply")   # device written, host not yet
        for i, seq in enumerate(seqs):
            k = int(valid[i].sum())     # valid is a prefix mask per row
            for t in out_tok[i, :k]:
                seq.generated.append(int(t))
            self.n_tokens_out += k
            # the dispatch wrote K/V for each *input* token: positions
            # n_total-1 .. n_total-2+k of the pre-dispatch sequence — the
            # final sampled token is, as ever, not yet in the cache
            seq.cache_len = seq.n_total - 1
            self.cache.commit(seq.slot, seq.cache_len)
            if self.prefix_cache:
                self.cache.register_prefix(seq.slot,
                                           seq.tokens[:seq.cache_len])
            self._maybe_finish(seq)

    # -- helpers --------------------------------------------------------------
    def _dispatch(self, slots, tokens, q_pos, kv_lens):
        self.n_work_positions += tokens.size
        bt = self.cache.table_rows(slots)
        logits, self.cache.pools = self._step_fn(
            self.cache.pools, self.params, jnp.asarray(tokens),
            jnp.asarray(q_pos), jnp.asarray(kv_lens), bt,
            jnp.asarray(np.asarray(slots, np.int32)))
        self.n_host_syncs += 1          # blocking (B, vocab) logits fetch
        return np.asarray(logits)

    def _sample_and_advance(self, seq, logits):
        seq.generated.append(int(np.argmax(logits)))
        self.n_tokens_out += 1

    def _maybe_finish(self, seq):
        if seq.is_done():
            self._finished[seq.req.req_id] = np.asarray(seq.generated,
                                                        np.int32)
            self.scheduler.finish(seq)
