"""Static-batch serving engine: prefill -> ring-buffer decode.

The engine demonstrates the paper's deployment story end-to-end: params may
be a mixed pytree with MSB ``QTensor`` leaves (quantize-on-load via
core.policy). ``execution="packed"`` (default on TPU) rewrites them once at
load into kernel-layout ``PackedQTensor`` so every forward streams 4-bit
codes through the fused Pallas matmul; ``execution="simulated"`` keeps the
per-layer dequantize of the paper's bf16 simulation (Sec. 4.1). Both modes
produce identical greedy tokens (DESIGN.md Sec. 9).

This is the non-batched (fixed batch, lockstep decode) fallback; production
traffic goes through ``serve.continuous.ContinuousEngine``, which adds
request scheduling, a paged KV cache (DESIGN.md §8), and automatic
cross-request prefix caching + ``fork_request`` page sharing (§11) — none
of which exist here: every ``generate`` call prefills its full prompts. It
also covers the decoder-only architectures paging does not (ssm/xlstm
recurrent state).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def resolve_execution(execution, params):
    """Resolve the engine ``execution`` mode and (maybe) pack params.

    ``"packed"`` rewrites QTensor leaves to kernel-layout ``PackedQTensor``
    once at load (core.policy.pack_params) so every forward streams 4-bit
    codes; ``"simulated"`` keeps per-call dequantize (paper-parity bf16
    math, Sec. 4.1). Default: packed on TPU, simulated elsewhere — the
    jnp packed fallback is correct off-TPU but pays unpack cost per call.
    """
    if execution is None:
        execution = "packed" if jax.default_backend() == "tpu" else "simulated"
    if execution == "packed":
        from ..core.policy import pack_params
        params, _ = pack_params(params)
    elif execution != "simulated":
        raise ValueError(f"execution must be 'packed' or 'simulated', "
                         f"got {execution!r}")
    return execution, params


@dataclasses.dataclass
class ServeEngine:
    """Static-batch serving engine.

    Contract: ``generate(prompts, n)`` runs one prefill + n lockstep greedy
    decode steps and returns exactly the argmax token sequence of the
    underlying model — independent of ``execution`` mode and of ``mesh``
    (tensor parallelism changes where the math runs, not which tokens come
    out). ``params`` may be any mixed pytree of dense arrays and MSB
    ``QTensor`` leaves; the engine never mutates the caller's tree in
    place.

    ``mesh``: optional device mesh for tensor parallelism. Params are
    partitioned once at load (``core.policy.tp_partition_params``) and
    prefill/decode run under ``shard_map`` with manual collectives; the KV
    ring cache shards by head exactly when the attention projections
    themselves sharded. ``parallel`` (a ``ParallelContext``) remains the
    GSPMD alternative; the two are mutually exclusive.
    """
    model: object
    params: object
    max_seq: int
    parallel: object = None
    execution: Optional[str] = None   # "packed" | "simulated" | None=auto
    mesh: object = None               # tensor-parallel device mesh

    def __post_init__(self):
        if self.mesh is not None and self.parallel is not None:
            raise ValueError("pass either mesh= (manual TP) or parallel= "
                             "(GSPMD), not both")
        self.execution, self.params = resolve_execution(self.execution,
                                                        self.params)
        if self.mesh is not None:
            self._init_tensor_parallel()
        else:
            self._prefill = jax.jit(
                lambda p, b: self.model.prefill(p, b, self.parallel))
            self._decode = jax.jit(
                lambda p, c, t, pos: self.model.decode_step(p, c, t, pos,
                                                            self.parallel))
        self._score = jax.jit(
            lambda p, b: self.model.loss(p, b, self.parallel))
        # n_tokens and the sampling branch are static; temperature itself is
        # traced, so sweeping it never retraces the scan
        self._scan_generate = jax.jit(self._scan_generate_impl,
                                      static_argnums=(6, 7))

    def _init_tensor_parallel(self):
        """Build shard_map'd prefill/decode over ``mesh`` (DESIGN.md §10).

        The KV ring cache shards by head only when the attention weights
        themselves sharded (the planner's all-or-nothing head rule);
        otherwise attention is replicated and only MLP / MoE / unembedding
        run tensor-parallel. Logits come back replicated either way.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..core.policy import tp_localize, tp_partition_params
        from ..parallel.sharding import TPShard, from_mesh, shard_map_compat
        ctx = from_mesh(self.mesh)
        tp = TPShard(axis=ctx.tp_axis, size=ctx.tp_size)
        self.tp = tp
        self.params, pspecs, self.tp_report = tp_partition_params(
            self.params, tp.size, cfg=self.model.cfg, axis=tp.axis)
        self.params = jax.device_put(
            self.params,
            jax.tree_util.tree_map(lambda s: NamedSharding(self.mesh, s),
                                   pspecs))
        attn_sharded = any(v == "heads" for v in self.tp_report.values())

        def cache_spec(defs):
            if isinstance(defs, dict):
                return {k: cache_spec(v) for k, v in defs.items()}
            _shape, _dt, axes = defs
            return P(*[tp.axis if a == "heads" and attn_sharded else None
                       for a in axes])

        cspecs = cache_spec(self.model.cache_defs(1, 1))
        model, rep = self.model, P()

        def local_prefill(params, batch):
            return model.prefill(tp_localize(params), batch, tp)

        def local_decode(params, cache, tokens, pos):
            return model.decode_step(tp_localize(params), cache, tokens,
                                     pos, tp)

        self._prefill = jax.jit(shard_map_compat(
            local_prefill, self.mesh, in_specs=(pspecs, rep),
            out_specs=(rep, cspecs)))
        self._decode = jax.jit(shard_map_compat(
            local_decode, self.mesh, in_specs=(pspecs, cspecs, rep, rep),
            out_specs=(rep, cspecs)))

    def _grow_cache(self, cache, prompt_len):
        """Re-home prefill caches (length P) into max_seq ring buffers.

        K/V leaves are identified by their position in the cache pytree
        (the ``attn`` subtree's ``k``/``v`` entries, per Model.cache_defs) —
        not by shape, which false-positives whenever an unrelated state leaf
        happens to have dim 2 == prompt_len. ``xattn`` leaves are static
        encoder K/V and must NOT grow (decode's cross branch attends every
        cache row, so zero-padding would corrupt it)."""
        s = self.max_seq

        def grow(path, leaf):
            keys = [p.key for p in path if hasattr(p, "key")]
            if (len(keys) >= 2 and keys[-1] in ("k", "v")
                    and keys[-2] == "attn"):             # (P, B, S, KV, hd)
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, s - prompt_len)
                return jnp.pad(leaf, pad)
            return leaf

        new = {"layers": jax.tree_util.tree_map_with_path(grow,
                                                          cache["layers"])}
        if "pos" in cache:
            pos = jnp.full((cache["pos"].shape[0], s), -1, jnp.int32)
            new["pos"] = jax.lax.dynamic_update_slice_in_dim(
                pos, cache["pos"], 0, 1)
        return new

    def generate(self, prompts, n_tokens, temperature=0.0, rng=None):
        """prompts: (B, P) int32. Returns (B, n_tokens) greedy/temp samples.

        The decode loop is one jitted ``lax.scan`` with sampling *on
        device* — greedy argmax or temperature ``jax.random.categorical``,
        both inside the scan body — so serving ``n_tokens`` costs one
        prefill dispatch plus one scan dispatch instead of ``n_tokens``
        per-step host round trips. Tokens (and temperature samples, for a
        given ``rng``) are identical to the per-step loop this replaced
        (``_generate_stepwise``, kept for the identity test): same sample →
        decode → advance ops in the same order, only the dispatch boundary
        moved."""
        prompts = jnp.asarray(prompts, jnp.int32)
        b, p = prompts.shape
        assert p + n_tokens <= self.max_seq
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        cache = self._grow_cache(cache, p)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        temp = jnp.asarray(float(temperature) if temperature > 0 else 1.0,
                           jnp.float32)
        return self._scan_generate(self.params, logits, cache,
                                   jnp.full((b,), p, jnp.int32), rng, temp,
                                   int(n_tokens), bool(temperature > 0))

    def _scan_generate_impl(self, params, logits, cache, cur, rng, temp,
                            n_tokens, use_temp):
        def body(carry, _):
            logits, cache, cur, rng = carry
            if use_temp:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(sub, logits / temp, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            logits, cache = self._decode(params, cache,
                                         tok[:, None].astype(jnp.int32), cur)
            return (logits, cache, cur + 1, rng), tok
        _, toks = jax.lax.scan(body, (logits, cache, cur, rng), None,
                               length=n_tokens)
        return toks.T                             # (n, B) -> (B, n)

    def _generate_stepwise(self, prompts, n_tokens, temperature=0.0,
                           rng=None):
        """The pre-scan per-step Python loop (one dispatch + one host sync
        per token). Retained as the identity oracle for ``generate`` — not
        a serving path."""
        prompts = jnp.asarray(prompts, jnp.int32)
        b, p = prompts.shape
        assert p + n_tokens <= self.max_seq
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        cache = self._grow_cache(cache, p)
        out = []
        cur = jnp.full((b,), p, jnp.int32)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        for i in range(n_tokens):
            if temperature > 0:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            out.append(tok)
            logits, cache = self._decode(self.params, cache,
                                         tok[:, None].astype(jnp.int32), cur)
            cur = cur + 1
        return jnp.stack(out, axis=1)

    def score(self, tokens):
        """Mean next-token NLL of ``tokens`` (B, S) under the model."""
        batch = {"tokens": tokens[:, :-1],
                 "labels": tokens[:, 1:].astype(jnp.int32)}
        loss, _ = self._score(self.params, batch)
        return float(loss)
