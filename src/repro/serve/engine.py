"""Batched serving engine: prefill -> ring-buffer decode, quantized weights.

The engine demonstrates the paper's deployment story end-to-end: params may
be a mixed pytree with MSB ``QTensor`` leaves (quantize-on-load via
core.policy); the model dequantizes per layer (simulation mode, paper Sec.
4.1) or routes through the Pallas fused kernel on TPU.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ServeEngine:
    model: object
    params: object
    max_seq: int
    parallel: object = None

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.parallel))
        self._decode = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, c, t, pos,
                                                        self.parallel))

    def _grow_cache(self, cache, prompt_len):
        """Re-home prefill caches (length P) into max_seq ring buffers."""
        s = self.max_seq

        def grow(leaf):
            if (hasattr(leaf, "ndim") and leaf.ndim >= 3
                    and leaf.shape[2] == prompt_len):   # (P?, B, S, ...) k/v
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, s - prompt_len)
                return jnp.pad(leaf, pad)
            return leaf

        new = {"layers": jax.tree_util.tree_map(grow, cache["layers"])}
        if "pos" in cache:
            pos = jnp.full((cache["pos"].shape[0], s), -1, jnp.int32)
            new["pos"] = jax.lax.dynamic_update_slice_in_dim(
                pos, cache["pos"], 0, 1)
        return new

    def generate(self, prompts, n_tokens, temperature=0.0, rng=None):
        """prompts: (B, P) int32. Returns (B, n_tokens) greedy/temp samples."""
        prompts = jnp.asarray(prompts, jnp.int32)
        b, p = prompts.shape
        assert p + n_tokens <= self.max_seq
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        cache = self._grow_cache(cache, p)
        out = []
        cur = jnp.full((b,), p, jnp.int32)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        for i in range(n_tokens):
            if temperature > 0:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            out.append(tok)
            logits, cache = self._decode(self.params, cache,
                                         tok[:, None].astype(jnp.int32), cur)
            cur = cur + 1
        return jnp.stack(out, axis=1)

    def score(self, tokens):
        """Mean next-token NLL of ``tokens`` (B, S) under the model."""
        batch = {"tokens": tokens[:, :-1],
                 "labels": tokens[:, 1:].astype(jnp.int32)}
        loss, _ = jax.jit(
            lambda p, b: self.model.loss(p, b, self.parallel))(
                self.params, batch)
        return float(loss)
