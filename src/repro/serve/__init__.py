from .continuous import ContinuousEngine
from .engine import ServeEngine
from .lifecycle import (CompletionParams, RequestLifecycle, ValidationError,
                        parse_completion_request)
from .metrics import Counter, Gauge, Histogram, Registry, ServeMetrics
from .paged_cache import (OutOfPages, PagedKVCache, PageStateError,
                          PrefixMatch)
from .scheduler import Request, Saturated, Scheduler, Sequence
from .server import APIServer, EngineLoop

__all__ = ["APIServer", "CompletionParams", "ContinuousEngine", "Counter",
           "EngineLoop", "Gauge", "Histogram", "OutOfPages", "PagedKVCache",
           "PageStateError", "PrefixMatch", "Registry", "Request",
           "RequestLifecycle", "Saturated", "Scheduler", "Sequence",
           "ServeEngine", "ServeMetrics", "ValidationError",
           "parse_completion_request"]
