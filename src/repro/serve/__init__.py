from .continuous import ContinuousEngine
from .engine import ServeEngine
from .paged_cache import (OutOfPages, PagedKVCache, PageStateError,
                          PrefixMatch)
from .scheduler import Request, Scheduler, Sequence

__all__ = ["ContinuousEngine", "OutOfPages", "PagedKVCache",
           "PageStateError", "PrefixMatch", "Request", "Scheduler",
           "Sequence", "ServeEngine"]
