from .continuous import ContinuousEngine, jit_trace_count
from .engine import ServeEngine
from .faults import NO_FAULTS, FaultEvent, FaultPlan, InjectedControlFault, \
    InjectedFault, InjectedOOM
from .lifecycle import (PRIORITY_CLASSES, CompletionParams, RequestLifecycle,
                        ValidationError, parse_completion_request)
from .metrics import Counter, Gauge, Histogram, Registry, ServeMetrics
from .overload import (DEFAULT_LADDER, BrownoutLevel, OverloadController,
                       compute_retry_after)
from .paged_cache import (OutOfPages, PagedKVCache, PageStateError,
                          PrefixMatch)
from .scheduler import Request, Saturated, Scheduler, Sequence
from .server import APIServer, EngineLoop
from .supervisor import (Draining, EngineDied, EngineSupervisor,
                         PoisonedRequest, Recovering, Warming,
                         WatchdogTimeout)
from .warmup import enumerate_traces, warm_engine

__all__ = ["APIServer", "BrownoutLevel", "CompletionParams",
           "ContinuousEngine", "Counter", "DEFAULT_LADDER", "Draining",
           "EngineDied", "EngineLoop", "EngineSupervisor", "FaultEvent",
           "FaultPlan", "Gauge", "Histogram", "InjectedControlFault",
           "InjectedFault", "InjectedOOM", "NO_FAULTS", "OutOfPages",
           "OverloadController", "PRIORITY_CLASSES", "PagedKVCache",
           "PageStateError", "PoisonedRequest", "PrefixMatch", "Recovering",
           "Registry", "Request", "RequestLifecycle", "Saturated",
           "Scheduler", "Sequence", "ServeEngine", "ServeMetrics",
           "ValidationError", "Warming", "WatchdogTimeout",
           "compute_retry_after", "enumerate_traces", "jit_trace_count",
           "parse_completion_request", "warm_engine"]
