from .engine import ServeEngine
