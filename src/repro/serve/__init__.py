from .continuous import ContinuousEngine
from .engine import ServeEngine
from .paged_cache import OutOfPages, PagedKVCache
from .scheduler import Request, Scheduler, Sequence

__all__ = ["ContinuousEngine", "OutOfPages", "PagedKVCache", "Request",
           "Scheduler", "Sequence", "ServeEngine"]
