"""Overload control plane: SLO-driven brownout ladder + load-derived
Retry-After (DESIGN.md Sec. 17).

Under overload the serving stack used to behave like a binary switch: the
page pool saturates, ``would_accept`` flips to 429, and every request is
treated identically. ``OverloadController`` replaces the switch with a
deterministic, hysteresis-guarded **brownout ladder**: it observes pressure
(page-pool occupancy, admission queue depth, preemption rate, and — when a
metrics registry is attached — TTFT/ITL percentiles against their SLOs) and
walks a small table of degradation levels. Level 0 is exactly today's
behavior; each successive level trades a little per-request machinery for
headroom:

  1. shrink the *effective* decode horizon for new dispatches — fewer
     speculatively leased pages per sequence, faster page turnover;
  2. cap the packed-prefill wave width — smaller prefill bursts, decode
     keeps breathing;
  3. evict the prefix-cache LRU park down to a floor — cold prefix
     residency is the cheapest RAM to give back;
  4. shed by priority class — batch-class submits turn into 429s whose
     ``Retry-After`` reflects actual load.

Every lever is **schedule-only**: the static jit traces (decode-horizon
scan, packed-prefill buckets) never change shape, so a level change can
never trigger a post-warmup trace, and greedy token identity holds at every
level for whatever is admitted. Already-running work is never killed by the
controller — only deprioritized (admission order, preemption-victim order).

Concurrency contract: ``tick()`` runs on the engine thread (the
``EngineLoop`` calls it once per loop iteration, right after the metrics
sync; direct-drive harnesses call it between ``step()``s). It therefore
mutates scheduler/cache state with the same single-writer discipline as the
engine itself — no new races. ``level`` and ``last_pressure`` are single
attribute reads, safe to observe from the HTTP thread (``/healthz``,
``Retry-After``).

Hysteresis: a level transition needs (a) ``up_ticks`` consecutive ticks of
pressure >= ``up`` (or ``down_ticks`` consecutive ticks <= ``down``), and
(b) at least ``min_dwell_ticks`` ticks since the previous transition. With
``down < up`` this bounds the transition rate at one per dwell window no
matter how adversarially the pressure signal oscillates — the property the
controller-site chaos test asserts.

Supervisor interaction: the controller holds the level; engine incarnations
only hold its *consequences* (scheduler knobs). ``apply_to`` is idempotent
and re-run every tick, and ``EngineSupervisor.attach_overload`` re-applies
it inside ``_recover`` — so a crash during overload resumes at the same
level with zero flapping.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

from .faults import NO_FAULTS, InjectedControlFault, InjectedFault

MAX_RETRY_AFTER_S = 30.0


def compute_retry_after(base_s: float, *, pressure: float = 0.0,
                        level: int = 0, salt: int = 0,
                        jitter_frac: float = 0.25,
                        max_s: float = MAX_RETRY_AFTER_S) -> int:
    """The one Retry-After computation for every shedding path (saturation
    429, warming 503, recovery 503 — they used to each do it differently).

    Load-derived and deterministic: ``base_s`` is scaled up by the brownout
    level and the instantaneous pressure (a loaded server asks clients to
    back off longer), then spread by a deterministic jitter in
    ``[0, jitter_frac)`` keyed on ``salt`` (a per-rejection counter) so a
    thundering herd of simultaneous 429s does not re-synchronize its
    retries. Pure function of its inputs — golden-tested. Returns whole
    seconds >= 1 (the HTTP header granularity), capped at ``max_s``."""
    p = min(max(float(pressure), 0.0), 1.0)
    s = float(base_s) * (1.0 + int(level)) * (1.0 + p)
    # Knuth multiplicative hash of the salt -> uniform-ish [0, 1)
    h = (int(salt) * 2654435761) & 0xFFFFFFFF
    s *= 1.0 + jitter_frac * (h / 2.0 ** 32)
    return max(1, int(math.ceil(min(s, float(max_s)))))


@dataclasses.dataclass(frozen=True)
class BrownoutLevel:
    """One rung of the ladder. Fractions are of the engine's configured
    capacity (decode_horizon, max_batch, usable pages); ``1.0`` means
    untouched. ``shed`` names the priority classes turned away at
    admission while this level holds."""
    level: int
    horizon_frac: float = 1.0   # effective decode horizon / decode_horizon
    wave_frac: float = 1.0      # packed-wave width / max_batch
    lru_frac: float = 1.0       # LRU park floor / usable pages
    shed: Tuple[str, ...] = ()  # classes refused at submit

    def describe(self) -> str:
        parts = []
        if self.horizon_frac < 1.0:
            parts.append(f"horizon x{self.horizon_frac:g}")
        if self.wave_frac < 1.0:
            parts.append(f"wave x{self.wave_frac:g}")
        if self.lru_frac < 1.0:
            parts.append(f"lru floor {self.lru_frac:g}")
        if self.shed:
            parts.append("shed " + "+".join(self.shed))
        return ", ".join(parts) or "normal"


DEFAULT_LADDER: Tuple[BrownoutLevel, ...] = (
    BrownoutLevel(0),
    BrownoutLevel(1, horizon_frac=0.5),
    BrownoutLevel(2, horizon_frac=0.5, wave_frac=0.5),
    BrownoutLevel(3, horizon_frac=0.25, wave_frac=0.5, lru_frac=0.25),
    BrownoutLevel(4, horizon_frac=0.25, wave_frac=0.25, lru_frac=0.0,
                  shed=("batch",)),
)


class OverloadController:
    """Closes the loop between observed pressure and the brownout ladder.

    ``engine`` is a ``ContinuousEngine`` or an ``EngineSupervisor`` (both
    expose ``scheduler``/``cache``; the supervisor additionally gets
    ``attach_overload`` called so rebuilt incarnations inherit the level).
    ``metrics`` (optional ``ServeMetrics``) supplies TTFT/ITL percentiles
    as pressure inputs and receives the ``msb_brownout_level`` gauge and
    transition counter.
    """

    def __init__(self, engine, metrics=None, *,
                 ladder: Tuple[BrownoutLevel, ...] = DEFAULT_LADDER,
                 up: float = 0.85, down: float = 0.5,
                 up_ticks: int = 2, down_ticks: int = 4,
                 min_dwell_ticks: int = 8,
                 interval_s: float = 0.05,
                 queue_ref: Optional[int] = None,
                 ttft_slo_s: Optional[float] = None,
                 itl_slo_s: Optional[float] = None,
                 retry_after_base_s: float = 1.0,
                 faults=NO_FAULTS):
        if not ladder or ladder[0].level != 0:
            raise ValueError("ladder must start at level 0 (normal service)")
        for i, lv in enumerate(ladder):
            if lv.level != i:
                raise ValueError(f"ladder levels must be 0..{len(ladder)-1} "
                                 f"in order, got {lv.level} at index {i}")
        if not (0.0 <= down < up):
            raise ValueError(f"need 0 <= down < up for hysteresis, "
                             f"got down={down}, up={up}")
        self.engine = engine
        self.metrics = metrics
        self.ladder = tuple(ladder)
        self.up = float(up)
        self.down = float(down)
        self.up_ticks = max(1, int(up_ticks))
        self.down_ticks = max(1, int(down_ticks))
        self.min_dwell_ticks = max(1, int(min_dwell_ticks))
        self.interval_s = float(interval_s)
        self.queue_ref = queue_ref
        self.ttft_slo_s = ttft_slo_s
        self.itl_slo_s = itl_slo_s
        self.retry_after_base_s = float(retry_after_base_s)
        self.faults = faults if faults is not None else NO_FAULTS
        self.level = 0
        self.last_pressure = 0.0
        self.last_signals: Dict[str, float] = {}
        self.n_transitions = 0
        self.n_tick_errors = 0       # controller-site crashes swallowed
        # (tick index, old level, new level, pressure) — bounded history
        self.transition_log: List[Tuple[int, int, int, float]] = []
        self._tick_n = 0
        self._hi = 0
        self._lo = 0
        self._last_transition_tick = -10 ** 9
        self._last_tick_t = -math.inf
        self._forced: Optional[str] = None   # "stuck" | "flap" (injected)
        self._retry_salt = 0
        if metrics is not None and hasattr(metrics, "brownout_level"):
            metrics.brownout_level.set(0)
        attach = getattr(engine, "attach_overload", None)
        if attach is not None:
            attach(self)

    # -- pressure -----------------------------------------------------------
    def measure(self) -> Dict[str, float]:
        """One deterministic snapshot of the pressure signals, each
        normalized so 1.0 means 'at the limit'. The composite is their max
        — any single saturated resource is enough to climb the ladder."""
        sched = self.engine.scheduler
        cache = self.engine.cache
        usable = max(1, cache.num_pages - 1)
        pool = 1.0 - cache.n_available_pages / usable
        qref = self.queue_ref
        if qref is None:
            qref = sched.max_waiting if sched.max_waiting else \
                4 * sched.max_batch
        queue = len(sched.waiting) / max(1, qref)
        # preemption churn per step since the last measure: >= 1 means the
        # pool is thrashing (every step evicts someone)
        st = self.engine.stats()
        d_pre = st["preemptions"] - getattr(self, "_seen_pre", 0)
        d_steps = st["steps"] - getattr(self, "_seen_steps", 0)
        self._seen_pre, self._seen_steps = st["preemptions"], st["steps"]
        preempt = min(1.0, d_pre / d_steps) if d_steps > 0 else 0.0
        sig = {"pool": min(1.0, max(0.0, pool)),
               "queue": min(1.0, queue),
               "preempt": preempt}
        if self.metrics is not None:
            if self.ttft_slo_s:
                p99 = self.metrics.ttft.percentile(0.99)
                if p99 is not None:
                    sig["ttft"] = min(1.0, (p99 / self.ttft_slo_s) / 2.0)
            if self.itl_slo_s:
                p99 = self.metrics.itl.percentile(0.99)
                if p99 is not None:
                    sig["itl"] = min(1.0, (p99 / self.itl_slo_s) / 2.0)
        sig["composite"] = max(v for k, v in sig.items())
        return sig

    # -- the control loop ---------------------------------------------------
    def tick(self, now: Optional[float] = None) -> Optional[int]:
        """One control iteration (engine thread only): measure pressure,
        advance the hysteresis counters, maybe transition one level, and
        (re-)apply the current level's knobs to the engine. Returns the new
        level on a transition, else None. Rate-limited to one evaluation
        per ``interval_s`` (pass 0 for direct-drive harnesses)."""
        now = time.monotonic() if now is None else now
        if now - self._last_tick_t < self.interval_s:
            return None
        self._last_tick_t = now
        try:
            if self.faults.armed:
                self.faults.fire("controller")
        except InjectedControlFault as e:
            self._forced = e.mode
        except InjectedFault:
            # a crashed controller must never take the engine loop down —
            # fail safe by holding the current level this tick
            self.n_tick_errors += 1
            return None
        self._tick_n += 1
        sig = self.measure()
        p = sig["composite"]
        if self._forced == "stuck":
            p = 1.0
        elif self._forced == "flap":
            p = 1.0 if self._tick_n % 2 == 0 else 0.0
        self.last_pressure = p
        self.last_signals = sig
        if p >= self.up:
            self._hi += 1
            self._lo = 0
        elif p <= self.down:
            self._lo += 1
            self._hi = 0
        else:
            self._hi = self._lo = 0        # dead band: hold
        changed = None
        dwell_ok = (self._tick_n - self._last_transition_tick
                    >= self.min_dwell_ticks)
        if dwell_ok and self._hi >= self.up_ticks \
                and self.level < len(self.ladder) - 1:
            changed = self._transition(self.level + 1, p)
        elif dwell_ok and self._lo >= self.down_ticks and self.level > 0:
            changed = self._transition(self.level - 1, p)
        self.apply_to(self.engine)
        return changed

    def _transition(self, new_level: int, pressure: float) -> int:
        old = self.level
        self.level = new_level
        self.n_transitions += 1
        self._last_transition_tick = self._tick_n
        self._hi = self._lo = 0
        if len(self.transition_log) < 4096:
            self.transition_log.append((self._tick_n, old, new_level,
                                        round(pressure, 4)))
        m = self.metrics
        if m is not None and hasattr(m, "brownout_level"):
            m.brownout_level.set(new_level)
            m.brownout_transitions.inc()
        return new_level

    def apply_to(self, engine) -> None:
        """Install the current level's knobs on ``engine`` (idempotent,
        engine thread only). Split from ``tick`` so the supervisor can
        re-apply the inherited level to a freshly rebuilt incarnation
        before it dispatches anything."""
        lv = self.ladder[self.level]
        sched = engine.scheduler
        cache = engine.cache
        if lv.horizon_frac >= 1.0:
            sched.horizon_cap = None
        else:
            sched.horizon_cap = max(
                1, int(sched.decode_horizon * lv.horizon_frac))
        if lv.wave_frac >= 1.0:
            sched.max_wave_segments = None
        else:
            sched.max_wave_segments = max(
                1, int(sched.max_batch * lv.wave_frac))
        sched.shed_classes = frozenset(lv.shed)
        if lv.lru_frac < 1.0:
            usable = max(1, cache.num_pages - 1)
            cache.shrink_lru(int(lv.lru_frac * usable))

    # -- shedding -----------------------------------------------------------
    def retry_after(self) -> int:
        """Load-derived Retry-After seconds for the next rejection. Each
        call advances the jitter salt so consecutive rejections spread."""
        self._retry_salt += 1
        return compute_retry_after(self.retry_after_base_s,
                                   pressure=self.last_pressure,
                                   level=self.level,
                                   salt=self._retry_salt)

    # -- introspection ------------------------------------------------------
    def status(self) -> Dict[str, object]:
        return {
            "brownout_level": self.level,
            "brownout_action": self.ladder[self.level].describe(),
            "pressure": round(self.last_pressure, 4),
            "signals": {k: round(v, 4)
                        for k, v in self.last_signals.items()},
            "transitions": self.n_transitions,
            "tick_errors": self.n_tick_errors,
        }

    def __repr__(self):
        return (f"OverloadController(level={self.level}, "
                f"pressure={self.last_pressure:.3f}, "
                f"transitions={self.n_transitions})")
