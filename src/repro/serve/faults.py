"""Deterministic fault injection for the serving stack (DESIGN.md Sec. 14).

Every recovery path in the supervisor (serve/supervisor.py) exists because
some step of the serving pipeline can fail in production: a device dispatch
raises, the allocator runs dry, a step hangs, the detokenize thread dies, a
client socket drops mid-stream. None of those happen on a healthy CI host,
so without injection the recovery code is dead code until the first real
incident. This module makes faults *first-class, seeded inputs*: a
``FaultPlan`` is an explicit schedule of ``FaultEvent``s keyed on (site,
call index), so a chaos test replays the exact same failure sequence on
every run — and a recovery bug bisects like any other regression.

Sites (where ``fire(site)`` is called):

  * ``"step"``   — top of ``ContinuousEngine.step()``, before any work is
    scheduled. A crash here models a dispatch/tracing failure surfacing at
    the step boundary; a stall models a hung device dispatch.
  * ``"apply"``  — inside the engine's decode paths *after* the device
    dispatch, before host bookkeeping (commit/sample). A crash here leaves
    device pools written but host state behind — the nastiest partial
    state recovery must handle (it discards the incarnation wholesale).
  * ``"alloc"``  — inside ``PagedKVCache.reserve``. An ``oom`` event
    raises ``InjectedOOM`` (an ``OutOfPages``), which the scheduler treats
    exactly like real pool exhaustion: preemption, not crash. Pool
    pressure is the *common* failure at serving scale and must degrade
    gracefully without supervisor involvement.
  * ``"detok"``  — top of the detokenize thread's batch loop (between
    batches, so no event is ever half-processed). A crash kills the
    thread; the engine loop detects and restarts it.
  * ``"socket"`` — per token-bearing SSE frame in the HTTP stream writer.
    A crash drops the client connection mid-stream, exercising the
    disconnect -> abort -> page-release path under load.
  * ``"controller"`` — top of ``OverloadController.tick()``. The control
    plane has its own failure modes: a stuck pressure signal pinning the
    ladder at max brownout (``kind="stuck"``), or a signal oscillating
    between extremes every tick trying to make the ladder flap
    (``kind="flap"``). Both raise ``InjectedControlFault``, which the
    controller *catches* and converts into a forced pressure override —
    the chaos test then asserts the hysteresis guard still bounds the
    transition rate and the server drains cleanly. A plain ``crash``
    here is also caught: a controller failure must never take down the
    engine loop, it just holds the current level (fail-safe).

Kinds: ``"crash"`` raises ``InjectedFault``; ``"oom"`` raises
``InjectedOOM``; ``"stall"`` sleeps ``stall_s`` then returns (the step
completes, late — what a watchdog must catch); ``"stuck"``/``"flap"``
(controller site only) raise ``InjectedControlFault``.

The default is a shared no-op plan (``NO_FAULTS``): one attribute check
per site call, no lock, no allocation — production pays nothing.
``FaultPlan.seeded(seed, ...)`` derives a reproducible schedule from a
single integer; two plans built from the same seed fire identically.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .paged_cache import OutOfPages

SITES = ("step", "apply", "alloc", "detok", "socket", "controller")
KINDS = ("crash", "oom", "stall", "stuck", "flap")
# kinds that only make sense at the controller site (pressure overrides,
# not exceptions that escape) — and the only non-crash kinds it accepts
_CONTROLLER_KINDS = ("stuck", "flap", "crash")


class InjectedFault(RuntimeError):
    """A FaultPlan-scheduled crash. Deliberately a plain RuntimeError
    subtype: the supervisor must not special-case injected faults — it
    sees an exception escaping the engine, same as production."""


class InjectedOOM(OutOfPages):
    """A FaultPlan-scheduled allocator failure. An ``OutOfPages`` subtype
    so the scheduler's preemption path handles it identically to real
    pool exhaustion."""


class InjectedControlFault(RuntimeError):
    """A FaultPlan-scheduled control-plane fault. ``mode`` is ``"stuck"``
    (pressure pinned at max from now on) or ``"flap"`` (pressure alternates
    between extremes every tick). Raised by ``fire("controller")`` and
    *caught* by ``OverloadController.tick`` — control-plane faults degrade
    the controller, never the engine."""

    def __init__(self, mode: str, msg: str = ""):
        super().__init__(msg or f"injected controller fault ({mode})")
        self.mode = mode


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fires on the ``at``-th call (0-based) of
    ``site``. ``stall_s`` is only meaningful for ``kind="stall"``."""
    site: str
    at: int
    kind: str = "crash"
    stall_s: float = 0.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} "
                             f"(sites: {SITES})")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(kinds: {KINDS})")
        if self.kind in ("stuck", "flap") and self.site != "controller":
            raise ValueError(f"kind {self.kind!r} is controller-site only "
                             f"(got site {self.site!r})")
        if self.site == "controller" and self.kind not in _CONTROLLER_KINDS:
            raise ValueError(f"controller site accepts kinds "
                             f"{_CONTROLLER_KINDS}, got {self.kind!r}")
        if self.at < 0:
            raise ValueError(f"fault index must be >= 0, got {self.at}")


class FaultPlan:
    """A deterministic schedule of faults over per-site call counters.

    ``fire(site)`` increments the site's counter and, if an event is
    scheduled at that index, raises or stalls accordingly. Counters are
    lock-protected (sites are hit from the engine, detokenize and asyncio
    threads); fired events land on ``self.fired`` for assertions.

    A plan is exhausted when every event has fired — ``exhausted`` lets a
    chaos driver keep the workload running until the full schedule has
    been delivered.
    """

    def __init__(self, events: Sequence[FaultEvent] = (),
                 seed: Optional[int] = None):
        self.seed = seed
        by_site: Dict[str, Dict[int, FaultEvent]] = {}
        for ev in events:
            slot = by_site.setdefault(ev.site, {})
            if ev.at in slot:
                raise ValueError(f"duplicate fault at ({ev.site}, {ev.at})")
            slot[ev.at] = ev
        self._events = by_site
        self._counts = {site: 0 for site in SITES}
        self._lock = threading.Lock()
        self.n_events = len(tuple(events))
        self.fired: List[Tuple[str, int, str]] = []  # (site, at, kind)

    # -- construction -------------------------------------------------------
    @classmethod
    def seeded(cls, seed: int, *, n_faults: int = 10,
               sites: Sequence[str] = ("step", "apply", "alloc"),
               first: int = 2, spread: int = 200,
               stall_s: float = 0.05,
               stall_weight: float = 0.25) -> "FaultPlan":
        """Derive a reproducible ``n_faults``-event schedule from ``seed``.

        Call indices are sampled without replacement per site from
        ``[first, first + spread)`` — dense enough to hit mid-stream
        states, never index 0 of everything at once. ``step``-site events
        become stalls with probability ``stall_weight`` (a watchdog needs
        hangs, not just crashes); ``alloc`` events are always ``oom``.
        """
        import random
        rng = random.Random(seed)
        taken: Dict[str, set] = {s: set() for s in SITES}
        events = []
        for _ in range(int(n_faults)):
            site = rng.choice(tuple(sites))
            at = first + rng.randrange(spread)
            while at in taken[site]:
                at = first + rng.randrange(spread)
            taken[site].add(at)
            if site == "alloc":
                kind = "oom"
            elif site == "controller":
                kind = rng.choice(("stuck", "flap"))
            elif site == "step" and rng.random() < stall_weight:
                kind = "stall"
            else:
                kind = "crash"
            events.append(FaultEvent(site, at, kind, stall_s=stall_s))
        return cls(events, seed=seed)

    # -- the hot path --------------------------------------------------------
    armed = True

    def fire(self, site: str):
        """Tick ``site``'s counter; crash/stall if an event is due."""
        with self._lock:
            n = self._counts[site]
            self._counts[site] = n + 1
            ev = self._events.get(site, {}).get(n)
            if ev is None:
                return
            self.fired.append((ev.site, ev.at, ev.kind))
        if ev.kind == "stall":
            time.sleep(ev.stall_s)
            return
        msg = (f"injected {ev.kind} at site={ev.site!r} call #{ev.at}"
               + (f" (seed={self.seed})" if self.seed is not None else ""))
        if ev.kind == "oom":
            raise InjectedOOM(msg)
        if ev.kind in ("stuck", "flap"):
            raise InjectedControlFault(ev.kind, msg)
        raise InjectedFault(msg)

    # -- introspection -------------------------------------------------------
    @property
    def exhausted(self) -> bool:
        """True once every scheduled event has fired."""
        return len(self.fired) >= self.n_events

    def calls(self, site: str) -> int:
        with self._lock:
            return self._counts[site]

    def __repr__(self):
        return (f"FaultPlan(n_events={self.n_events}, "
                f"fired={len(self.fired)}, seed={self.seed})")


class _NoFaults:
    """The production default: ``fire`` is a no-op with no lock and no
    allocation. ``armed = False`` lets extra-hot call sites skip even the
    method call (``if faults.armed: faults.fire(...)``)."""

    armed = False
    n_events = 0
    exhausted = True
    fired: List[Tuple[str, int, str]] = []

    def fire(self, site: str):
        return

    def calls(self, site: str) -> int:
        return 0

    def __repr__(self):
        return "NO_FAULTS"


NO_FAULTS = _NoFaults()
