"""Async serving front door: streaming HTTP API over the continuous engine.

The production entry point that turns "an engine" into "a service"
(DESIGN.md Sec. 13). Stdlib-only by construction — ``asyncio`` plus a
minimal HTTP/1.1 + Server-Sent-Events layer — because the serving
container must not grow a web-framework dependency to expose four routes:

  * ``POST /v1/completions`` — OpenAI-compatible, stream and non-stream.
    ``prompt`` is token ids (this repo serves ids, not text); streaming
    responses are SSE ``data:`` frames ending in ``data: [DONE]``.
  * ``GET /v1/models``    — the one loaded model.
  * ``GET /healthz``      — liveness (503 once the engine loop dies).
  * ``GET /metrics``      — Prometheus text format (serve/metrics.py).

**Thread topology.** Three threads, one owner per mutable domain:

  1. The *engine step-loop thread* (``EngineLoop``) exclusively owns the
     ``ContinuousEngine``: it drains a command queue (submit/cancel),
     runs ``step()``, drains ``stream_updates()``, enforces per-request
     deadlines, and writes all engine-derived metrics. The engine is
     single-threaded by contract; every mutation funnels through this
     loop's command queue.
  2. The *detokenize thread* turns token-id bursts into text pieces and
     forwards events into each request's asyncio queue
     (``loop.call_soon_threadsafe``). String work and cross-thread
     hand-off stay off the hot loop; FIFO order is preserved because all
     events route through it.
  3. The *asyncio thread* runs the HTTP server: parse, validate (typed
     4xx), admission-probe (429 + Retry-After on saturation), then await
     per-request event queues and write frames.

**Request lifecycle.** Validation errors never touch the engine.
Accepted requests get a ``RequestLifecycle`` (serve/lifecycle.py) whose
TTFT/ITL the engine loop records at drain time. Client disconnects are
detected by an EOF watcher on the request socket (plus write failures
mid-stream) and propagate to ``ContinuousEngine.abort_request`` — pages,
horizon leases and prefix-cache refs all return to the allocator; server-
side timeouts take the same path with finish_reason ``timeout``.
Backpressure: the engine is built with ``max_waiting=`` so the scheduler's
``would_accept`` probe (read-only, called from the asyncio thread; the
engine-thread submit re-validates) can shed load before any state is
touched.
"""
from __future__ import annotations

import asyncio
import json
import queue
import threading
import time
from typing import Callable, Dict, Optional

from .faults import NO_FAULTS
from .lifecycle import (DONE, FINISH_CANCELLED, FINISH_ERROR, FINISH_LENGTH,
                        FINISH_STOP, FINISH_TIMEOUT, RequestLifecycle,
                        ValidationError, parse_completion_request)
from .metrics import Registry, ServeMetrics
from .overload import OverloadController, compute_retry_after
from .scheduler import Saturated
from .supervisor import DEAD, DEGRADED, DRAINING, OK, WARMING, Draining, \
    EngineDied, Recovering, Warming


def default_detokenize(token_id: int) -> str:
    """Token ids -> text without a real tokenizer: each token renders as
    a leading-space decimal id, so streams concatenate into ' 5 17 3'.
    Lossless (ids are also returned verbatim in ``token_ids``) and
    replaceable via ``APIServer(detokenize=...)``."""
    return f" {token_id}"


def _set_future(fut: asyncio.Future, err: Optional[Exception]):
    if not fut.cancelled():
        fut.set_result(err)


def _distribute(items):
    """Runs on the asyncio loop: fan one cross-thread wakeup out to many
    per-request queues. Batching events per engine step into a single
    ``call_soon_threadsafe`` matters on small hosts — each threadsafe call
    is a self-pipe write plus a loop wakeup, and paying that per request
    per token measurably taxes the engine thread it shares cores with."""
    for q, event in items:
        q.put_nowait(event)


class EngineLoop:
    """Background thread that exclusively owns a ``ContinuousEngine``.

    Commands (``submit``/``cancel``) arrive on a thread-safe queue and are
    applied between engine steps, so the engine never sees concurrent
    mutation. Token events leave through the detokenize backlog thread
    into per-request asyncio queues. The loop blocks on the command queue
    only when the engine is idle (with a short timeout so deadlines are
    still enforced); with work queued it drains commands non-blocking and
    steps flat out."""

    def __init__(self, engine, metrics: Optional[ServeMetrics] = None,
                 detokenize: Optional[Callable[[int], str]] = None,
                 idle_poll_s: float = 0.05, faults=NO_FAULTS,
                 max_detok_restarts: int = 3, warmup: bool = False,
                 overload: Optional[OverloadController] = None):
        self.engine = engine
        self.metrics = metrics or ServeMetrics()
        self.detokenize = detokenize or default_detokenize
        self.idle_poll_s = idle_poll_s
        self.faults = faults
        # overload control plane (DESIGN.md Sec. 17): ticked once per loop
        # iteration on this thread — the controller mutates scheduler/cache
        # state under the same single-writer discipline as the engine
        self.overload = overload
        self.warmup_requested = bool(warmup)
        self.warming = False           # startup AOT warmup in flight
        self.max_detok_restarts = int(max_detok_restarts)
        self.n_detok_restarts = 0
        self.detok_dead = False        # restart budget exhausted
        self.detok_err: Optional[BaseException] = None
        self.died: Optional[BaseException] = None   # _run escaped with this
        self.draining = False
        self._cmds: "queue.Queue" = queue.Queue()
        self._detok_q: "queue.Queue" = queue.Queue()
        self._by_rid: Dict[int, RequestLifecycle] = {}
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="msb-engine-loop")
        self._detok_thread = threading.Thread(target=self._detok_run,
                                              daemon=True,
                                              name="msb-detokenize")

    # -- API (any thread) ---------------------------------------------------
    def start(self):
        self._thread.start()
        self._detok_thread.start()

    def stop(self):
        self._stop.set()
        self._cmds.put(None)                    # wake a blocked get()
        self._thread.join(timeout=10)
        self._detok_q.put(None)
        self._detok_thread.join(timeout=10)

    @property
    def alive(self) -> bool:
        """False once the loop can no longer deliver events: the engine
        thread died (crash with an unsupervised engine — ``died`` holds
        the exception — or clean ``stop()``) or the detokenize thread
        exhausted its restart budget."""
        return self._thread.is_alive() and not self.detok_dead

    @property
    def health(self) -> str:
        """``ok | warming | degraded | draining | dead`` for ``/healthz``:
        dead/draining/warming are loop-level states; a supervised engine
        contributes its own warming/degraded/draining/dead states beneath
        them."""
        if not self.alive:
            return DEAD
        if self.draining:
            return DRAINING
        if self.warming:
            return WARMING
        return getattr(self.engine, "health", OK)

    def drain(self):
        """Stop admissions (``probe`` answers ``Draining`` -> 503) while
        in-flight work runs to completion; ``drained`` flips once the
        engine is empty. Callable from any thread."""
        self.draining = True
        if hasattr(self.engine, "drain"):
            self._cmds.put(("drain", None, None))

    @property
    def drained(self) -> bool:
        return (self.draining
                and not self.engine.has_work and not self._by_rid)

    def probe(self, prompt_len: int, max_tokens: int,
              priority: str = "standard") -> Optional[Exception]:
        """Read-only admission probe (safe off-thread: counters only; the
        engine-thread submit re-validates, so staleness costs one retry,
        never corrupted state)."""
        if not self.alive:
            return EngineDied("engine loop is dead"
                              + (f": {self.died}" if self.died else ""))
        if self.draining:
            return Draining("server is draining; not accepting work")
        if self.warming:
            return Warming("engine is warming up (compiling the trace "
                           "set); retry shortly")
        return self.engine.would_accept(prompt_len, max_tokens,
                                        priority=priority)

    def submit(self, lc: RequestLifecycle) -> asyncio.Future:
        """Enqueue a validated request; returns a future (on the caller's
        running loop) resolving to None on acceptance or the exception the
        engine submit raised (Saturated/ValueError race with the probe)."""
        lc.loop = asyncio.get_running_loop()
        lc.queue = asyncio.Queue()
        fut = lc.loop.create_future()
        self._cmds.put(("submit", lc, fut))
        return fut

    def cancel(self, lc: RequestLifecycle, reason: str):
        """Request cancellation (client disconnect, explicit abort). No-op
        if the request already finished by the time the command drains."""
        self._cmds.put(("cancel", lc, reason))

    # -- engine thread ------------------------------------------------------
    def _run(self):
        try:
            if self.warmup_requested and hasattr(self.engine, "warmup"):
                # AOT-compile the reachable trace set before accepting
                # work; probe/healthz answer Warming/503 until done
                self.warming = True
                try:
                    self.engine.warmup()
                finally:
                    self.warming = False
                self.metrics.sync_engine(self.engine)
            while not self._stop.is_set():
                busy = self.engine.has_work
                self._drain_cmds(block=not busy)
                if self._stop.is_set():
                    break
                if self.engine.has_work:
                    self.engine.step()
                    self._apply_updates(self.engine.stream_updates(),
                                        time.monotonic())
                self._drain_failures(time.monotonic())
                self._check_deadlines(time.monotonic())
                self._ensure_detok()
                self.metrics.sync_engine(self.engine)
                if self.overload is not None:
                    self.overload.tick()
        except BaseException as e:
            # an unsupervised engine's step() crashing lands here (a
            # supervised one contains it); record the cause so probe/
            # healthz can name it, then fail everything below
            self.died = e
        finally:
            # fail every in-flight request loudly rather than hanging its
            # handler forever (healthz flips to 503 via `alive`)
            now = time.monotonic()
            if self.died is not None:
                msg = (f"engine loop died: "
                       f"{type(self.died).__name__}: {self.died}")
                for rid, lc in list(self._by_rid.items()):
                    lc.on_finish(FINISH_ERROR, now)
                    self._emit(lc, ("error", msg))
            else:
                for rid, lc in list(self._by_rid.items()):
                    lc.on_finish(FINISH_CANCELLED, now)
                    self._emit(lc, ("finish", FINISH_CANCELLED))
            self._by_rid.clear()
            self._fail_queued_submits()

    def _fail_queued_submits(self):
        """Submit commands still queued when the loop exits would leave
        their handlers awaiting a future nobody will ever resolve."""
        err = EngineDied("engine loop is gone"
                         + (f": {self.died}" if self.died else ""))
        while True:
            try:
                cmd = self._cmds.get_nowait()
            except queue.Empty:
                return
            if cmd is not None and cmd[0] == "submit":
                lc, fut = cmd[1], cmd[2]
                try:
                    lc.loop.call_soon_threadsafe(_set_future, fut, err)
                except RuntimeError:
                    pass

    def _drain_failures(self, now: float):
        """Requests a supervised engine *failed* (poison quarantine, engine
        death) finish with an error event -> HTTP 500 naming the cause."""
        pop = getattr(self.engine, "pop_failures", None)
        if pop is None:
            return
        for rid, err in pop().items():
            lc = self._by_rid.pop(rid, None)
            if lc is None:
                continue
            lc.on_finish(FINISH_ERROR, now)
            self._emit(lc, ("error", f"{type(err).__name__}: {err}"))

    def _ensure_detok(self):
        """Detect a dead detokenize thread and restart it (bounded). The
        fault site fires *between* batches, so a dying thread never
        half-delivers an event; the queue content survives intact for its
        replacement."""
        if self._detok_thread.is_alive() or self._stop.is_set():
            return
        if self.n_detok_restarts >= self.max_detok_restarts:
            self.detok_dead = True              # alive -> False, healthz 503
            return
        self.n_detok_restarts += 1
        self.metrics.detok_restarts.set_to(self.n_detok_restarts)
        self._detok_thread = threading.Thread(target=self._detok_run,
                                              daemon=True,
                                              name="msb-detokenize")
        self._detok_thread.start()

    def _drain_cmds(self, block: bool):
        while True:
            try:
                cmd = (self._cmds.get(timeout=self.idle_poll_s) if block
                       else self._cmds.get_nowait())
            except queue.Empty:
                return
            block = False                       # only the first get blocks
            if cmd is None:
                return
            if cmd[0] == "submit":
                self._do_submit(cmd[1], cmd[2])
            elif cmd[0] == "cancel":
                self._do_cancel(cmd[1], cmd[2])
            elif cmd[0] == "drain":
                self.engine.drain()

    def _do_submit(self, lc: RequestLifecycle, fut: asyncio.Future):
        p = lc.params
        try:
            if self.draining:                   # raced the drain flag
                raise Draining("server is draining; not accepting work")
            rid = self.engine.submit(p.prompt, p.max_tokens,
                                     eos_id=p.eos_id, priority=p.priority,
                                     deadline_ms=p.deadline_ms)
        except Exception as e:                  # probe->submit race
            lc.loop.call_soon_threadsafe(_set_future, fut, e)
            return
        lc.engine_id = rid
        lc.on_accepted(time.monotonic())
        self._by_rid[rid] = lc
        lc.loop.call_soon_threadsafe(_set_future, fut, None)

    def _do_cancel(self, lc: RequestLifecycle, reason: str):
        rid = lc.engine_id
        if rid is None or rid not in self._by_rid:
            return                              # finished or never accepted
        del self._by_rid[rid]
        try:
            self.engine.abort_request(rid)
        except KeyError:
            pass
        lc.on_finish(reason, time.monotonic())
        self._emit(lc, ("finish", reason))

    def _apply_updates(self, updates, now: float):
        batch = []
        for rid, (new, done) in updates.items():
            lc = self._by_rid.get(rid)
            if lc is None:
                continue
            reason = None
            if len(lc.params.stop_ids) > 1:
                # multi-stop is monitored here (a single stop id rides the
                # engine's own eos path, including on-device mid-horizon)
                for j, t in enumerate(new):
                    if t in lc.params.stop_ids:
                        new, reason = new[:j + 1], FINISH_STOP
                        break
            if new:
                lc.on_tokens(new, now)
                batch.append((lc, ("tokens", list(new))))
            if reason is not None and not done:
                self.engine.abort_request(rid)
                done = True
            elif done:
                eos = lc.params.eos_id
                reason = (FINISH_STOP if eos is not None and lc.token_ids
                          and lc.token_ids[-1] == eos else FINISH_LENGTH)
            if done:
                del self._by_rid[rid]
                lc.on_finish(reason, now)
                batch.append((lc, ("finish", reason)))
        if batch:                  # one detok hand-off per engine step
            self._detok_q.put(batch)

    def _check_deadlines(self, now: float):
        for rid, lc in list(self._by_rid.items()):
            if lc.timed_out(now):
                del self._by_rid[rid]
                try:
                    self.engine.abort_request(rid)
                except KeyError:
                    pass
                lc.on_finish(FINISH_TIMEOUT, now)
                self._emit(lc, ("finish", FINISH_TIMEOUT))

    def _emit(self, lc: RequestLifecycle, event):
        self._detok_q.put([(lc, event)])

    # -- detokenize thread --------------------------------------------------
    def _detok_run(self):
        try:
            self._detok_batches()
        except Exception as e:                  # noqa: BLE001
            # the thread dies (injected or real); queued batches survive
            # untouched for the restarted thread (`_ensure_detok`)
            self.detok_err = e

    def _detok_batches(self):
        while True:
            if self.faults.armed:
                self.faults.fire("detok")       # pre-get: nothing is lost
            batch = self._detok_q.get()
            if batch is None:
                return
            by_loop: Dict[object, list] = {}
            for lc, event in batch:
                if event[0] == "tokens":
                    text = "".join(self.detokenize(t) for t in event[1])
                    event = ("tokens", event[1], text)
                by_loop.setdefault(lc.loop, []).append((lc.queue, event))
            for loop, items in by_loop.items():
                try:
                    loop.call_soon_threadsafe(_distribute, items)
                except RuntimeError:
                    pass                        # handler's loop shut down


class APIServer:
    """The HTTP front door. Owns an ``EngineLoop`` around the given
    ``ContinuousEngine`` (build the engine with ``max_waiting=`` to enable
    429 backpressure) and serves on ``host:port`` (port 0 = ephemeral).

    Use ``serve_background()`` (returns ``(host, port)``) for in-process
    embedding/tests and ``run()`` to block forever (examples/serve_api.py).
    Every connection is single-request (``Connection: close``): streaming
    bodies are EOF-delimited SSE, and a closed socket *is* the
    cancellation signal."""

    def __init__(self, engine, *, host: str = "127.0.0.1", port: int = 0,
                 model_name: Optional[str] = None,
                 metrics: Optional[ServeMetrics] = None,
                 detokenize: Optional[Callable[[int], str]] = None,
                 default_max_tokens: int = 16, max_tokens_cap: int = 2048,
                 max_timeout_s: Optional[float] = None,
                 retry_after_s: float = 1.0, faults=NO_FAULTS,
                 warmup: bool = False, overload=False):
        self.host, self.port = host, port
        model = getattr(engine, "engine", engine).model  # unwrap supervisor
        self.model_name = model_name or model.cfg.name
        self.vocab_size = int(model.cfg.vocab_size)
        self.default_max_tokens = default_max_tokens
        self.max_tokens_cap = max_tokens_cap
        self.max_timeout_s = max_timeout_s
        self.retry_after_s = retry_after_s
        self.faults = faults
        self.engine_loop = EngineLoop(engine, metrics=metrics,
                                      detokenize=detokenize, faults=faults,
                                      warmup=warmup)
        self.metrics = self.engine_loop.metrics
        # overload control plane (DESIGN.md Sec. 17): pass overload=True
        # for the default brownout ladder, a dict of OverloadController
        # kwargs to tune it, or a prebuilt controller. The engine loop
        # ticks it; rejections and /healthz read it.
        self.overload: Optional[OverloadController] = None
        if overload:
            if isinstance(overload, OverloadController):
                self.overload = overload
            elif isinstance(overload, dict):
                self.overload = OverloadController(
                    engine, self.metrics,
                    retry_after_base_s=retry_after_s, **overload)
            else:
                self.overload = OverloadController(
                    engine, self.metrics, retry_after_base_s=retry_after_s)
            self.engine_loop.overload = self.overload
        self._retry_salt = 0          # deterministic Retry-After jitter key
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._shutdown: Optional[asyncio.Event] = None
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ----------------------------------------------------------
    def serve_background(self):
        """Start the engine loop + HTTP server on daemon threads; returns
        the bound ``(host, port)``. Pair with ``close()``."""
        self.engine_loop.start()
        ready = threading.Event()
        self._thread = threading.Thread(
            target=self._thread_main, args=(ready,), daemon=True,
            name="msb-api-server")
        self._thread.start()
        if not ready.wait(timeout=30):
            raise RuntimeError("API server failed to bind")
        return self.host, self.port

    def run(self, drain_grace_s: float = 30.0):
        """Serve until interrupted (the CLI path). SIGTERM/SIGINT initiate
        a graceful drain (``train.fault.PreemptionHandler``): admissions
        close with 503, in-flight requests finish (bounded by
        ``drain_grace_s``), then the process exits cleanly."""
        from ..train.fault import PreemptionHandler
        preemption = PreemptionHandler()
        self.engine_loop.start()
        try:
            asyncio.run(self._amain(None, preemption=preemption,
                                    drain_grace_s=drain_grace_s))
        finally:
            preemption.restore()
            self.engine_loop.stop()

    def drain(self):
        """Programmatic drain (same path SIGTERM takes in ``run()``)."""
        self.engine_loop.drain()

    def close(self):
        if self._loop is not None and self._shutdown is not None:
            try:
                self._loop.call_soon_threadsafe(self._shutdown.set)
            except RuntimeError:
                pass
        if self._thread is not None:
            self._thread.join(timeout=10)
        self.engine_loop.stop()

    def _thread_main(self, ready: threading.Event):
        self._loop = asyncio.new_event_loop()
        asyncio.set_event_loop(self._loop)
        try:
            self._loop.run_until_complete(self._amain(ready))
        finally:
            self._loop.close()

    async def _amain(self, ready: Optional[threading.Event],
                     preemption=None, drain_grace_s: float = 30.0):
        self._shutdown = asyncio.Event()
        server = await asyncio.start_server(self._handle, self.host,
                                            self.port)
        self.port = server.sockets[0].getsockname()[1]
        if ready is not None:
            ready.set()
        else:
            print(f"[serve] listening on http://{self.host}:{self.port} "
                  f"(model {self.model_name})")
        async with server:
            if preemption is None:
                await self._shutdown.wait()
            else:
                await self._wait_or_drain(preemption, drain_grace_s)

    async def _wait_or_drain(self, preemption, drain_grace_s: float):
        """Poll for SIGTERM/SIGINT; on arrival, drain: close admissions
        (503), let in-flight requests finish (up to ``drain_grace_s``),
        then fall out of ``_amain`` so the server sockets close."""
        while not self._shutdown.is_set():
            if preemption.should_stop():
                print("[serve] preemption signal: draining "
                      f"(grace {drain_grace_s:.0f}s)")
                self.engine_loop.drain()
                deadline = time.monotonic() + drain_grace_s
                while (not self.engine_loop.drained
                       and time.monotonic() < deadline):
                    await asyncio.sleep(0.05)
                print("[serve] drained" if self.engine_loop.drained
                      else "[serve] drain grace expired; exiting anyway")
                return
            try:
                await asyncio.wait_for(self._shutdown.wait(), timeout=0.1)
            except asyncio.TimeoutError:
                pass

    # -- HTTP plumbing ------------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        try:
            await self._handle_one(reader, writer)
        except (ConnectionError, asyncio.IncompleteReadError,
                asyncio.TimeoutError, asyncio.LimitOverrunError):
            pass
        except Exception as e:                  # pragma: no cover - backstop
            await self._send_json(writer, 500, {"error": {
                "message": f"internal error: {e}",
                "type": "internal_error"}}, best_effort=True)
        finally:
            try:
                writer.close()
            except Exception:
                pass

    async def _handle_one(self, reader, writer):
        head = await asyncio.wait_for(reader.readuntil(b"\r\n\r\n"),
                                      timeout=30)
        req_line, *header_lines = head.decode("latin-1").split("\r\n")
        parts = req_line.split(" ")
        if len(parts) < 3:
            return await self._send_json(writer, 400, _err("malformed "
                                         "request line", "protocol_error"))
        method, path = parts[0].upper(), parts[1].split("?", 1)[0]
        headers = {}
        for line in header_lines:
            if ":" in line:
                k, v = line.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", 0) or 0)
        body = await reader.readexactly(n) if n else b""

        if path == "/v1/completions":
            if method != "POST":
                return await self._send_json(writer, 405, _err(
                    f"{method} not allowed on {path}", "protocol_error"))
            return await self._completions(reader, writer, body)
        if method != "GET":
            return await self._send_json(writer, 405, _err(
                f"{method} not allowed on {path}", "protocol_error"))
        if path == "/healthz":
            health = self.engine_loop.health
            body = {"status": health, "model": self.model_name,
                    "brownout_level":
                        self.overload.level if self.overload else 0}
            stats = getattr(self.engine_loop.engine, "stats", None)
            if stats is not None:
                st = stats()
                for k in ("restarts", "watchdog_trips", "quarantined"):
                    if k in st:
                        body[k] = st[k]
            # ok/degraded keep serving (200); warming/draining/dead do
            # not (503) — warming adds Retry-After (transient, like a
            # recovery window) so probes know to re-check, not evict
            if health in (OK, DEGRADED):
                return await self._send_json(writer, 200, body)
            extra = ()
            if health == WARMING:
                extra = self._retry_after_header()
            return await self._send_json(writer, 503, body, extra=extra)
        if path == "/v1/models":
            return await self._send_json(writer, 200, {
                "object": "list",
                "data": [{"id": self.model_name, "object": "model",
                          "owned_by": "msb-repro"}]})
        if path == "/metrics":
            self.metrics.sync_engine(self.engine_loop.engine)
            return await self._send_raw(
                writer, 200, self.metrics.render().encode(),
                Registry.CONTENT_TYPE)
        return await self._send_json(writer, 404, _err(
            f"no route {path}", "not_found_error"))

    # -- /v1/completions ----------------------------------------------------
    async def _completions(self, reader, writer, body: bytes):
        try:
            payload = json.loads(body.decode() or "{}")
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            self.metrics.requests.inc(outcome="rejected")
            return await self._send_json(writer, 400, _err(
                f"body is not valid JSON: {e}", "invalid_request_error"))
        if isinstance(payload, dict) and \
                payload.get("model") not in (None, self.model_name):
            self.metrics.requests.inc(outcome="rejected")
            return await self._send_json(writer, 404, _err(
                f"model {payload['model']!r} not found (serving "
                f"{self.model_name!r})", "not_found_error", param="model"))
        try:
            params = parse_completion_request(
                payload, vocab_size=self.vocab_size,
                default_max_tokens=self.default_max_tokens,
                max_tokens_cap=self.max_tokens_cap,
                max_timeout_s=self.max_timeout_s)
        except ValidationError as e:
            self.metrics.requests.inc(outcome="rejected")
            return await self._send_json(writer, 400, _err(
                str(e), "invalid_request_error", param=e.param))

        err = self.engine_loop.probe(len(params.prompt), params.max_tokens,
                                     priority=params.priority)
        if err is None:
            lc = RequestLifecycle(params, metrics=self.metrics)
            err = await self.engine_loop.submit(lc)
        if err is not None:
            return await self._reject(writer, err, params)

        watcher = asyncio.ensure_future(self._watch_disconnect(reader, lc))
        try:
            if params.stream:
                await self._stream_response(writer, lc)
            else:
                await self._json_response(writer, lc)
        finally:
            watcher.cancel()

    def _retry_after_value(self) -> int:
        """The one Retry-After computation (satellite: previously the
        saturation 429, warming 503 and recovery 503 paths each derived
        their own constant). With a controller attached the value scales
        with observed pressure and brownout level; without one it is the
        configured base with deterministic per-response jitter so
        synchronized clients don't re-arrive in lockstep."""
        if self.overload is not None:
            return self.overload.retry_after()
        self._retry_salt += 1
        return compute_retry_after(self.retry_after_s,
                                   salt=self._retry_salt)

    def _retry_after_header(self):
        return ((b"Retry-After", str(self._retry_after_value()).encode()),)

    async def _reject(self, writer, err: Exception, params=None):
        retry = self._retry_after_header()
        if isinstance(err, Saturated):
            # transient *capacity* condition: back off and retry (429).
            # Brownout sheds land here too — count them by class so the
            # shed fraction per priority is observable.
            self.metrics.requests.inc(outcome="saturated")
            if params is not None:
                self.metrics.sheds.inc(**{"class": params.priority})
            return await self._send_json(
                writer, 429, _err(f"server saturated, retry later: {err}",
                                  "overloaded_error"), extra=retry)
        if isinstance(err, Recovering):
            # transient *availability* condition: the replica is rebuilding
            # after a crash — distinct from saturation so load balancers
            # can tell "shed load" from "replica briefly down" (503)
            self.metrics.requests.inc(outcome="recovering")
            return await self._send_json(
                writer, 503, _err(str(err), "unavailable_error"),
                extra=retry)
        if isinstance(err, Warming):
            # transient, like Recovering: startup warmup is compiling the
            # trace set — the replica will accept shortly (503 + Retry-
            # After, distinct outcome so dashboards can tell them apart)
            self.metrics.requests.inc(outcome="warming")
            return await self._send_json(
                writer, 503, _err(str(err), "unavailable_error"),
                extra=retry)
        if isinstance(err, (Draining, EngineDied)):
            # permanent for this replica: go elsewhere (503, no Retry-After)
            self.metrics.requests.inc(
                outcome="draining" if isinstance(err, Draining) else "dead")
            return await self._send_json(
                writer, 503, _err(str(err), "unavailable_error"))
        self.metrics.requests.inc(outcome="rejected")
        return await self._send_json(writer, 400, _err(
            str(err), "invalid_request_error"))

    async def _watch_disconnect(self, reader, lc: RequestLifecycle):
        """EOF on the request socket = the client went away: propagate
        cancellation so the engine frees the request's pages. A client that
        pipelines extra bytes on this one-request connection is ignored."""
        try:
            data = await reader.read(1)
        except Exception:
            data = b""
        if not data and lc.state != DONE:
            self.engine_loop.cancel(lc, FINISH_CANCELLED)

    def _chunk(self, lc, text, token_ids, finish_reason):
        if lc.created is None:
            lc.created = int(time.time())
        return {"id": lc.request_id, "object": "text_completion",
                "created": lc.created, "model": self.model_name,
                "choices": [{"index": 0, "text": text,
                             "token_ids": token_ids,
                             "finish_reason": finish_reason}]}

    async def _stream_response(self, writer, lc: RequestLifecycle):
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        while True:
            # drain everything already queued and write it as one syscall:
            # still one SSE frame per token-bearing event (framing is the
            # contract), but a handler that fell behind the engine catches
            # up in a single write+drain instead of one per frame
            events = [await lc.queue.get()]
            while True:
                try:
                    events.append(lc.queue.get_nowait())
                except asyncio.QueueEmpty:
                    break
            out, done = bytearray(), False
            for event in events:
                if event[0] == "tokens":
                    if self.faults.armed:
                        try:
                            # injected mid-stream connection drop: fires
                            # per token-bearing frame
                            self.faults.fire("socket")
                        except Exception:
                            self.engine_loop.cancel(lc, FINISH_CANCELLED)
                            try:
                                writer.transport.abort()
                            except Exception:
                                pass
                            return
                    out += _sse(self._chunk(lc, event[2], event[1], None))
                elif event[0] == "error":       # engine-side failure
                    frame = self._chunk(lc, "", [], FINISH_ERROR)
                    frame["error"] = {"message": event[1],
                                      "type": "engine_error"}
                    out += _sse(frame)
                    out += b"data: [DONE]\n\n"
                    done = True
                    break
                else:                           # ("finish", reason)
                    out += _sse(self._chunk(lc, "", [], event[1]))
                    out += b"data: [DONE]\n\n"
                    done = True
                    break
            try:
                writer.write(bytes(out))
                await writer.drain()
            except (ConnectionError, BrokenPipeError):
                self.engine_loop.cancel(lc, FINISH_CANCELLED)
                return
            if done:
                return

    async def _json_response(self, writer, lc: RequestLifecycle):
        pieces, ids = [], []
        while True:
            event = await lc.queue.get()
            if event[0] == "tokens":
                ids.extend(event[1])
                pieces.append(event[2])
            elif event[0] == "error":           # engine-side failure: 500
                return await self._send_json(writer, 500, _err(
                    event[1], "engine_error"), best_effort=True)
            else:
                reason = event[1]
                break
        if reason == FINISH_CANCELLED:
            return                              # nobody left to answer
        n_prompt = int(len(lc.params.prompt))
        resp = self._chunk(lc, "".join(pieces), ids, reason)
        resp["usage"] = {"prompt_tokens": n_prompt,
                         "completion_tokens": len(ids),
                         "total_tokens": n_prompt + len(ids)}
        await self._send_json(writer, 200, resp)

    # -- response writers ---------------------------------------------------
    _REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
                405: "Method Not Allowed", 429: "Too Many Requests",
                500: "Internal Server Error", 503: "Service Unavailable"}

    async def _send_raw(self, writer, status, body: bytes, ctype: str,
                        extra=(), best_effort=False):
        try:
            head = (f"HTTP/1.1 {status} {self._REASONS.get(status, '')}\r\n"
                    f"Content-Type: {ctype}\r\n"
                    f"Content-Length: {len(body)}\r\n"
                    "Connection: close\r\n").encode()
            for k, v in extra:
                head += k + b": " + v + b"\r\n"
            writer.write(head + b"\r\n" + body)
            await writer.drain()
        except (ConnectionError, BrokenPipeError):
            if not best_effort:
                raise

    async def _send_json(self, writer, status, obj, extra=(),
                         best_effort=False):
        await self._send_raw(writer, status, json.dumps(obj).encode(),
                             "application/json", extra, best_effort)


def _sse(frame) -> bytes:
    return (b"data: " + json.dumps(frame, separators=(",", ":")).encode()
            + b"\n\n")


def _err(message, type_, param=None):
    e = {"message": message, "type": type_}
    if param is not None:
        e["param"] = param
    return {"error": e}
