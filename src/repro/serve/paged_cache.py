"""Paged KV-cache: a global page pool + per-sequence block tables.

The data plane (the pools themselves) is a device pytree written inside the
jitted serving step (models.Model.paged_step); this class is the *control*
plane: a host-side allocator that hands out fixed-size pages from a free
list, maintains the block table and length of every sequence slot, and
reference-counts pages so forked sequences share their common prefix
(copy-on-write only for the final partial page, which is the only page that
can still be written).

Page 0 is reserved as a scratch page: padding rows of the packed batch
scatter their (garbage) K/V there, so the jitted step needs no masking
branches. The allocator never hands page 0 to a sequence.

**Automatic prefix cache** (DESIGN.md Sec. 11). With ``prefix_cache=True``
the allocator keeps a registry of committed, immutable *full* pages keyed
by a rolling content hash of the token chain that produced them (the K/V
of page *i* depends on every token before it, so the hash chains:
``h_i = H(h_{i-1} || tokens[i*ps:(i+1)*ps])``). A registered page whose
refcount drops to zero is *cached-but-alive*: it moves to an LRU list
instead of the free list, and is reclaimed (unregistered + freed) only
under pool pressure — always before any live sequence is preempted. New
sequences longest-prefix-match the registry at admission and adopt the
matched pages by refcount bump, so chunked prefill skips the shared
prefix entirely.

Tensor parallelism (DESIGN.md Sec. 10) never touches this control plane:
page ids, block tables, lengths, refcounts and the prefix registry are
head-agnostic. Under a TP mesh the engine re-homes ``pools`` with a
head-sharded NamedSharding (leaf dim 3, the KV-head dim, split over the
model axis) and every device holds the *same pages* for *its* heads — one
block-table row addresses all shards at once, and fork/preempt/commit/
prefix-adopt work unchanged.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class OutOfPages(Exception):
    """Raised when a reservation cannot be satisfied (caller preempts)."""


class PageStateError(RuntimeError):
    """An allocator lifecycle invariant was violated (double release,
    commit past the reservation, adopt into a dirty slot). Unlike the bare
    ``assert`` this replaced, it survives ``python -O`` — silently
    corrupting the free list is strictly worse than failing loudly."""


def _chain_digest(prev: bytes, page_tokens: np.ndarray) -> bytes:
    """Rolling hash step: digest of (parent digest, this page's tokens)."""
    h = hashlib.blake2b(prev, digest_size=16)
    h.update(np.ascontiguousarray(page_tokens, np.int32).tobytes())
    return h.digest()


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """A longest-prefix registry hit: ``pages`` (and their chain digests)
    cover the first ``n_tokens`` positions; ``n_unreferenced`` of them are
    currently LRU-cached (refcount 0) and leave the reclaimable set when
    adopted."""
    pages: Tuple[int, ...]
    digests: Tuple[bytes, ...]
    n_tokens: int
    n_unreferenced: int


class PagedKVCache:
    """Host-side page allocator + device page pools.

    Contract: ``reserve`` is all-or-nothing (raises ``OutOfPages`` with no
    partial allocation), ``commit`` only ever records lengths the caller
    has actually written device-side, ``release`` returns a slot's pages in
    reverse order (LIFO reuse keeps prefixes warm), and ``fork`` shares
    full pages by refcount while copying only the final partial page.
    With ``prefix_cache=True`` a released page that is registered in the
    prefix registry parks on an LRU list instead of the free list;
    ``reserve``/``fork`` reclaim from that list before reporting the pool
    exhausted, so cached pages never cause a preemption.
    ``pools`` is an opaque device pytree owned by the jitted serving step;
    this class never reads it, only swaps it wholesale (fork's page copy,
    the engine's sharded re-homing).
    """

    def __init__(self, model, *, num_pages, page_size, max_seqs,
                 max_pages_per_seq=None, prefix_cache=False, faults=None,
                 kv_bits=16):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved scratch)")
        if kv_bits not in (16, 8, 4):
            raise ValueError(f"kv_bits must be 16, 8 or 4, got {kv_bits}")
        if faults is None:
            from .faults import NO_FAULTS
            faults = NO_FAULTS
        self.faults = faults
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)
        self.max_seqs = int(max_seqs)
        self.max_pages_per_seq = int(max_pages_per_seq or num_pages - 1)
        self.prefix_cache = bool(prefix_cache)
        self.kv_bits = int(kv_bits)
        self.pools = model.init_paged_pools(num_pages, page_size,
                                            kv_bits=self.kv_bits,
                                            max_seqs=max_seqs)
        # quantization frontier: full pages of each slot whose content is
        # committed into the packed code/scale pools (kv_bits < 16). The
        # device quantizes every page completed by a dispatch, so after the
        # host commit this must equal seq_lens // page_size — "no committed
        # page left unquantized" (check_invariants enforces it).
        self._quant_frontier = np.zeros((max_seqs,), np.int64)
        # host metadata
        self.block_tables = np.zeros((max_seqs, self.max_pages_per_seq),
                                     np.int32)
        self.seq_pages: List[List[int]] = [[] for _ in range(max_seqs)]
        self.seq_lens = np.zeros((max_seqs,), np.int32)
        self.ref_counts = np.zeros((num_pages,), np.int32)
        self.ref_counts[0] = 1                    # scratch page, never freed
        self._free = list(range(num_pages - 1, 0, -1))    # LIFO free list
        self._free_slots = list(range(max_seqs - 1, -1, -1))
        # prefix registry: digest <-> page (one-to-one), LRU of refcount-0
        # registered pages (insertion order == eviction order)
        self._registry: Dict[bytes, int] = {}
        self._page_digest: Dict[int, bytes] = {}
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self._slot_digests: List[List[bytes]] = [[] for _ in range(max_seqs)]
        self.n_cache_evictions = 0
        # bumped whenever the digest->page mapping changes (register /
        # reclaim); lets callers memoize match_prefix results — an epoch
        # match can only go stale in LRU-membership (avail accounting),
        # never in page validity, and reserve-time OutOfPages + preemption
        # already backstop optimistic admission
        self.registry_epoch = 0
        # block-table row upload cache: slot versions bump on any table
        # mutation, so an unchanged (slots, tables) dispatch reuses the
        # already-transferred device rows instead of re-uploading
        self._versions = np.zeros((max_seqs,), np.int64)
        self._rows_cache: Dict[Tuple[int, ...],
                               Tuple[Tuple[int, ...], jnp.ndarray]] = {}
        donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
        self._copy_page = jax.jit(self._copy_page_impl, donate_argnums=donate)
        self._copy_hot = jax.jit(self._copy_hot_impl, donate_argnums=donate)

    # -- capacity ----------------------------------------------------------
    @property
    def n_free_pages(self):
        return len(self._free)

    @property
    def n_cached_pages(self):
        """Registered pages no sequence references (reclaimable on demand)."""
        return len(self._lru)

    @property
    def n_available_pages(self):
        """Pages a reservation can obtain: free now + reclaimable LRU."""
        return len(self._free) + len(self._lru)

    @property
    def n_free_slots(self):
        return len(self._free_slots)

    def pages_for(self, n_tokens):
        return -(-int(n_tokens) // self.page_size)

    def fits(self, n_tokens):
        """Whole-sequence capacity check (used at submit/admission time)."""
        return self.capacity_error(n_tokens) is None

    def capacity_error(self, n_tokens) -> Optional[str]:
        """Why ``n_tokens`` can never fit, or None if it can. Names every
        limit the request actually exceeds — a request bounded by
        ``max_pages_per_seq`` must not be told the pool is too small."""
        need = self.pages_for(n_tokens)
        limits = []
        if need > self.max_pages_per_seq:
            limits.append(f"max_pages_per_seq={self.max_pages_per_seq}")
        if need > self.num_pages - 1:
            limits.append(f"the page pool ({self.num_pages - 1} usable "
                          f"pages x {self.page_size})")
        if not limits:
            return None
        return (f"{n_tokens} tokens need {need} pages, exceeding "
                + " and ".join(limits))

    # -- slots -------------------------------------------------------------
    def alloc_slot(self) -> Optional[int]:
        if not self._free_slots:
            return None
        slot = self._free_slots.pop()
        self.seq_pages[slot] = []
        self.seq_lens[slot] = 0
        self.block_tables[slot] = 0
        self._slot_digests[slot] = []
        self._quant_frontier[slot] = 0
        self._versions[slot] += 1
        return slot

    def release(self, slot):
        """Free the slot: decref every page. Dead pages return to the free
        list (reverse order so LIFO reuse stays prefix-friendly) — unless
        registered in the prefix cache, in which case they park on the LRU
        list, content intact, until reclaimed under pressure."""
        for page in reversed(self.seq_pages[slot]):
            if self.ref_counts[page] <= 0:
                raise PageStateError(
                    f"release(slot={slot}): page {page} refcount "
                    f"{int(self.ref_counts[page])} already zero "
                    "(double release?)")
            self.ref_counts[page] -= 1
            if self.ref_counts[page] == 0:
                if page in self._page_digest:
                    self._lru[page] = None       # newest at the end
                else:
                    self._free.append(page)
        self.seq_pages[slot] = []
        self.seq_lens[slot] = 0
        self.block_tables[slot] = 0
        self._slot_digests[slot] = []
        self._quant_frontier[slot] = 0
        self._versions[slot] += 1
        self._free_slots.append(slot)

    # -- pages -------------------------------------------------------------
    def _reclaim(self, n) -> int:
        """Evict up to ``n`` LRU-cached pages back onto the free list
        (oldest first); returns how many were reclaimed."""
        freed = 0
        while freed < n and self._lru:
            page, _ = self._lru.popitem(last=False)
            digest = self._page_digest.pop(page)
            del self._registry[digest]
            self._free.append(page)
            self.n_cache_evictions += 1
            freed += 1
        if freed:
            self.registry_epoch += 1
        return freed

    def shrink_lru(self, floor_pages: int) -> int:
        """Evict LRU-parked prefix pages until at most ``floor_pages``
        remain parked; returns how many were evicted. The brownout ladder's
        cache-pressure lever (DESIGN.md Sec. 17): trading cold prefix
        residency for free pages is host-only bookkeeping — no live
        sequence is touched and no device state moves — so it is always
        safe to apply between steps. A no-op when the park is already at
        or under the floor."""
        excess = len(self._lru) - max(0, int(floor_pages))
        return self._reclaim(excess) if excess > 0 else 0

    def n_covered_tokens(self, slot) -> int:
        """Token positions ``slot``'s reserved pages can hold — the extent
        of its current lease. A decode-horizon dispatch (DESIGN.md Sec. 12)
        may write any position below this without host intervention;
        ``commit`` may trail it when a row stopped early (eos mid-horizon),
        and ``release`` returns trailing never-written pages all the same."""
        return len(self.seq_pages[slot]) * self.page_size

    def reserve(self, slot, n_tokens):
        """Grow ``slot``'s block table to cover ``n_tokens``. All-or-nothing:
        raises OutOfPages without partial allocation if the pool is short
        (after reclaiming LRU-cached prefix pages, which are always spent
        before the caller resorts to preempting a live sequence).

        A reservation is a *lease*: the pages are addressable device-side
        (``table_rows`` uploads the whole row) the moment this returns, so
        a fused multi-token dispatch can fill them without further host
        round trips. Reserved-but-unwritten pages hold stale data until
        written; the attention mask (``kpos < kv_lens``) keeps them
        invisible, and ``commit`` only ever ratifies what was written."""
        need = self.pages_for(n_tokens) - len(self.seq_pages[slot])
        if need <= 0:
            return
        if self.faults.armed:
            # fires before any allocation, so the all-or-nothing contract
            # holds for injected OOM exactly as for real exhaustion
            self.faults.fire("alloc")
        if self.pages_for(n_tokens) > self.max_pages_per_seq:
            raise OutOfPages(f"slot {slot}: {n_tokens} tokens exceed "
                             f"max_pages_per_seq={self.max_pages_per_seq}")
        if need > len(self._free):
            self._reclaim(need - len(self._free))
        if need > len(self._free):
            raise OutOfPages(f"slot {slot}: need {need} pages, "
                             f"{len(self._free)} free")
        for _ in range(need):
            page = self._free.pop()
            self.ref_counts[page] += 1
            self.block_tables[slot, len(self.seq_pages[slot])] = page
            self.seq_pages[slot].append(page)
        self._versions[slot] += 1

    def commit(self, slot, n_tokens):
        """Record that ``n_tokens`` of ``slot`` are now written device-side."""
        if self.pages_for(n_tokens) > len(self.seq_pages[slot]):
            raise PageStateError(
                f"commit(slot={slot}, n_tokens={n_tokens}): only "
                f"{len(self.seq_pages[slot])} pages reserved "
                f"({len(self.seq_pages[slot]) * self.page_size} tokens)")
        self.seq_lens[slot] = n_tokens
        # the dispatch that wrote these tokens also quantized every page it
        # completed (quantize-on-commit), so the frontier rides the commit
        self._quant_frontier[slot] = int(n_tokens) // self.page_size

    # -- prefix registry ---------------------------------------------------
    def register_prefix(self, slot, tokens):
        """Register ``slot``'s committed full pages under the rolling hash
        of ``tokens`` (the committed token chain, ``tokens[:seq_lens]``).
        Idempotent and incremental: the per-slot digest chain is extended
        only for newly-filled pages, so per-step decode calls are O(new
        pages), not O(sequence length). First-writer-wins: a digest already
        mapping to an equal-content page is left alone."""
        if not self.prefix_cache:
            return
        ps = self.page_size
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        n_full = min(len(tokens) // ps, len(self.seq_pages[slot]))
        digests = self._slot_digests[slot]
        prev = digests[-1] if digests else b""
        for i in range(len(digests), n_full):
            prev = _chain_digest(prev, tokens[i * ps:(i + 1) * ps])
            digests.append(prev)
            page = self.seq_pages[slot][i]
            if prev not in self._registry and page not in self._page_digest:
                self._registry[prev] = page
                self._page_digest[page] = prev
                self.registry_epoch += 1

    def match_prefix(self, tokens, max_tokens=None) -> Optional[PrefixMatch]:
        """Longest-prefix registry lookup for a token chain. Pure (no
        allocator mutation); returns None when disabled or nothing matches.
        ``max_tokens`` caps the match (admission passes ``len(tokens)-1``
        so at least one position is always left to prefill — the sampler
        needs its logits)."""
        if not self.prefix_cache or not self._registry:
            return None
        ps = self.page_size
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        limit = len(tokens) if max_tokens is None else min(len(tokens),
                                                           int(max_tokens))
        n_full = min(limit // ps, self.max_pages_per_seq)
        pages, digests = [], []
        prev = b""
        for i in range(n_full):
            prev = _chain_digest(prev, tokens[i * ps:(i + 1) * ps])
            page = self._registry.get(prev)
            if page is None:
                break
            pages.append(page)
            digests.append(prev)
        if not pages:
            return None
        n_unref = sum(1 for p in pages if p in self._lru)
        return PrefixMatch(tuple(pages), tuple(digests),
                           len(pages) * ps, n_unref)

    def adopt_prefix(self, slot, match: PrefixMatch):
        """Bump the matched pages into ``slot``'s block table and record
        their content as committed (it already is, device-side). The slot
        must be freshly allocated — adopted pages are always a sequence's
        first pages (position 0 onward), by construction of the hash chain.
        """
        if self.seq_pages[slot]:
            raise PageStateError(f"adopt_prefix(slot={slot}): slot already "
                                 f"holds {len(self.seq_pages[slot])} pages")
        for i, page in enumerate(match.pages):
            self._lru.pop(page, None)            # referenced again
            self.ref_counts[page] += 1
            self.block_tables[slot, i] = page
            self.seq_pages[slot].append(page)
        self._slot_digests[slot] = list(match.digests)
        self.seq_lens[slot] = match.n_tokens
        # adopted pages are committed full pages: under kv_bits < 16 their
        # content already lives quantized in the packed pools
        self._quant_frontier[slot] = match.n_tokens // self.page_size
        self._versions[slot] += 1

    # -- prefix sharing ----------------------------------------------------
    def fork(self, src_slot) -> Optional[int]:
        """Fork ``src_slot``: full pages are shared by refcount; a partial
        final page is copied device-side (copy-on-write at fork time — full
        pages are never written again, so sharing them is safe). Returns
        None, with no slot or page leaked, when slots are exhausted or the
        partial-page copy cannot get a page even after reclaiming the
        prefix-cache LRU."""
        dst = self.alloc_slot()
        if dst is None:
            return None
        n = int(self.seq_lens[src_slot])
        n_full = n // self.page_size
        partial = n % self.page_size > 0
        if partial and not self._free and not self._reclaim(1):
            self.release(dst)
            return None
        try:
            for i, page in enumerate(self.seq_pages[src_slot][:n_full]):
                self.ref_counts[page] += 1
                self.block_tables[dst, i] = page
                self.seq_pages[dst].append(page)
            if partial:
                page = self._free.pop()
                self.ref_counts[page] += 1
                self.block_tables[dst, n_full] = page
                self.seq_pages[dst].append(page)
                if self.kv_bits == 16:
                    src_page = self.seq_pages[src_slot][n_full]
                    self.pools = self._copy_page(self.pools, src_page, page)
                else:
                    # quantized pools keep the partial page full-precision in
                    # the per-slot hot row; the packed page just allocated is
                    # address space for the eventual quantize-on-commit. Copy
                    # the hot row (src slot+1 -> dst slot+1) instead.
                    self.pools = self._copy_hot(self.pools, src_slot + 1,
                                                dst + 1)
        except Exception:
            self.release(dst)
            raise
        self.seq_lens[dst] = n
        self._slot_digests[dst] = self._slot_digests[src_slot][:n_full]
        self._quant_frontier[dst] = n // self.page_size
        self._versions[dst] += 1
        return dst

    @staticmethod
    def _copy_page_impl(pools, src, dst):
        def cp(leaf):
            # leaves: (n_periods, num_pages, page_size, KV, hd)
            return leaf.at[:, dst].set(leaf[:, src])
        return jax.tree_util.tree_map(cp, pools)

    @staticmethod
    def _copy_hot_impl(pools, src_row, dst_row):
        def cp(path, leaf):
            # hot leaves: (n_periods, max_seqs + 1, page_size, KV, hd);
            # code/scale pools are page-indexed, not slot-indexed — untouched
            if str(getattr(path[-1], "key", "")).endswith("_hot"):
                return leaf.at[:, dst_row].set(leaf[:, src_row])
            return leaf
        return jax.tree_util.tree_map_with_path(cp, pools)

    # -- packed-batch views -------------------------------------------------
    def table_rows(self, slots):
        """Device block-table rows for the given slots, zero-padded to the
        packed batch size implied by ``len(slots)`` (-1 slots = pad rows).
        Each row carries the slot's *entire* reservation — every leased
        page, committed or not — which is what lets a decode-horizon
        dispatch cross page boundaries mid-scan with no host intervention
        (DESIGN.md Sec. 12). Memoized on (slots, per-slot table versions):
        the steady-state decode loop re-dispatches the same rows every
        step, so the (B, max_pages_per_seq) host build + transfer happens
        only when a slot's table actually changed."""
        key = tuple(int(s) for s in slots)
        vers = tuple(int(self._versions[s]) if s >= 0 else -1 for s in key)
        hit = self._rows_cache.get(key)
        if hit is not None and hit[0] == vers:
            return hit[1]
        rows = np.zeros((len(key), self.max_pages_per_seq), np.int32)
        for i, s in enumerate(key):
            if s >= 0:
                rows[i] = self.block_tables[s]
        dev = jnp.asarray(rows)
        if len(self._rows_cache) >= 256:          # bound stale batch shapes
            self._rows_cache.clear()
        self._rows_cache[key] = (vers, dev)
        return dev

    # -- invariants ----------------------------------------------------------
    def check_invariants(self, expect_idle: bool = False):
        """Full-state allocator audit; raises ``PageStateError`` naming the
        first violated invariant, returns None when consistent.

        Checks: the refcount of every page equals its occurrence count
        across live block tables (scratch page 0 pinned at 1, LRU-parked
        pages at 0); the free list is duplicate-free and disjoint from
        every live table, the LRU and the registry; every usable page is
        in exactly one of {free, LRU-cached, referenced} — conservation,
        i.e. zero leaked pages; block-table rows mirror ``seq_pages``;
        committed lengths fit inside reserved leases; the registry and its
        page->digest inverse are a bijection and the LRU is a subset of the
        registered refcount-0 pages; free slots are duplicate-free with
        fully cleared state. Under ``kv_bits < 16`` additionally: every live
        slot's quantization frontier equals its committed full-page count
        (no committed page left unquantized, none quantized ahead of
        commit) and fits its lease; free slots sit at frontier 0.

        ``expect_idle=True`` additionally requires no live sequence at all
        — every slot free and every usable page free or LRU-reclaimable,
        the post-drain / teardown baseline the chaos tests assert.

        This is the ground truth the supervisor's recovery story leans on:
        an engine incarnation that crashed mid-mutation is *discarded*,
        never repaired, precisely because this audit can only certify a
        pool that finished its bookkeeping."""
        def fail(msg):
            raise PageStateError(f"check_invariants: {msg}")

        live = [s for s in range(self.max_seqs) if s not in self._free_slots]
        if len(set(self._free_slots)) != len(self._free_slots):
            fail(f"duplicate slots on the free-slot list: {self._free_slots}")
        for s in self._free_slots:
            if self.seq_pages[s] or self.seq_lens[s] or self._slot_digests[s]:
                fail(f"free slot {s} still holds state: "
                     f"pages={self.seq_pages[s]}, "
                     f"len={int(self.seq_lens[s])}")
            if self._quant_frontier[s] != 0:
                fail(f"free slot {s} has nonzero quant frontier "
                     f"{int(self._quant_frontier[s])}")
        # refcount reconstruction from live block tables (+ scratch pin)
        expected = np.zeros((self.num_pages,), np.int64)
        expected[0] = 1
        for s in live:
            pages = self.seq_pages[s]
            for i, p in enumerate(pages):
                if not 1 <= p < self.num_pages:
                    fail(f"slot {s} holds invalid page id {p}")
                expected[p] += 1
                if self.block_tables[s, i] != p:
                    fail(f"block_tables[{s}, {i}] = "
                         f"{int(self.block_tables[s, i])} but seq_pages "
                         f"says page {p}")
            if np.any(self.block_tables[s, len(pages):] != 0):
                fail(f"slot {s}: block-table tail past its {len(pages)} "
                     "pages is not zeroed")
            if self.seq_lens[s] > len(pages) * self.page_size:
                fail(f"slot {s}: committed length {int(self.seq_lens[s])} "
                     f"exceeds its lease of {len(pages)} pages "
                     f"({len(pages) * self.page_size} tokens)")
            if len(self._slot_digests[s]) > len(pages):
                fail(f"slot {s}: {len(self._slot_digests[s])} chain digests "
                     f"for {len(pages)} pages")
            if self.kv_bits < 16:
                fr = int(self._quant_frontier[s])
                want = int(self.seq_lens[s]) // self.page_size
                if fr < want:
                    fail(f"slot {s}: committed pages left unquantized — "
                         f"quant frontier {fr} behind "
                         f"{want} committed full pages (kv_bits="
                         f"{self.kv_bits})")
                if fr > want:
                    fail(f"slot {s}: quant frontier {fr} ahead of "
                         f"{want} committed full pages — pages marked "
                         "quantized that were never committed")
                if fr > len(pages):
                    fail(f"slot {s}: quant frontier {fr} exceeds its lease "
                         f"of {len(pages)} pages")
        mism = [p for p in range(self.num_pages)
                if int(self.ref_counts[p]) != int(expected[p])]
        if mism:
            p = mism[0]
            fail(f"page {p}: refcount {int(self.ref_counts[p])} but "
                 f"{int(expected[p])} live references reconstruct "
                 f"({len(mism)} pages disagree)")
        # free list: unique, refcount 0, unregistered, not scratch
        free = set(self._free)
        if len(free) != len(self._free):
            fail("duplicate pages on the free list")
        if 0 in free:
            fail("scratch page 0 is on the free list")
        for p in self._free:
            if self.ref_counts[p] != 0:
                fail(f"free page {p} has refcount {int(self.ref_counts[p])}")
            if p in self._page_digest:
                fail(f"free page {p} is still registered in the prefix "
                     "registry")
        # registry <-> page digest bijection; LRU subset of registered@0
        if len(self._registry) != len(self._page_digest):
            fail(f"registry has {len(self._registry)} digests but "
                 f"{len(self._page_digest)} pages carry one")
        for digest, p in self._registry.items():
            if self._page_digest.get(p) != digest:
                fail(f"registry maps digest->page {p} but page maps back "
                     f"to a different digest")
        for p in self._lru:
            if p not in self._page_digest:
                fail(f"LRU page {p} is not registered")
            if self.ref_counts[p] != 0:
                fail(f"LRU page {p} has refcount {int(self.ref_counts[p])}")
            if p in free:
                fail(f"page {p} is on both the LRU and the free list")
        # conservation: every usable page in exactly one of the three states
        referenced = {p for p in range(1, self.num_pages)
                      if self.ref_counts[p] > 0}
        lru = set(self._lru)
        if free & referenced:
            fail(f"pages both free and referenced: {sorted(free & referenced)}")
        n_accounted = len(free) + len(lru) + len(referenced)
        if n_accounted != self.num_pages - 1:
            missing = (set(range(1, self.num_pages))
                       - free - lru - referenced)
            fail(f"page conservation violated: {len(free)} free + "
                 f"{len(lru)} cached + {len(referenced)} referenced = "
                 f"{n_accounted}, expected {self.num_pages - 1} "
                 f"(leaked: {sorted(missing)})")
        if expect_idle:
            if live:
                fail(f"expected idle pool but slots {live} are live")
            if referenced:
                fail(f"expected idle pool but pages {sorted(referenced)} "
                     "are still referenced (leak)")
