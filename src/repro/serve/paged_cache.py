"""Paged KV-cache: a global page pool + per-sequence block tables.

The data plane (the pools themselves) is a device pytree written inside the
jitted serving step (models.Model.paged_step); this class is the *control*
plane: a host-side allocator that hands out fixed-size pages from a free
list, maintains the block table and length of every sequence slot, and
reference-counts pages so forked sequences share their common prefix
(copy-on-write only for the final partial page, which is the only page that
can still be written).

Page 0 is reserved as a scratch page: padding rows of the packed batch
scatter their (garbage) K/V there, so the jitted step needs no masking
branches. The allocator never hands page 0 to a sequence.

Tensor parallelism (DESIGN.md Sec. 10) never touches this control plane:
page ids, block tables, lengths and refcounts are head-agnostic. Under a
TP mesh the engine re-homes ``pools`` with a head-sharded NamedSharding
(leaf dim 3, the KV-head dim, split over the model axis) and every device
holds the *same pages* for *its* heads — one block-table row addresses all
shards at once, and fork/preempt/commit work unchanged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class OutOfPages(Exception):
    """Raised when a reservation cannot be satisfied (caller preempts)."""


class PagedKVCache:
    """Host-side page allocator + device page pools.

    Contract: ``reserve`` is all-or-nothing (raises ``OutOfPages`` with no
    partial allocation), ``commit`` only ever records lengths the caller
    has actually written device-side, ``release`` returns a slot's pages in
    reverse order (LIFO reuse keeps prefixes warm), and ``fork`` shares
    full pages by refcount while copying only the final partial page.
    ``pools`` is an opaque device pytree owned by the jitted serving step;
    this class never reads it, only swaps it wholesale (fork's page copy,
    the engine's sharded re-homing).
    """

    def __init__(self, model, *, num_pages, page_size, max_seqs,
                 max_pages_per_seq=None):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved scratch)")
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)
        self.max_seqs = int(max_seqs)
        self.max_pages_per_seq = int(max_pages_per_seq or num_pages - 1)
        self.pools = model.init_paged_pools(num_pages, page_size)
        # host metadata
        self.block_tables = np.zeros((max_seqs, self.max_pages_per_seq),
                                     np.int32)
        self.seq_pages: List[List[int]] = [[] for _ in range(max_seqs)]
        self.seq_lens = np.zeros((max_seqs,), np.int32)
        self.ref_counts = np.zeros((num_pages,), np.int32)
        self.ref_counts[0] = 1                    # scratch page, never freed
        self._free = list(range(num_pages - 1, 0, -1))    # LIFO free list
        self._free_slots = list(range(max_seqs - 1, -1, -1))
        donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
        self._copy_page = jax.jit(self._copy_page_impl, donate_argnums=donate)

    # -- capacity ----------------------------------------------------------
    @property
    def n_free_pages(self):
        return len(self._free)

    @property
    def n_free_slots(self):
        return len(self._free_slots)

    def pages_for(self, n_tokens):
        return -(-int(n_tokens) // self.page_size)

    def fits(self, n_tokens):
        """Whole-sequence capacity check (used at submit/admission time)."""
        need = self.pages_for(n_tokens)
        return need <= self.max_pages_per_seq and need <= self.num_pages - 1

    # -- slots -------------------------------------------------------------
    def alloc_slot(self) -> Optional[int]:
        if not self._free_slots:
            return None
        slot = self._free_slots.pop()
        self.seq_pages[slot] = []
        self.seq_lens[slot] = 0
        self.block_tables[slot] = 0
        return slot

    def release(self, slot):
        """Free the slot: decref every page, returning dead pages to the
        free list (reverse order so LIFO reuse stays prefix-friendly)."""
        for page in reversed(self.seq_pages[slot]):
            self.ref_counts[page] -= 1
            assert self.ref_counts[page] >= 0
            if self.ref_counts[page] == 0:
                self._free.append(page)
        self.seq_pages[slot] = []
        self.seq_lens[slot] = 0
        self.block_tables[slot] = 0
        self._free_slots.append(slot)

    # -- pages -------------------------------------------------------------
    def reserve(self, slot, n_tokens):
        """Grow ``slot``'s block table to cover ``n_tokens``. All-or-nothing:
        raises OutOfPages without partial allocation if the pool is short."""
        need = self.pages_for(n_tokens) - len(self.seq_pages[slot])
        if need <= 0:
            return
        if self.pages_for(n_tokens) > self.max_pages_per_seq:
            raise OutOfPages(f"slot {slot}: {n_tokens} tokens exceed "
                             f"max_pages_per_seq={self.max_pages_per_seq}")
        if need > len(self._free):
            raise OutOfPages(f"slot {slot}: need {need} pages, "
                             f"{len(self._free)} free")
        for _ in range(need):
            page = self._free.pop()
            self.ref_counts[page] += 1
            self.block_tables[slot, len(self.seq_pages[slot])] = page
            self.seq_pages[slot].append(page)

    def commit(self, slot, n_tokens):
        """Record that ``n_tokens`` of ``slot`` are now written device-side."""
        assert self.pages_for(n_tokens) <= len(self.seq_pages[slot])
        self.seq_lens[slot] = n_tokens

    # -- prefix sharing ----------------------------------------------------
    def fork(self, src_slot) -> Optional[int]:
        """Fork ``src_slot``: full pages are shared by refcount; a partial
        final page is copied device-side (copy-on-write at fork time — full
        pages are never written again, so sharing them is safe)."""
        dst = self.alloc_slot()
        if dst is None:
            return None
        n = int(self.seq_lens[src_slot])
        n_full = n // self.page_size
        partial = n % self.page_size > 0
        if partial and not self._free:
            self.release(dst)
            return None
        try:
            for i, page in enumerate(self.seq_pages[src_slot][:n_full]):
                self.ref_counts[page] += 1
                self.block_tables[dst, i] = page
                self.seq_pages[dst].append(page)
            if partial:
                page = self._free.pop()
                self.ref_counts[page] += 1
                self.block_tables[dst, n_full] = page
                self.seq_pages[dst].append(page)
                src_page = self.seq_pages[src_slot][n_full]
                self.pools = self._copy_page(self.pools, src_page, page)
        except Exception:
            self.release(dst)
            raise
        self.seq_lens[dst] = n
        return dst

    @staticmethod
    def _copy_page_impl(pools, src, dst):
        def cp(leaf):
            # leaves: (n_periods, num_pages, page_size, KV, hd)
            return leaf.at[:, dst].set(leaf[:, src])
        return jax.tree_util.tree_map(cp, pools)

    # -- packed-batch views -------------------------------------------------
    def table_rows(self, slots):
        """Device block-table rows for the given slots, zero-padded to the
        packed batch size implied by ``len(slots)`` (-1 slots = pad rows)."""
        rows = np.zeros((len(slots), self.max_pages_per_seq), np.int32)
        for i, s in enumerate(slots):
            if s >= 0:
                rows[i] = self.block_tables[s]
        return jnp.asarray(rows)
