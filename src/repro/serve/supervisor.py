"""Engine supervision: crash containment, watchdog, token-identical replay.

DESIGN.md Sec. 14. PRs 1-6 built a serving stack whose entire data plane
hangs off one step loop; before this module, any exception or hung
dispatch in that loop stranded every in-flight client and leaked every
page lease. ``EngineSupervisor`` wraps ``ContinuousEngine`` with the same
submit/step/collect/stream_updates surface and adds the failure-domain
boundary the ROADMAP's north star requires:

  * **Crash containment.** An exception escaping ``engine.step()`` is
    caught, the incarnation is discarded wholesale (host metadata may be
    mid-mutation; nothing is trusted), and a fresh engine — new
    ``PagedKVCache`` pools, new scheduler, *same* params/mesh/config — is
    built from the factory.
  * **Token-identical replay.** Every unfinished request is re-admitted
    as ``prompt + tokens generated so far`` with the remaining budget.
    Greedy decode is deterministic and batch-composition-independent
    (the PR 1/2/3/5 identity invariants), so the continuation is
    token-identical to the uncrashed run — clients cannot tell a crash
    happened except by latency. Replayed requests sharing a prefix
    dedupe against each other through the (rebuilt) prefix cache during
    re-prefill.
  * **Watchdog.** Steps run on a per-incarnation worker thread; the
    supervisor waits with a deadline derived from a rolling median of
    clean step times (``train.fault.StragglerMonitor`` — the same
    statistic the training stack uses to flag stragglers). A step that
    blows the deadline is declared hung: the worker is abandoned (it can
    no longer touch shared state) and recovery proceeds as for a crash.
  * **Poison quarantine.** Each crash blames the requests in the work
    unit that was stepping (a prefill chunk blames one sequence; a
    packed prefill or decode blames the batch; a pre-schedule crash
    blames every running sequence). A request blamed
    ``max_crashes_per_request`` times is quarantined: it fails with
    ``PoisonedRequest`` (surfaced as a 500 naming the cause) instead of
    crashing the engine a fourth time — the cohort survives. A crash
    blamed on a *multi-segment packed prefill* additionally switches
    every later incarnation to one-segment prefill waves (crash
    isolation), so repeat blame — and therefore quarantine — lands on
    the poisoned request alone, never on innocent packing neighbors.
  * **Warmup.** ``warmup()`` AOT-compiles the incarnation's reachable
    trace set (``serve.warmup``), surfacing ``warming`` health /
    ``Warming`` (503 + Retry-After) while it runs; once requested, every
    rebuilt incarnation re-warms inside its recovery window.
  * **Drain.** ``drain()`` stops admissions (``Draining`` -> HTTP 503)
    while in-flight work runs to completion; ``drained`` flips when the
    engine is empty. Wired to SIGTERM via ``train.fault
    .PreemptionHandler`` in the server entry point.

Replay is deliberately *two-phase*: recovery (inside the crashed
``step()``) rebuilds the engine and computes the replay set; re-admission
happens at the *next* ``step()``. The window between the phases is where
an ``abort_request`` racing a rebuild lands — an aborted request is
dropped from the replay set, never resurrected (negative-tested).

The supervisor is single-threaded by contract, like the engine: every
mutation (submit/step/abort) must come from one thread (the server's
engine loop). ``would_accept`` and ``health`` are read-only and safe to
probe from other threads.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..train.fault import StragglerMonitor
from .scheduler import Saturated

__all__ = ["Draining", "EngineDied", "EngineSupervisor", "PoisonedRequest",
           "Recovering", "Warming", "WatchdogTimeout"]


class EngineDied(RuntimeError):
    """The engine is gone for good: the supervisor exhausted its restart
    budget (or there is no supervisor and the step loop crashed). Every
    in-flight request fails with this instead of hanging forever."""


class PoisonedRequest(RuntimeError):
    """This request was in the blamed work unit of
    ``max_crashes_per_request`` engine crashes and is quarantined: it
    fails (HTTP 500 naming the cause) so the rest of the cohort can make
    progress."""


class WatchdogTimeout(RuntimeError):
    """A step exceeded the watchdog deadline and was declared hung."""


class Draining(RuntimeError):
    """Admissions are closed: the server is draining toward shutdown.
    Maps to HTTP 503 (permanent for this replica — go elsewhere)."""


class Recovering(RuntimeError):
    """Admissions are briefly closed while a crash recovery rebuilds the
    engine and re-admits in-flight work. Maps to HTTP 503 + Retry-After
    (transient — retry this replica shortly)."""


class Warming(RuntimeError):
    """Admissions are closed while startup AOT warmup compiles the
    reachable trace set (``serve.warmup``). Maps to HTTP 503 +
    Retry-After, like ``Recovering`` — transient, retry shortly."""


# health states, in increasing order of trouble
OK, WARMING, DEGRADED, DRAINING, DEAD = (
    "ok", "warming", "degraded", "draining", "dead")


class _SupReq:
    """Supervisor-side record of one request: the replay source of truth.

    ``tokens`` accumulates every generated token across incarnations;
    after a crash the request is re-admitted as ``prompt + tokens`` with
    ``max_new_tokens - len(tokens)`` budget, which greedy determinism
    makes token-identical to the uncrashed continuation."""

    __slots__ = ("rid", "prompt", "max_new_tokens", "eos_id", "tokens",
                 "engine_rid", "crashes", "finished", "aborted", "error",
                 "reported_done", "stream_off", "priority", "deadline_at")

    def __init__(self, rid, prompt, max_new_tokens, eos_id,
                 priority="standard", deadline_at=None):
        self.rid = rid
        self.prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.max_new_tokens = int(max_new_tokens)
        self.eos_id = eos_id
        # replay passes the *absolute* deadline through: a crash must not
        # grant a request extra EDF slack
        self.priority = priority
        self.deadline_at = deadline_at
        self.tokens: List[int] = []
        self.engine_rid: Optional[int] = None   # id in the current engine
        self.crashes = 0                        # times blamed for a crash
        self.finished = False
        self.aborted = False
        self.error: Optional[Exception] = None
        self.reported_done = False
        self.stream_off = 0                     # tokens already streamed


class EngineSupervisor:
    """Owns a ``ContinuousEngine`` built by ``factory`` and mirrors its
    driving API (``submit``/``step``/``collect``/``stream_updates``/
    ``abort_request``/``would_accept``/``has_work``/``stats``), adding
    crash recovery, a hang watchdog, poison quarantine and drain.

    ``factory`` must be a zero-arg callable returning a fresh
    ``ContinuousEngine`` with identical config each call (same model,
    params, mesh, execution, horizon) — replay correctness rides on the
    rebuilt engine being greedy-token-identical to the crashed one.

    Watchdog: a step's deadline is ``max(watchdog_floor_s,
    watchdog_factor * rolling median)`` of clean step times (median via
    ``StragglerMonitor``; the factor only engages once >= 10 samples
    exist). The first ``warmup_steps`` steps of every incarnation use
    ``max(deadline, warmup_deadline_s)`` instead — a fresh incarnation
    may be JIT-compiling (mesh engines rebuild their shard_map closures),
    and compilation is indistinguishable from a hang. ``watchdog=False``
    runs steps inline on the calling thread (no hang detection, no extra
    thread) — crash containment and replay still apply.
    """

    def __init__(self, factory: Callable[[], object], *,
                 max_crashes_per_request: int = 3,
                 max_restarts: Optional[int] = None,
                 watchdog: bool = True,
                 watchdog_floor_s: float = 30.0,
                 watchdog_factor: float = 8.0,
                 watchdog_window: int = 50,
                 warmup_steps: int = 2,
                 warmup_deadline_s: float = 300.0,
                 degraded_window_s: float = 2.0):
        self._factory = factory
        self.max_crashes_per_request = int(max_crashes_per_request)
        self.max_restarts = max_restarts
        self.watchdog_enabled = bool(watchdog)
        self.watchdog_floor_s = float(watchdog_floor_s)
        self.watchdog_factor = float(watchdog_factor)
        self.warmup_steps = int(warmup_steps)
        self.warmup_deadline_s = float(warmup_deadline_s)
        self.degraded_window_s = float(degraded_window_s)
        self._monitor = StragglerMonitor(window=watchdog_window,
                                         threshold=watchdog_factor)
        self.engine = factory()
        self._worker = (_StepWorker(self.engine)
                        if self.watchdog_enabled else None)
        self._steps_this_incarnation = 0
        self._next_rid = 0
        self._reqs: Dict[int, _SupReq] = {}     # all live supervised reqs
        self._by_engine: Dict[int, int] = {}    # engine rid -> sup rid
        self._pending_replay: List[int] = []    # phase-B re-admissions
        self._failures: Dict[int, Exception] = {}
        self._finished_out: Dict[int, np.ndarray] = {}
        self._recovering = False
        self._warming = False       # startup/rebuild AOT warmup in flight
        self._warmed = False        # warmup() was requested: re-warm rebuilds
        self._isolate_prefill = False   # crash blamed on a packed prefill
        self._dead: Optional[Exception] = None
        self.draining = False
        self._degraded_until = 0.0
        # monotonic counters accumulated across incarnations (an engine's
        # own counters reset when it is rebuilt; metrics must not regress)
        self._base = {k: 0 for k in _ENGINE_COUNTERS}
        self._base_dicts: Dict[str, Dict[str, int]] = {
            k: {} for k in _ENGINE_DICT_COUNTERS}
        self._aborts_extra = 0      # aborts of pending-replay requests
        self._overload = None       # OverloadController, if attached
        self.n_restarts = 0
        self.n_watchdog_trips = 0
        self.n_replayed_tokens = 0
        self.n_quarantined = 0
        self.recovery_log: List[float] = []     # seconds per recovery
        self.last_crash: Optional[Exception] = None

    # -- admission ----------------------------------------------------------
    @property
    def health(self) -> str:
        if self._dead is not None:
            return DEAD
        if self.draining:
            return DRAINING
        if self._warming:
            return WARMING
        if (self._recovering or self._pending_replay
                or time.monotonic() < self._degraded_until):
            return DEGRADED
        return OK

    def would_accept(self, prompt_len, max_new_tokens,
                     priority="standard"):
        """Read-only admission probe, safe off-thread. Beyond the engine's
        own answers (``None`` / ``ValueError`` / ``Saturated``) this adds
        ``Draining`` (shutting down, 503) and ``Recovering`` (mid-rebuild
        window, 503 + Retry-After) and ``EngineDied``."""
        if self._dead is not None:
            return EngineDied(f"engine supervisor gave up after "
                              f"{self.n_restarts} restarts: "
                              f"{self._dead}")
        if self.draining:
            return Draining("server is draining; not accepting work")
        if self._warming:
            return Warming("engine is warming up (compiling the trace "
                           "set); retry shortly")
        if self._recovering or self._pending_replay:
            return Recovering("engine is recovering from a crash; "
                              "retry shortly")
        return self.engine.would_accept(prompt_len, max_new_tokens,
                                        priority=priority)

    def submit(self, prompt, max_new_tokens, eos_id=None,
               priority="standard", deadline_ms=None,
               deadline_at=None) -> int:
        """Mirror of ``ContinuousEngine.submit`` with a supervisor-owned
        request id (stable across engine rebuilds). A relative
        ``deadline_ms`` is resolved to an absolute ``deadline_at`` here,
        once — replay after a crash passes the absolute value through, so
        recovery never extends a request's EDF slack."""
        err = self._gate()
        if err is not None:
            raise err
        if deadline_at is None and deadline_ms is not None:
            deadline_at = time.monotonic() + float(deadline_ms) / 1000.0
        sr = _SupReq(self._next_rid, prompt, max_new_tokens, eos_id,
                     priority=priority, deadline_at=deadline_at)
        # engine submit first: if it rejects (Saturated/ValueError) the
        # supervisor records nothing
        erid = self.engine.submit(sr.prompt, sr.max_new_tokens,
                                  eos_id=eos_id, priority=priority,
                                  deadline_at=deadline_at)
        self._next_rid += 1
        sr.engine_rid = erid
        self._reqs[sr.rid] = sr
        self._by_engine[erid] = sr.rid
        return sr.rid

    def _gate(self) -> Optional[Exception]:
        if self._dead is not None:
            return EngineDied(f"engine supervisor gave up after "
                              f"{self.n_restarts} restarts: {self._dead}")
        if self.draining:
            return Draining("server is draining; not accepting work")
        if self._warming:
            return Warming("engine is warming up (compiling the trace "
                           "set); retry shortly")
        if self._recovering or self._pending_replay:
            return Recovering("engine is recovering from a crash; "
                              "retry shortly")
        return None

    def attach_overload(self, controller):
        """Register the overload controller so rebuilt incarnations
        inherit the current brownout level: the controller holds the
        level, engine incarnations only hold its consequences
        (scheduler knobs), and ``_recover`` re-applies them before the
        fresh engine dispatches anything — no flapping across crashes.
        Called by ``OverloadController.__init__``."""
        self._overload = controller

    def warmup(self):
        """AOT-warm the current incarnation's reachable trace set
        (``ContinuousEngine.warmup``). While it runs, health reads
        ``warming`` and admissions get ``Warming`` (503 + Retry-After).
        Once requested, every rebuilt incarnation re-warms inside its
        recovery window, so steady-state serving stays trace-free across
        crashes too."""
        self._warming = True
        try:
            report = self.engine.warmup()
        finally:
            self._warming = False
        self._warmed = True
        return report

    # -- the supervised step -------------------------------------------------
    @property
    def has_work(self) -> bool:
        if self._dead is not None:
            return False
        return bool(self._pending_replay) or self.engine.scheduler.has_work

    def step(self) -> bool:
        """One supervised engine step. Contains crashes (rebuild + replay),
        trips the watchdog on hangs, and drains the engine's stream
        updates into the supervisor's records (the replay source)."""
        if self._dead is not None:
            return False
        if self._pending_replay:
            self._resubmit_replays()
        if not self.engine.scheduler.has_work:
            return False
        t0 = time.monotonic()
        if self._worker is not None:
            outcome = self._worker.step(self._deadline())
            if outcome is None:                       # hung: abandon worker
                self.n_watchdog_trips += 1
                self._worker.abandon()
                self._recover(WatchdogTimeout(
                    f"engine step exceeded watchdog deadline "
                    f"{self._deadline():.3f}s (median "
                    f"{self._monitor.median:.3f}s over "
                    f"{len(self._monitor.times)} steps)"))
                return True
            status, value = outcome
        else:
            try:
                status, value = "ok", self.engine.step()
            except Exception as e:                     # noqa: BLE001
                status, value = "err", e
        if status == "err":
            self._recover(value)
            return True
        dt = time.monotonic() - t0
        self._steps_this_incarnation += 1
        self._monitor.record(self._base["steps"] + self.engine.n_steps, dt)
        self._drain_engine()
        return bool(value)

    def _deadline(self) -> float:
        d = self.watchdog_floor_s
        if len(self._monitor.times) >= 10:
            d = max(d, self.watchdog_factor * self._monitor.median)
        if self._steps_this_incarnation < self.warmup_steps:
            d = max(d, self.warmup_deadline_s)         # JIT compile amnesty
        return d

    def _drain_engine(self):
        """Pull the engine's per-step stream updates into the supervisor
        records immediately — the narrower this window, the fewer tokens a
        crash forces replay to regenerate."""
        for erid, (new, done) in self.engine.stream_updates().items():
            rid = self._by_engine.get(erid)
            if rid is None:
                continue                    # aborted between step and drain
            sr = self._reqs[rid]
            sr.tokens.extend(new)
            if done:
                sr.finished = True
                del self._by_engine[erid]
                sr.engine_rid = None
                self._finished_out[rid] = np.asarray(sr.tokens, np.int32)

    # -- recovery ------------------------------------------------------------
    def _recover(self, cause: Exception):
        """Phase A of recovery, inside the crashed step: blame, quarantine,
        accumulate counters, rebuild the engine. Replay (phase B) happens
        at the next ``step()`` so a racing ``abort_request`` can still
        drop a request from the replay set."""
        t0 = time.monotonic()
        self._recovering = True
        try:
            self.last_crash = cause
            self._blame(cause)
            self.n_restarts += 1
            if (self.max_restarts is not None
                    and self.n_restarts > self.max_restarts):
                self._die(cause)
                return
            self._accumulate(self.engine)
            if self._worker is not None:
                self._worker.abandon()
            old_reqs = [rid for rid, sr in self._reqs.items()
                        if not sr.finished and sr.error is None
                        and not sr.aborted]
            self.engine = self._factory()
            if self._isolate_prefill:
                # a previous crash was blamed on a multi-segment packed
                # prefill: pack one segment per wave from here on so blame
                # (and poison quarantine) is per-request precise
                self.engine.scheduler.isolate_prefill = True
            if self._overload is not None:
                # the rebuilt incarnation inherits the brownout level the
                # controller holds — recovery under overload must not
                # briefly serve at level 0 (flap) before the next tick
                self._overload.apply_to(self.engine)
            if self._warmed:
                self._warming = True
                try:
                    self.engine.warmup()    # re-warm: per-incarnation traces
                finally:
                    self._warming = False
            if self.watchdog_enabled:
                self._worker = _StepWorker(self.engine)
            self._steps_this_incarnation = 0
            self._by_engine = {}
            for rid in old_reqs:
                self._reqs[rid].engine_rid = None
            self._pending_replay = old_reqs        # original submit order
        finally:
            self._recovering = False
            self.recovery_log.append(time.monotonic() - t0)
            self._degraded_until = time.monotonic() + self.degraded_window_s

    def _blame(self, cause: Exception):
        """Attribute the crash and quarantine over-budget requests. The
        engine's ``last_step_rids`` names the work unit that was stepping
        (one sequence for a prefill chunk, the batch for a decode); a
        crash before scheduling — or a hang, where the worker's state is
        not trusted — blames every running sequence (best-effort)."""
        blamed = tuple(getattr(self.engine, "last_step_rids", ()) or ())
        if (len(blamed) > 1 and not isinstance(cause, WatchdogTimeout)
                and getattr(self.engine, "last_step_kind", "")
                == "prefill_packed"):
            self._isolate_prefill = True    # applied to rebuilt incarnations
        if not blamed or isinstance(cause, WatchdogTimeout):
            blamed = tuple(s.req.req_id
                           for s in self.engine.scheduler.running)
        for erid in blamed:
            rid = self._by_engine.get(erid)
            if rid is None:
                continue
            sr = self._reqs[rid]
            sr.crashes += 1
            if sr.crashes >= self.max_crashes_per_request:
                sr.error = PoisonedRequest(
                    f"request quarantined after being blamed for "
                    f"{sr.crashes} engine crashes (budget "
                    f"{self.max_crashes_per_request}); last cause: "
                    f"{type(cause).__name__}: {cause}")
                self._failures[rid] = sr.error
                self.n_quarantined += 1

    def _die(self, cause: Exception):
        self._dead = cause
        for rid, sr in self._reqs.items():
            if not sr.finished and sr.error is None and not sr.aborted:
                sr.error = EngineDied(
                    f"engine supervisor gave up after {self.n_restarts} "
                    f"restarts; last cause: {type(cause).__name__}: "
                    f"{cause}")
                self._failures[rid] = sr.error
        self._pending_replay = []

    def _resubmit_replays(self):
        """Phase B: re-admit the replay set as ``prompt + generated`` with
        the remaining budget. A replay refused by backpressure stays
        pending and is retried next step (it was admitted once; shedding
        it now would drop accepted work)."""
        still_pending: List[int] = []
        for rid in self._pending_replay:
            sr = self._reqs.get(rid)
            if sr is None or sr.aborted or sr.finished \
                    or sr.error is not None:
                continue                    # raced abort/quarantine: drop
            prompt = np.concatenate(
                [sr.prompt, np.asarray(sr.tokens, np.int32)])
            remaining = sr.max_new_tokens - len(sr.tokens)
            if remaining <= 0 or (sr.eos_id is not None and sr.tokens
                                  and sr.tokens[-1] == sr.eos_id):
                sr.finished = True          # crashed after its last token
                self._finished_out[rid] = np.asarray(sr.tokens, np.int32)
                continue
            try:
                erid = self.engine.submit(prompt, remaining,
                                          eos_id=sr.eos_id,
                                          priority=sr.priority,
                                          deadline_at=sr.deadline_at)
            except Saturated:
                still_pending.append(rid)
                continue
            except ValueError as e:         # factory config shrank the pool
                sr.error = e
                self._failures[rid] = e
                continue
            sr.engine_rid = erid
            self._by_engine[erid] = rid
            self.n_replayed_tokens += len(sr.tokens)
        self._pending_replay = still_pending

    # -- request surface -----------------------------------------------------
    def abort_request(self, rid) -> bool:
        """Mirror of ``ContinuousEngine.abort_request`` that is also
        correct *during a recovery rebuild*: a request still waiting in
        the replay set is dropped from it (never resurrected). Raises
        ``KeyError`` for unknown ids; returns False when the request had
        already finished or failed (its result is dropped)."""
        sr = self._reqs.get(rid)
        if sr is None:
            raise KeyError(f"unknown request id {rid}")
        if sr.finished or sr.error is not None:
            del self._reqs[rid]
            self._finished_out.pop(rid, None)
            self._failures.pop(rid, None)
            return False
        sr.aborted = True
        del self._reqs[rid]
        if sr.engine_rid is not None:
            erid = sr.engine_rid
            self._by_engine.pop(erid, None)
            try:
                return self.engine.abort_request(erid)
            except KeyError:
                return False
        # pending replay (or mid-rebuild): nothing engine-side to free
        self._aborts_extra += 1
        return True

    def stream_updates(self) -> Dict[int, Tuple[List[int], bool]]:
        """Per-token streaming drain in supervisor ids; same exactly-once,
        in-order contract as the engine's. Quarantined/died requests are
        *not* reported here — drain them via ``pop_failures()``."""
        out: Dict[int, Tuple[List[int], bool]] = {}
        for rid in list(self._reqs):
            sr = self._reqs[rid]
            if sr.error is not None:
                continue
            new = sr.tokens[sr.stream_off:]
            if new or sr.finished:
                out[rid] = (list(new), sr.finished)
                sr.stream_off = len(sr.tokens)
            if sr.finished:
                sr.reported_done = True
                del self._reqs[rid]
                self._finished_out.pop(rid, None)
        return out

    def collect(self) -> Dict[int, np.ndarray]:
        """Drain finished outputs (full generated-token arrays, spanning
        every incarnation the request lived through)."""
        out, self._finished_out = self._finished_out, {}
        for rid in out:
            self._reqs.pop(rid, None)
        return out

    def pop_failures(self) -> Dict[int, Exception]:
        """Drain requests that *failed* (quarantined poison requests,
        engine death). Each failure is reported exactly once; the server
        loop maps these to 500s naming the cause."""
        out, self._failures = self._failures, {}
        for rid in out:
            self._reqs.pop(rid, None)
        return out

    def run(self) -> Dict[int, np.ndarray]:
        """Drive until all submitted work completes or fails."""
        done: Dict[int, np.ndarray] = {}
        while self.has_work:
            if not self.step():
                break
            done.update(self.collect())
        done.update(self.collect())
        return done

    # -- drain / teardown ----------------------------------------------------
    def drain(self):
        """Stop admissions; in-flight work keeps stepping to completion."""
        self.draining = True

    @property
    def drained(self) -> bool:
        return (self.draining and not self._pending_replay
                and not self.engine.scheduler.has_work)

    def close(self, check: bool = True):
        """Tear down: stop the step worker and (optionally) verify the
        current incarnation's allocator invariants — after a drain the
        pool must be back at baseline with zero leaked pages."""
        if self._worker is not None:
            self._worker.abandon()
            self._worker = None
        if check and self._dead is None:
            self.engine.close(check=True)

    # -- metrics -------------------------------------------------------------
    def _accumulate(self, engine):
        st = engine.stats()
        for k in _ENGINE_COUNTERS:
            self._base[k] += st[k]
        for k in _ENGINE_DICT_COUNTERS:
            base = self._base_dicts[k]
            for c, v in st.get(k, {}).items():
                base[c] = base.get(c, 0) + v

    def stats(self) -> Dict[str, object]:
        """Aggregated monotonic counters across every incarnation, plus
        the supervision counters. Same schema as ``ContinuousEngine
        .stats()`` with the supervisor extras — ``ServeMetrics
        .sync_engine`` consumes either."""
        st = self.engine.stats()
        out = {k: self._base[k] + st[k] for k in _ENGINE_COUNTERS}
        for k in _ENGINE_DICT_COUNTERS:
            merged = dict(self._base_dicts[k])
            for c, v in st.get(k, {}).items():
                merged[c] = merged.get(c, 0) + v
            out[k] = merged
        out["aborts"] += self._aborts_extra
        out["queue_depth"] = st["queue_depth"] + len(self._pending_replay)
        out["running"] = st["running"]
        out.update(
            restarts=self.n_restarts,
            watchdog_trips=self.n_watchdog_trips,
            replayed_tokens=self.n_replayed_tokens,
            quarantined=self.n_quarantined,
            health=self.health,
            recovery_log=list(self.recovery_log),
        )
        return out

    def drain_observations(self):
        """Passthrough of ``ContinuousEngine.drain_observations`` (bounded
        per-event samples for metrics histograms). Samples buffered inside
        a crashed incarnation are lost with it — they are observability,
        not accounting."""
        return self.engine.drain_observations()

    # passthrough conveniences for tests / benches
    @property
    def scheduler(self):
        return self.engine.scheduler

    @property
    def cache(self):
        return self.engine.cache


# the monotonic keys shared by ContinuousEngine.stats() and the
# supervisor's cross-incarnation accumulator
_ENGINE_COUNTERS = ("tokens_out", "steps", "decode_steps", "host_syncs",
                    "work_positions", "aborts", "preemptions", "admissions",
                    "prefix_hits", "prefix_positions_saved", "forks",
                    "prefill_dispatches", "prefill_segments",
                    "admission_waves", "warmup_seconds", "warmup_traces")

# per-priority-class dict counters (overload control plane), folded across
# incarnations the same way the scalar counters are
_ENGINE_DICT_COUNTERS = ("preemptions_by_class", "admissions_by_class",
                         "sheds_by_class")


class _StepWorker:
    """One engine incarnation's step executor. The supervisor thread asks
    for a step and waits with a deadline; on timeout the worker is
    *abandoned* — it may still be stuck inside the hung dispatch, but it
    holds only the discarded engine, so it can never touch the
    replacement. An abandoned worker exits as soon as the hung call
    returns (or immediately, if it was idle)."""

    def __init__(self, engine):
        import threading
        self.engine = engine
        self._go = threading.Event()
        self._done = threading.Event()
        self._quit = False
        self.result: Optional[Tuple[str, object]] = None
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="msb-step-worker")
        self._thread.start()

    def _run(self):
        while True:
            self._go.wait()
            self._go.clear()
            if self._quit:
                return
            try:
                self.result = ("ok", self.engine.step())
            except Exception as e:                     # noqa: BLE001
                self.result = ("err", e)
            self._done.set()
            if self._quit:
                return

    def step(self, timeout: float):
        """Run one engine.step() with a deadline; None = timed out."""
        self.result = None
        self._done.clear()
        self._go.set()
        if not self._done.wait(timeout):
            return None
        return self.result

    def abandon(self):
        self._quit = True
        self._go.set()
