"""AOT warmup: compile every reachable dispatch shape at load time.

The engine's data plane is a finite set of jit traces, fully determined by
its config (DESIGN.md Sec. 16):

  * packed prefill — one trace per bucket in ``prefill_buckets`` (or the
    single legacy ``(1, prefill_chunk)`` chunk trace when packing is off)
  * decode — one trace per power-of-two batch bucket up to ``max_batch``,
    through the fused horizon scan when ``decode_horizon > 1`` and the
    plain step otherwise

``enumerate_traces`` lists that set; ``warm_engine`` executes one **all-pad
dummy dispatch** per entry through the engine's real jitted callables.
Dummy inputs carry ``q_pos = -1`` / ``seg_ids = -1`` / ``slots = -1`` /
``kv_lens = 0`` with the scratch block table, so every KV write lands on
the reserved scratch page (and hot row 0 on the quantized pools) and the
dispatch is semantically a no-op — but it populates the *call-site* jit
cache, which an offline ``lower().compile()`` would not, and it exercises
the exact aval set steady-state serving uses. Pools are reassigned from
the returned tree so donation on TPU/GPU stays correct.

After ``warm_engine`` returns, a serving run that stays inside the
config's shape envelope performs zero new traces — the property the
trace-count probe (``continuous.jit_trace_count``) lets tests and the
``msb_traces_compiled_total`` metric assert.

The overload brownout ladder (``serve.overload``, DESIGN.md Sec. 17)
preserves this: its horizon cap is a *dynamic* clamp on the per-sequence
token budget (the static horizon-scan trace is untouched) and its wave
cap only shrinks the segment count, which selects a smaller —
already-warmed — covering bucket. Level changes therefore never add to
the reachable trace set enumerated here.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np


def enumerate_traces(engine) -> List[Tuple[str, int]]:
    """The reachable (kind, size) dispatch set for ``engine``'s config.

    Kinds: ``prefill_packed`` (size = bucket token length), ``prefill``
    (size = prefill_chunk; only reachable with packing off), ``decode`` /
    ``decode_horizon`` (size = batch bucket).
    """
    entries: List[Tuple[str, int]] = []
    if engine.prefill_buckets:
        entries += [("prefill_packed", b) for b in engine.prefill_buckets]
    else:
        entries.append(("prefill", engine.prefill_chunk))
    kind = "decode_horizon" if engine.decode_horizon > 1 else "decode"
    b = 1
    while True:
        entries.append((kind, b))
        if b >= engine.max_batch:
            break
        b *= 2
    return entries


def _warm_one(engine, kind: str, size: int):
    """One all-pad dummy dispatch of the given shape; blocks on the result
    so compile time is paid here, not on the first request."""
    cache = engine.cache
    if kind == "prefill_packed":
        s = engine.max_batch
        bt = cache.table_rows([-1] * s)
        out, cache.pools = engine._prefill_fn(
            cache.pools, engine.params,
            jnp.zeros((size,), jnp.int32), jnp.full((size,), -1, jnp.int32),
            jnp.full((size,), -1, jnp.int32), jnp.zeros((s,), jnp.int32),
            bt, jnp.full((s,), -1, jnp.int32), jnp.zeros((s,), jnp.int32),
            jnp.zeros((s,), jnp.int32))
    elif kind == "prefill":
        bt = cache.table_rows([-1])
        out, cache.pools = engine._step_fn(
            cache.pools, engine.params, jnp.zeros((1, size), jnp.int32),
            jnp.full((1, size), -1, jnp.int32), jnp.zeros((1,), jnp.int32),
            bt, jnp.full((1,), -1, jnp.int32))
    elif kind == "decode":
        bt = cache.table_rows([-1] * size)
        out, cache.pools = engine._step_fn(
            cache.pools, engine.params, jnp.zeros((size, 1), jnp.int32),
            jnp.full((size, 1), -1, jnp.int32), jnp.zeros((size,), jnp.int32),
            bt, jnp.full((size,), -1, jnp.int32))
    elif kind == "decode_horizon":
        bt = cache.table_rows([-1] * size)
        out, _valid, cache.pools = engine._horizon_fn(
            cache.pools, engine.params, jnp.zeros((size,), jnp.int32),
            jnp.full((size,), -1, jnp.int32), jnp.zeros((size,), jnp.int32),
            jnp.full((size,), -1, jnp.int32), bt,
            jnp.full((size,), -1, jnp.int32))
    else:
        raise ValueError(f"unknown warmup kind {kind!r}")
    np.asarray(out)                  # block until the dispatch retires


def warm_engine(engine) -> Dict[str, object]:
    """Warm every reachable trace of ``engine``; returns a report dict:
    ``seconds`` (wall time), ``entries`` (shapes warmed), ``traces`` (probe
    delta — 0 when a sibling engine already compiled the shared module-jit
    set), ``shapes`` (the enumerated list)."""
    from .continuous import jit_trace_count
    t0 = time.monotonic()
    n0 = jit_trace_count()
    entries = enumerate_traces(engine)
    for kind, size in entries:
        _warm_one(engine, kind, size)
    return {"seconds": time.monotonic() - t0, "entries": len(entries),
            "traces": jit_trace_count() - n0, "shapes": entries}
