"""Continuous-batching scheduler: admission, chunked prefill, preemption.

The scheduler owns policy, not execution: each ``schedule()`` call returns
one unit of work — a prefill chunk for one sequence or a batched decode over
every decoding sequence — and the engine runs it. Shapes stay static (one
jit trace per work kind) because prefill chunks are a fixed size and decode
batches are padded to ``max_batch``.

Policy choices (deliberately simple and deterministic; see DESIGN.md §8):
  * FIFO admission, gated on a whole-sequence capacity check against the
    page pool (prompt + max_new_tokens must fit) so a lone sequence can
    never deadlock the pool.
  * Prefill/decode interleaving alternates when both kinds of work exist,
    so a stream of long prompts cannot starve running decodes (and vice
    versa).
  * Horizon-aware decode leases: with ``decode_horizon=H`` the decode
    reservation covers ``n_total - 1 + min(H, remaining budget)`` positions
    up front, so one fused device dispatch can sample up to H tokens —
    crossing page boundaries mid-horizon — without coming back to the host
    (DESIGN.md Sec. 12). A lease is just a reservation: pages left
    unwritten when a row stops early stay reserved until the sequence
    finishes or is preempted, and release() returns them either way.
  * Preemption by recompute: when decode needs a page and the pool is dry,
    the youngest running sequence is evicted — its pages are freed and it
    re-enters the waiting queue (front) with its generated-so-far tokens
    appended to the prompt, so greedy output is unchanged. With the prefix
    cache on, ``reserve`` reclaims LRU-cached (unreferenced) prefix pages
    before ever reporting the pool dry, so cached pages are always spent
    before a live sequence is preempted — and a preempted sequence usually
    re-admits straight onto its own still-cached pages.
  * Automatic prefix caching (DESIGN.md Sec. 11): admission longest-prefix
    matches the page registry and adopts hits by refcount bump, so a
    request sharing a 2k-token system prompt with an earlier one skips
    that prefill entirely.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .paged_cache import OutOfPages, PagedKVCache

PREFILL, DECODE, FINISHED = "prefill", "decode", "finished"


class Saturated(RuntimeError):
    """A submit was refused for *transient* load reasons (waiting queue
    full, page pool oversubscribed) — distinct from the permanent
    ``ValueError`` a request that can *never* fit gets. Callers should shed
    load (HTTP 429 + Retry-After) and may retry the identical request
    later. Only raised when backpressure is enabled (``max_waiting=``)."""


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None


class Sequence:
    """Scheduler-internal state for one request."""

    def __init__(self, request: Request):
        self.req = request
        self.slot = -1
        self.generated: List[int] = []
        self.cache_len = 0        # tokens written to the KV pool
        self.state = PREFILL
        self.n_preempted = 0
        self._prefix_match = None   # (registry_epoch, match) memo
        self._tokens_memo = None    # (len(generated), array) memo

    @property
    def tokens(self) -> np.ndarray:
        """Prompt + everything sampled so far (the re-prefill source after a
        preemption; the last sampled token is not yet in the cache).

        Memoized on ``len(generated)`` — ``generated`` is append-only, so
        length identifies content — because every ``schedule()`` call,
        prefill chunk, and prefix registration reads this, and rebuilding
        the concatenation is O(sequence length) per access. The memo is
        returned read-only since callers share it (fork already copies)."""
        memo = self._tokens_memo
        if memo is not None and memo[0] == len(self.generated):
            return memo[1]
        toks = np.concatenate(
            [self.req.prompt,
             np.asarray(self.generated, np.int32)]).astype(np.int32)
        toks.setflags(write=False)
        self._tokens_memo = (len(self.generated), toks)
        return toks

    @property
    def n_total(self):
        return len(self.req.prompt) + len(self.generated)

    def is_done(self):
        if len(self.generated) >= self.req.max_new_tokens:
            return True
        return (self.req.eos_id is not None and self.generated
                and self.generated[-1] == self.req.eos_id)


class Scheduler:
    def __init__(self, cache: PagedKVCache, max_batch: int,
                 prefill_chunk: int, decode_horizon: int = 1,
                 max_waiting: Optional[int] = None,
                 oversubscribe: float = 2.0,
                 prefill_buckets: Optional[Tuple[int, ...]] = None):
        self.cache = cache
        self.max_batch = max_batch
        self.prefill_chunk = prefill_chunk
        self.decode_horizon = int(decode_horizon)
        # packed ragged prefill (DESIGN.md Sec. 16): when a bucket set is
        # given, schedule() bins every waiting PREFILL sequence's next chunk
        # into ONE dispatch padded to the smallest covering bucket; None
        # keeps the classic one-chunk-per-sequence path
        self.prefill_buckets = (tuple(sorted(int(b) for b in prefill_buckets))
                                if prefill_buckets else None)
        self.isolate_prefill = False    # one segment per wave when True
        # backpressure (None = unbounded queueing, the pre-server behavior):
        # max_waiting bounds the waiting queue; oversubscribe bounds the
        # outstanding page demand of admitted-but-unfinished work to a
        # multiple of the pool, so a burst of feasible-but-huge requests is
        # shed instead of queued behind a full pool-drain of work
        self.max_waiting = max_waiting if max_waiting is None \
            else int(max_waiting)
        self.oversubscribe = float(oversubscribe)
        self.waiting: Deque[Sequence] = deque()
        self.running: List[Sequence] = []
        self._last_was_prefill = False
        self.n_preemptions = 0
        self.n_admissions = 0         # waiting -> running transitions
        self.n_admission_waves = 0    # _admit() calls that admitted >= 1 seq
        self.n_aborts = 0             # requests cancelled before finishing
        self.n_prefix_hits = 0        # admissions that matched the registry
        self.n_prefix_tokens = 0      # positions adopted instead of prefilled
        # one queue-depth sample per admission wave (NOT per prefill chunk:
        # a long prompt's chunks would otherwise re-report the same depth
        # dozens of times and skew the distribution); drained by the engine
        self.queue_depth_obs: List[int] = []

    # -- queue entry points -------------------------------------------------
    def would_accept(self, n_tokens: int) -> Optional[Exception]:
        """Cheap, mutation-free admission probe for ``n_tokens`` (prompt +
        max_new_tokens). Returns ``None`` when a ``submit`` issued right now
        would be accepted, otherwise the exception instance a submit would
        raise: ``ValueError`` for permanent infeasibility (the request can
        never fit this pool) or ``Saturated`` for transient backpressure
        (retry later). A server front door calls this before mutating any
        state so a 429/400 costs no allocator work; ``submit`` re-checks,
        so the probe->submit race is benign."""
        why = self.cache.capacity_error(n_tokens)
        if why is not None:
            return ValueError(why)
        if self.max_waiting is None:
            return None                       # backpressure disabled
        if len(self.waiting) >= self.max_waiting:
            # queue full — but an empty queue + free slot + pool headroom
            # means immediate admission, which max_waiting=0 ("no queueing")
            # must still allow
            if not (not self.waiting and len(self.running) < self.max_batch
                    and self.cache.n_free_slots > 0
                    and self.cache.pages_for(n_tokens)
                    <= self.cache.n_available_pages):
                return Saturated(
                    f"waiting queue full ({len(self.waiting)} waiting, "
                    f"max_waiting={self.max_waiting})")
        demand = sum(
            self.cache.pages_for(len(s.req.prompt) + s.req.max_new_tokens)
            for s in self.running) + sum(
            self.cache.pages_for(len(s.req.prompt) + s.req.max_new_tokens)
            for s in self.waiting)
        usable = self.cache.num_pages - 1
        if demand + self.cache.pages_for(n_tokens) \
                > self.oversubscribe * usable:
            return Saturated(
                f"page pool saturated ({demand} pages of work outstanding "
                f"against {usable} usable pages, "
                f"oversubscribe={self.oversubscribe})")
        return None

    def submit(self, request: Request) -> Sequence:
        # the HTTP layer rejects these with a 400; direct callers get the
        # same contract here (there is no token to prefill, so the packed
        # planner would never assign the sequence a segment)
        if len(request.prompt) == 0:
            raise ValueError(f"request {request.req_id}: prompt must not "
                             "be empty (nothing to prefill)")
        total = len(request.prompt) + request.max_new_tokens
        err = self.would_accept(total)
        if err is not None:        # names the limit that actually rejected
            raise type(err)(f"request {request.req_id}: {err}")
        seq = Sequence(request)
        self.waiting.append(seq)
        return seq

    def abort(self, seq: Sequence) -> bool:
        """Cancel ``seq`` wherever it is in its lifecycle. A waiting
        sequence is dropped from the queue; a running one releases its slot
        — every page it holds (committed, leased-but-unwritten horizon
        pages, and adopted prefix pages alike) is decref'd by
        ``cache.release``, so registered pages park on the prefix-cache LRU
        (reclaimable, not leaked) and everything else returns to the free
        list. Returns False (no-op) when the sequence already finished."""
        if seq.state == FINISHED:
            return False
        if seq.slot >= 0:
            self.cache.release(seq.slot)
            seq.slot = -1
            self.running.remove(seq)
        else:
            try:
                self.waiting.remove(seq)
            except ValueError:
                pass                          # already gone
        seq.state = FINISHED
        self.n_aborts += 1
        return True

    @property
    def has_work(self):
        return bool(self.waiting or self.running)

    # -- internals ----------------------------------------------------------
    def _admit(self):
        """FIFO admission while slots, batch room, and pool headroom last.
        Headroom check is against the *whole* remaining sequence so an
        admitted sequence only ever blocks on pages another sequence can
        release (preemption handles that case); it counts LRU-cached prefix
        pages as available, since ``reserve`` reclaims them on demand.

        Each admitted sequence longest-prefix-matches the prefix registry
        first: matched pages are adopted by refcount bump and ``cache_len``
        starts at the matched boundary, so chunked prefill skips them. The
        match is capped at ``len(tokens) - 1`` — the last position must be
        prefilled for real so the sampler has logits to advance on."""
        depth_before = len(self.waiting)
        admitted_before = self.n_admissions
        while (self.waiting and len(self.running) < self.max_batch
               and self.cache.n_free_slots > 0):
            seq = self.waiting[0]
            toks = seq.tokens
            match = self._match_for(seq, toks)
            n_hit = len(match.pages) if match else 0
            need = self.cache.pages_for(
                len(toks) + seq.req.max_new_tokens
                - len(seq.generated)) - n_hit
            avail = self.cache.n_available_pages - (
                match.n_unreferenced if match else 0)
            if need > avail:
                break
            self.waiting.popleft()
            self.n_admissions += 1
            seq.slot = self.cache.alloc_slot()
            seq.cache_len = 0
            if match is not None:
                self.cache.adopt_prefix(seq.slot, match)
                seq.cache_len = match.n_tokens
                self.n_prefix_hits += 1
                self.n_prefix_tokens += match.n_tokens
            seq.state = PREFILL
            self.running.append(seq)
        if self.n_admissions > admitted_before:
            self.n_admission_waves += 1
            if len(self.queue_depth_obs) < 4096:   # bounded if undrained
                self.queue_depth_obs.append(depth_before)

    def _match_for(self, seq, toks):
        """match_prefix memoized per sequence on the registry epoch: a
        page-blocked head-of-queue request would otherwise re-hash its
        whole prompt on every schedule() call while it waits. The memo can
        only go stale in LRU-membership accounting (registration state is
        epoch-versioned), which admission treats as advisory anyway —
        reserve-time OutOfPages and preemption backstop it."""
        epoch = self.cache.registry_epoch
        if seq._prefix_match is not None and seq._prefix_match[0] == epoch:
            return seq._prefix_match[1]
        match = self.cache.match_prefix(toks, max_tokens=len(toks) - 1)
        seq._prefix_match = (epoch, match)
        return match

    def _preempt(self, victim):
        """Evict ``victim`` (recompute-on-resume): free its pages and push it
        to the front of the waiting queue with generated tokens preserved."""
        self.cache.release(victim.slot)
        victim.slot = -1
        victim.cache_len = 0
        victim.state = PREFILL
        victim.n_preempted += 1
        victim._prefix_match = None   # tokens grew since the last match
        self.running.remove(victim)
        self.waiting.appendleft(victim)
        self.n_preemptions += 1

    def _reserve_or_preempt(self, seq, n_tokens) -> bool:
        """Reserve pages for ``seq``, evicting youngest-first until it fits.
        ``seq`` itself is evicted if it is the youngest (never steal pages
        from an older sequence); returns False in that case."""
        while True:
            try:
                self.cache.reserve(seq.slot, n_tokens)
                return True
            except OutOfPages:
                victim = max(self.running, key=lambda s: s.req.req_id)
                self._preempt(victim)
                if victim is seq:
                    return False

    def _decode_lease(self, seq) -> int:
        """Token positions the next decode dispatch may write for ``seq``:
        a horizon dispatch samples up to ``min(decode_horizon, remaining
        budget)`` tokens, writing K/V for each input token starting at
        ``n_total - 1``, so the lease covers ``n_total - 1 + h`` positions.
        Reserving the whole lease up front is what lets the device cross
        page boundaries mid-horizon with no host intervention (the block
        table already addresses every reserved page). ``decode_horizon=1``
        degenerates to the classic one-position reserve (``n_total``)."""
        h = min(self.decode_horizon,
                seq.req.max_new_tokens - len(seq.generated))
        return seq.n_total - 1 + max(h, 1)

    def _try_decode(self):
        decodes = [s for s in self.running if s.state == DECODE]
        for seq in list(decodes):
            if seq in self.running:        # a peer's reserve may evict it
                self._reserve_or_preempt(seq, self._decode_lease(seq))
        decodes = [s for s in decodes if s in self.running]
        if not decodes:
            return None
        self._last_was_prefill = False
        return ("decode", decodes)

    def _try_prefill(self):
        prefills = [s for s in self.running if s.state == PREFILL]
        if not prefills:
            return None
        seq = prefills[0]
        toks = seq.tokens
        start = seq.cache_len
        chunk = min(self.prefill_chunk, len(toks) - start)
        if not self._reserve_or_preempt(seq, start + chunk):
            return None                    # self-preempted; decode instead
        self._last_was_prefill = True
        return ("prefill", seq, toks[start:start + chunk], start)

    def _try_prefill_packed(self):
        """Pack every runnable PREFILL sequence's next chunk into one
        dispatch (DESIGN.md Sec. 16): segments are assigned in running
        (FIFO-admission) order under a token budget of the largest bucket,
        each segment resuming at its own ``cache_len`` — so prefix-cache
        adoptions pack at the matched boundary and a prompt longer than the
        largest bucket simply continues across successive waves (chunking
        falls out, no special case). Returns
        ``("prefill_packed", [(seq, start, n), ...], bucket)`` with
        ``bucket`` the smallest bucket covering the assigned tokens.

        Reservation order is planning order; a reservation that preempts
        evicts the *youngest* running sequence, which may be a segment
        planned earlier in this very wave — the final membership filter
        drops any segment preemption took back (its reservation was
        released with its slot, so nothing leaks)."""
        if not any(s.state == PREFILL for s in self.running):
            return None
        budget = self.prefill_buckets[-1]
        # crash isolation (set by the supervisor after a crash blamed on a
        # multi-segment packed dispatch): pack one segment per wave so
        # blame — and poison quarantine — stays per-request precise
        max_segs = 1 if self.isolate_prefill else self.max_batch
        segs: List[Tuple[Sequence, int, int]] = []
        used = 0
        for seq in list(self.running):
            if seq.state != PREFILL or seq not in self.running:
                continue
            if len(segs) >= max_segs or used >= budget:
                break
            start = seq.cache_len
            n = min(budget - used, len(seq.tokens) - start)
            if n <= 0:
                continue
            if not self._reserve_or_preempt(seq, start + n):
                continue                   # self-preempted mid-wave
            segs.append((seq, start, n))
            used += n
        segs = [s for s in segs if s[0] in self.running]
        if not segs:
            return None
        used = sum(n for _, _, n in segs)
        bucket = next(b for b in self.prefill_buckets if b >= used)
        self._last_was_prefill = True
        return ("prefill_packed", segs, bucket)

    # -- the policy ----------------------------------------------------------
    def schedule(self):
        """Return the next unit of work, or None when idle:
          ("prefill", seq, chunk_tokens (C,), start_pos)   — one chunk
          ("prefill_packed", [(seq, start, n)], bucket)    — packed wave
          ("decode", [seqs])                               — packed batch

        Alternates prefill/decode when both exist; whichever kind is tried
        first, the other is the fallback, so one failed reservation (which
        preempts the requester) never idles a step that has runnable work.
        """
        self._admit()
        has_decode = any(s.state == DECODE for s in self.running)
        has_prefill = any(s.state == PREFILL for s in self.running)
        prefer_decode = has_decode and (not has_prefill
                                        or self._last_was_prefill)
        prefill = (self._try_prefill_packed if self.prefill_buckets
                   else self._try_prefill)
        order = (self._try_decode, prefill)
        if not prefer_decode:
            order = order[::-1]
        for attempt in order:
            work = attempt()
            if work is not None:
                return work
        return None

    # -- completions ----------------------------------------------------------
    def finish(self, seq):
        seq.state = FINISHED
        self.cache.release(seq.slot)
        seq.slot = -1
        self.running.remove(seq)
