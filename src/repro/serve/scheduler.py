"""Continuous-batching scheduler: admission, chunked prefill, preemption.

The scheduler owns policy, not execution: each ``schedule()`` call returns
one unit of work — a prefill chunk for one sequence or a batched decode over
every decoding sequence — and the engine runs it. Shapes stay static (one
jit trace per work kind) because prefill chunks are a fixed size and decode
batches are padded to ``max_batch``.

Policy choices (deliberately simple and deterministic; see DESIGN.md §8):
  * FIFO admission, gated on a whole-sequence capacity check against the
    page pool (prompt + max_new_tokens must fit) so a lone sequence can
    never deadlock the pool.
  * Prefill/decode interleaving alternates when both kinds of work exist,
    so a stream of long prompts cannot starve running decodes (and vice
    versa).
  * Preemption by recompute: when decode needs a page and the pool is dry,
    the youngest running sequence is evicted — its pages are freed and it
    re-enters the waiting queue (front) with its generated-so-far tokens
    appended to the prompt, so greedy output is unchanged.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .paged_cache import OutOfPages, PagedKVCache

PREFILL, DECODE, FINISHED = "prefill", "decode", "finished"


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None


class Sequence:
    """Scheduler-internal state for one request."""

    def __init__(self, request: Request):
        self.req = request
        self.slot = -1
        self.generated: List[int] = []
        self.cache_len = 0        # tokens written to the KV pool
        self.state = PREFILL
        self.n_preempted = 0

    @property
    def tokens(self) -> np.ndarray:
        """Prompt + everything sampled so far (the re-prefill source after a
        preemption; the last sampled token is not yet in the cache)."""
        return np.concatenate(
            [self.req.prompt,
             np.asarray(self.generated, np.int32)]).astype(np.int32)

    @property
    def n_total(self):
        return len(self.req.prompt) + len(self.generated)

    def is_done(self):
        if len(self.generated) >= self.req.max_new_tokens:
            return True
        return (self.req.eos_id is not None and self.generated
                and self.generated[-1] == self.req.eos_id)


class Scheduler:
    def __init__(self, cache: PagedKVCache, max_batch: int,
                 prefill_chunk: int):
        self.cache = cache
        self.max_batch = max_batch
        self.prefill_chunk = prefill_chunk
        self.waiting: Deque[Sequence] = deque()
        self.running: List[Sequence] = []
        self._last_was_prefill = False
        self.n_preemptions = 0

    # -- queue entry points -------------------------------------------------
    def submit(self, request: Request) -> Sequence:
        total = len(request.prompt) + request.max_new_tokens
        if not self.cache.fits(total):
            raise ValueError(
                f"request {request.req_id}: {total} tokens can never fit "
                f"the page pool ({self.cache.num_pages - 1} usable pages x "
                f"{self.cache.page_size})")
        seq = Sequence(request)
        self.waiting.append(seq)
        return seq

    @property
    def has_work(self):
        return bool(self.waiting or self.running)

    # -- internals ----------------------------------------------------------
    def _admit(self):
        """FIFO admission while slots, batch room, and pool headroom last.
        Headroom check is against the *whole* remaining sequence so an
        admitted sequence only ever blocks on pages another sequence can
        release (preemption handles that case)."""
        while (self.waiting and len(self.running) < self.max_batch
               and self.cache.n_free_slots > 0):
            seq = self.waiting[0]
            need = self.cache.pages_for(
                len(seq.tokens) + seq.req.max_new_tokens
                - len(seq.generated))
            if need > self.cache.n_free_pages:
                break
            self.waiting.popleft()
            seq.slot = self.cache.alloc_slot()
            seq.cache_len = 0
            seq.state = PREFILL
            self.running.append(seq)

    def _preempt(self, victim):
        """Evict ``victim`` (recompute-on-resume): free its pages and push it
        to the front of the waiting queue with generated tokens preserved."""
        self.cache.release(victim.slot)
        victim.slot = -1
        victim.cache_len = 0
        victim.state = PREFILL
        victim.n_preempted += 1
        self.running.remove(victim)
        self.waiting.appendleft(victim)
        self.n_preemptions += 1

    def _reserve_or_preempt(self, seq, n_tokens) -> bool:
        """Reserve pages for ``seq``, evicting youngest-first until it fits.
        ``seq`` itself is evicted if it is the youngest (never steal pages
        from an older sequence); returns False in that case."""
        while True:
            try:
                self.cache.reserve(seq.slot, n_tokens)
                return True
            except OutOfPages:
                victim = max(self.running, key=lambda s: s.req.req_id)
                self._preempt(victim)
                if victim is seq:
                    return False

    def _try_decode(self):
        decodes = [s for s in self.running if s.state == DECODE]
        for seq in list(decodes):
            if seq in self.running:        # a peer's reserve may evict it
                self._reserve_or_preempt(seq, seq.n_total)
        decodes = [s for s in decodes if s in self.running]
        if not decodes:
            return None
        self._last_was_prefill = False
        return ("decode", decodes)

    def _try_prefill(self):
        prefills = [s for s in self.running if s.state == PREFILL]
        if not prefills:
            return None
        seq = prefills[0]
        toks = seq.tokens
        start = seq.cache_len
        chunk = min(self.prefill_chunk, len(toks) - start)
        if not self._reserve_or_preempt(seq, start + chunk):
            return None                    # self-preempted; decode instead
        self._last_was_prefill = True
        return ("prefill", seq, toks[start:start + chunk], start)

    # -- the policy ----------------------------------------------------------
    def schedule(self):
        """Return the next unit of work, or None when idle:
          ("prefill", seq, chunk_tokens (C,), start_pos)   — one chunk
          ("decode", [seqs])                               — packed batch

        Alternates prefill/decode when both exist; whichever kind is tried
        first, the other is the fallback, so one failed reservation (which
        preempts the requester) never idles a step that has runnable work.
        """
        self._admit()
        has_decode = any(s.state == DECODE for s in self.running)
        has_prefill = any(s.state == PREFILL for s in self.running)
        prefer_decode = has_decode and (not has_prefill
                                        or self._last_was_prefill)
        order = (self._try_decode, self._try_prefill)
        if not prefer_decode:
            order = order[::-1]
        for attempt in order:
            work = attempt()
            if work is not None:
                return work
        return None

    # -- completions ----------------------------------------------------------
    def finish(self, seq):
        seq.state = FINISHED
        self.cache.release(seq.slot)
        seq.slot = -1
        self.running.remove(seq)
