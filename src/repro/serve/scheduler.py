"""Continuous-batching scheduler: admission, chunked prefill, preemption.

The scheduler owns policy, not execution: each ``schedule()`` call returns
one unit of work — a prefill chunk for one sequence or a batched decode over
every decoding sequence — and the engine runs it. Shapes stay static (one
jit trace per work kind) because prefill chunks are a fixed size and decode
batches are padded to ``max_batch``.

Policy choices (deliberately simple and deterministic; see DESIGN.md §8):
  * Class-aware admission (DESIGN.md Sec. 17): requests carry a priority
    class (interactive/standard/batch). Waiting sequences queue per class,
    EDF-ordered within a class (earliest deadline first, FIFO among
    deadline-free requests), and the admission head is chosen across
    classes by rank with weighted aging — a lower-class head that has
    waited long enough is promoted one class per ``promote_after`` queue
    events, so batch work can never starve. Admission is still gated on a
    whole-sequence capacity check against the page pool (prompt +
    max_new_tokens must fit) so a lone sequence can never deadlock the
    pool, and still head-blocking: if the selected head does not fit,
    nothing behind it leapfrogs.
  * Prefill/decode interleaving alternates when both kinds of work exist,
    so a stream of long prompts cannot starve running decodes (and vice
    versa).
  * Horizon-aware decode leases: with ``decode_horizon=H`` the decode
    reservation covers ``n_total - 1 + min(H, remaining budget)`` positions
    up front, so one fused device dispatch can sample up to H tokens —
    crossing page boundaries mid-horizon — without coming back to the host
    (DESIGN.md Sec. 12). A lease is just a reservation: pages left
    unwritten when a row stops early stay reserved until the sequence
    finishes or is preempted, and release() returns them either way.
  * Preemption by recompute: when decode needs a page and the pool is dry,
    the lowest-class-youngest running sequence is evicted — its pages are
    freed and it re-enters the waiting queue (front of its class) with its
    generated-so-far tokens appended to the prompt, so greedy output is
    unchanged. A sequence past its deadline's point of no return (less
    than half its deadline window remaining) is protected from eviction
    while any unprotected victim exists. With the prefix
    cache on, ``reserve`` reclaims LRU-cached (unreferenced) prefix pages
    before ever reporting the pool dry, so cached pages are always spent
    before a live sequence is preempted — and a preempted sequence usually
    re-admits straight onto its own still-cached pages.
  * Automatic prefix caching (DESIGN.md Sec. 11): admission longest-prefix
    matches the page registry and adopts hits by refcount bump, so a
    request sharing a 2k-token system prompt with an earlier one skips
    that prefill entirely.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .paged_cache import OutOfPages, PagedKVCache

PREFILL, DECODE, FINISHED = "prefill", "decode", "finished"

# Priority classes, best first. Rank is the admission sort key; the
# weighted-aging promotion in _WaitingQueue keeps the worst class from
# starving, and the brownout ladder (serve/overload.py) sheds from the
# worst class first.
CLASSES = ("interactive", "standard", "batch")
CLASS_RANK: Dict[str, int] = {c: i for i, c in enumerate(CLASSES)}


class Saturated(RuntimeError):
    """A submit was refused for *transient* load reasons (waiting queue
    full, page pool oversubscribed, brownout shedding) — distinct from the
    permanent ``ValueError`` a request that can *never* fit gets. Callers
    should shed load (HTTP 429 + Retry-After) and may retry the identical
    request later. Only raised when backpressure is enabled
    (``max_waiting=``) or a brownout level sheds the request's class."""


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # priority class ("interactive" | "standard" | "batch"): admission
    # order, preemption-victim order, and brownout shedding all key on it
    priority: str = "standard"
    # absolute monotonic deadline (seconds, time.monotonic() domain), or
    # None. Deadlines order admission within a class (EDF) and protect a
    # nearly-due sequence from preemption; they never abort work — the
    # lifecycle timeout owns hard cancellation.
    deadline: Optional[float] = None
    submitted_at: Optional[float] = None   # monotonic stamp, set at submit


class _WaitingQueue:
    """Per-class admission queues behind a deque-compatible facade.

    Within a class, entries are EDF-ordered: sorted by (deadline, arrival),
    deadline-free requests after deadlined ones, FIFO among themselves.
    ``appendleft`` (preemption re-entry) pins the sequence to the front of
    its class — a preempted sequence has head-of-line rights that a later
    arrival with an earlier deadline must not jump.

    Across classes, the logical head is chosen by *effective rank*:
    ``CLASS_RANK - waited // promote_after``, where ``waited`` counts queue
    events (appends + admissions) since the entry joined — deterministic,
    no wall clock. A batch request that has sat through ``promote_after``
    events competes as standard, through ``2*promote_after`` as
    interactive: bounded starvation by construction. Ties break by class
    rank, then EDF key.

    Only the deque operations the scheduler and engine actually use are
    implemented (append/appendleft/popleft/remove/len/bool/iter/[0])."""

    def __init__(self, promote_after: int = 8):
        self.promote_after = max(1, int(promote_after))
        self._q: Dict[str, List["Sequence"]] = {c: [] for c in CLASSES}
        self._clock = 0

    def __len__(self):
        return sum(len(q) for q in self._q.values())

    def __bool__(self):
        return any(self._q.values())

    def __iter__(self):
        for c in CLASSES:
            yield from self._q[c]

    @staticmethod
    def _edf_key(seq: "Sequence"):
        d = seq.req.deadline
        return (d if d is not None else math.inf, seq._queue_seq)

    def _select_class(self) -> Optional[str]:
        best = None
        for c in CLASSES:
            q = self._q[c]
            if not q:
                continue
            head = q[0]
            waited = self._clock - head._enq_clock
            eff = CLASS_RANK[c] - waited // self.promote_after
            key = (eff, CLASS_RANK[c], self._edf_key(head))
            if best is None or key < best[0]:
                best = (key, c)
        return None if best is None else best[1]

    def append(self, seq: "Sequence"):
        self._clock += 1
        seq._queue_seq = self._clock
        seq._enq_clock = self._clock
        q = self._q[seq.req.priority]
        key = self._edf_key(seq)
        i = len(q)
        while i > 0 and not q[i - 1]._hol and self._edf_key(q[i - 1]) > key:
            i -= 1
        q.insert(i, seq)

    def appendleft(self, seq: "Sequence"):
        # preemption re-entry: front of class, ahead of EDF order; keep the
        # original enqueue clock so accumulated aging credit survives
        seq._hol = True
        if seq._enq_clock is None:
            self._clock += 1
            seq._queue_seq = seq._enq_clock = self._clock
        self._q[seq.req.priority].insert(0, seq)

    def popleft(self) -> "Sequence":
        c = self._select_class()
        if c is None:
            raise IndexError("pop from an empty waiting queue")
        self._clock += 1
        seq = self._q[c].pop(0)
        seq._hol = False
        return seq

    def remove(self, seq: "Sequence"):
        self._q[seq.req.priority].remove(seq)   # ValueError when absent

    def __getitem__(self, i):
        if i != 0:
            raise IndexError("_WaitingQueue only exposes the head ([0])")
        c = self._select_class()
        if c is None:
            raise IndexError("waiting queue is empty")
        return self._q[c][0]

    def depth(self, priority: str) -> int:
        return len(self._q[priority])


class Sequence:
    """Scheduler-internal state for one request."""

    def __init__(self, request: Request):
        self.req = request
        self.slot = -1
        self.generated: List[int] = []
        self.cache_len = 0        # tokens written to the KV pool
        self.state = PREFILL
        self.n_preempted = 0
        self._prefix_match = None   # (registry_epoch, match) memo
        self._tokens_memo = None    # (len(generated), array) memo
        # _WaitingQueue bookkeeping: arrival order, aging epoch, and the
        # head-of-line pin a preempted sequence re-enters with
        self._queue_seq = 0
        self._enq_clock: Optional[int] = None
        self._hol = False

    @property
    def tokens(self) -> np.ndarray:
        """Prompt + everything sampled so far (the re-prefill source after a
        preemption; the last sampled token is not yet in the cache).

        Memoized on ``len(generated)`` — ``generated`` is append-only, so
        length identifies content — because every ``schedule()`` call,
        prefill chunk, and prefix registration reads this, and rebuilding
        the concatenation is O(sequence length) per access. The memo is
        returned read-only since callers share it (fork already copies)."""
        memo = self._tokens_memo
        if memo is not None and memo[0] == len(self.generated):
            return memo[1]
        toks = np.concatenate(
            [self.req.prompt,
             np.asarray(self.generated, np.int32)]).astype(np.int32)
        toks.setflags(write=False)
        self._tokens_memo = (len(self.generated), toks)
        return toks

    @property
    def n_total(self):
        return len(self.req.prompt) + len(self.generated)

    def is_done(self):
        if len(self.generated) >= self.req.max_new_tokens:
            return True
        return (self.req.eos_id is not None and self.generated
                and self.generated[-1] == self.req.eos_id)


class Scheduler:
    def __init__(self, cache: PagedKVCache, max_batch: int,
                 prefill_chunk: int, decode_horizon: int = 1,
                 max_waiting: Optional[int] = None,
                 oversubscribe: float = 2.0,
                 prefill_buckets: Optional[Tuple[int, ...]] = None,
                 promote_after: int = 8):
        self.cache = cache
        self.max_batch = max_batch
        self.prefill_chunk = prefill_chunk
        self.decode_horizon = int(decode_horizon)
        # brownout knobs (serve/overload.py writes these, always on the
        # engine thread). All three are schedule-only: they change which
        # work is dispatched and how much of it, never the jitted shapes —
        # horizon_cap clamps the *dynamic* per-dispatch token budget under
        # the same static-horizon trace, max_wave_segments packs fewer
        # segments into the same (already-warmed) buckets, shed_classes
        # turns submits of the named classes into Saturated.
        self.horizon_cap: Optional[int] = None
        self.max_wave_segments: Optional[int] = None
        self.shed_classes: frozenset = frozenset()
        # packed ragged prefill (DESIGN.md Sec. 16): when a bucket set is
        # given, schedule() bins every waiting PREFILL sequence's next chunk
        # into ONE dispatch padded to the smallest covering bucket; None
        # keeps the classic one-chunk-per-sequence path
        self.prefill_buckets = (tuple(sorted(int(b) for b in prefill_buckets))
                                if prefill_buckets else None)
        self.isolate_prefill = False    # one segment per wave when True
        # backpressure (None = unbounded queueing, the pre-server behavior):
        # max_waiting bounds the waiting queue; oversubscribe bounds the
        # outstanding page demand of admitted-but-unfinished work to a
        # multiple of the pool, so a burst of feasible-but-huge requests is
        # shed instead of queued behind a full pool-drain of work
        self.max_waiting = max_waiting if max_waiting is None \
            else int(max_waiting)
        self.oversubscribe = float(oversubscribe)
        self.waiting = _WaitingQueue(promote_after=promote_after)
        self.running: List[Sequence] = []
        self._last_was_prefill = False
        self.n_preemptions = 0
        self.n_admissions = 0         # waiting -> running transitions
        self.n_admission_waves = 0    # _admit() calls that admitted >= 1 seq
        self.n_aborts = 0             # requests cancelled before finishing
        self.n_prefix_hits = 0        # admissions that matched the registry
        self.n_prefix_tokens = 0      # positions adopted instead of prefilled
        # per-class observability (msb_*_total{class=} in serve/metrics.py)
        self.n_preemptions_by_class = {c: 0 for c in CLASSES}
        self.n_admissions_by_class = {c: 0 for c in CLASSES}
        self.n_sheds_by_class = {c: 0 for c in CLASSES}
        # one queue-depth sample per admission wave (NOT per prefill chunk:
        # a long prompt's chunks would otherwise re-report the same depth
        # dozens of times and skew the distribution); drained by the engine
        self.queue_depth_obs: List[int] = []

    # -- queue entry points -------------------------------------------------
    def would_accept(self, n_tokens: int,
                     priority: str = "standard") -> Optional[Exception]:
        """Cheap, mutation-free admission probe for ``n_tokens`` (prompt +
        max_new_tokens) at ``priority``. Returns ``None`` when a ``submit``
        issued right now would be accepted, otherwise the exception instance
        a submit would raise: ``ValueError`` for permanent infeasibility
        (the request can never fit this pool, or the priority class is
        unknown) or ``Saturated`` for transient backpressure or brownout
        shedding (retry later). A server front door calls this before
        mutating any state so a 429/400 costs no allocator work; ``submit``
        re-checks, so the probe->submit race is benign."""
        if priority not in CLASS_RANK:
            return ValueError(f"unknown priority class {priority!r} "
                              f"(expected one of {CLASSES})")
        why = self.cache.capacity_error(n_tokens)
        if why is not None:
            return ValueError(why)
        if priority in self.shed_classes:
            return Saturated(
                f"{priority}-class requests are shed under brownout "
                "(transient overload; retry later)")
        if self.max_waiting is None:
            return None                       # backpressure disabled
        if len(self.waiting) >= self.max_waiting:
            # queue full — but an empty queue + free slot + pool headroom
            # means immediate admission, which max_waiting=0 ("no queueing")
            # must still allow
            if not (not self.waiting and len(self.running) < self.max_batch
                    and self.cache.n_free_slots > 0
                    and self.cache.pages_for(n_tokens)
                    <= self.cache.n_available_pages):
                return Saturated(
                    f"waiting queue full ({len(self.waiting)} waiting, "
                    f"max_waiting={self.max_waiting})")
        demand = sum(
            self.cache.pages_for(len(s.req.prompt) + s.req.max_new_tokens)
            for s in self.running) + sum(
            self.cache.pages_for(len(s.req.prompt) + s.req.max_new_tokens)
            for s in self.waiting)
        usable = self.cache.num_pages - 1
        if demand + self.cache.pages_for(n_tokens) \
                > self.oversubscribe * usable:
            return Saturated(
                f"page pool saturated ({demand} pages of work outstanding "
                f"against {usable} usable pages, "
                f"oversubscribe={self.oversubscribe})")
        return None

    def submit(self, request: Request) -> Sequence:
        # the HTTP layer rejects these with a 400; direct callers get the
        # same contract here (there is no token to prefill, so the packed
        # planner would never assign the sequence a segment)
        if len(request.prompt) == 0:
            raise ValueError(f"request {request.req_id}: prompt must not "
                             "be empty (nothing to prefill)")
        total = len(request.prompt) + request.max_new_tokens
        err = self.would_accept(total, priority=request.priority)
        if err is not None:        # names the limit that actually rejected
            if isinstance(err, Saturated):
                self.n_sheds_by_class[request.priority] += 1
            raise type(err)(f"request {request.req_id}: {err}")
        if request.submitted_at is None:
            request.submitted_at = time.monotonic()
        seq = Sequence(request)
        self.waiting.append(seq)
        return seq

    def abort(self, seq: Sequence) -> bool:
        """Cancel ``seq`` wherever it is in its lifecycle. A waiting
        sequence is dropped from the queue; a running one releases its slot
        — every page it holds (committed, leased-but-unwritten horizon
        pages, and adopted prefix pages alike) is decref'd by
        ``cache.release``, so registered pages park on the prefix-cache LRU
        (reclaimable, not leaked) and everything else returns to the free
        list. Returns False (no-op) when the sequence already finished."""
        if seq.state == FINISHED:
            return False
        if seq.slot >= 0:
            self.cache.release(seq.slot)
            seq.slot = -1
            self.running.remove(seq)
        else:
            try:
                self.waiting.remove(seq)
            except ValueError:
                pass                          # already gone
        seq.state = FINISHED
        self.n_aborts += 1
        return True

    @property
    def has_work(self):
        return bool(self.waiting or self.running)

    # -- internals ----------------------------------------------------------
    def _admit(self):
        """Class-ordered admission while slots, batch room, and pool
        headroom last (the _WaitingQueue head: class rank with aging
        promotion, EDF within a class).
        Headroom check is against the *whole* remaining sequence so an
        admitted sequence only ever blocks on pages another sequence can
        release (preemption handles that case); it counts LRU-cached prefix
        pages as available, since ``reserve`` reclaims them on demand.

        Each admitted sequence longest-prefix-matches the prefix registry
        first: matched pages are adopted by refcount bump and ``cache_len``
        starts at the matched boundary, so chunked prefill skips them. The
        match is capped at ``len(tokens) - 1`` — the last position must be
        prefilled for real so the sampler has logits to advance on."""
        depth_before = len(self.waiting)
        admitted_before = self.n_admissions
        while (self.waiting and len(self.running) < self.max_batch
               and self.cache.n_free_slots > 0):
            seq = self.waiting[0]
            toks = seq.tokens
            match = self._match_for(seq, toks)
            n_hit = len(match.pages) if match else 0
            need = self.cache.pages_for(
                len(toks) + seq.req.max_new_tokens
                - len(seq.generated)) - n_hit
            avail = self.cache.n_available_pages - (
                match.n_unreferenced if match else 0)
            if need > avail:
                break
            self.waiting.popleft()
            self.n_admissions += 1
            self.n_admissions_by_class[seq.req.priority] += 1
            seq.slot = self.cache.alloc_slot()
            seq.cache_len = 0
            if match is not None:
                self.cache.adopt_prefix(seq.slot, match)
                seq.cache_len = match.n_tokens
                self.n_prefix_hits += 1
                self.n_prefix_tokens += match.n_tokens
            seq.state = PREFILL
            self.running.append(seq)
        if self.n_admissions > admitted_before:
            self.n_admission_waves += 1
            if len(self.queue_depth_obs) < 4096:   # bounded if undrained
                self.queue_depth_obs.append(depth_before)

    def _match_for(self, seq, toks):
        """match_prefix memoized per sequence on the registry epoch: a
        page-blocked head-of-queue request would otherwise re-hash its
        whole prompt on every schedule() call while it waits. The memo can
        only go stale in LRU-membership accounting (registration state is
        epoch-versioned), which admission treats as advisory anyway —
        reserve-time OutOfPages and preemption backstop it."""
        epoch = self.cache.registry_epoch
        if seq._prefix_match is not None and seq._prefix_match[0] == epoch:
            return seq._prefix_match[1]
        match = self.cache.match_prefix(toks, max_tokens=len(toks) - 1)
        seq._prefix_match = (epoch, match)
        return match

    def _preempt(self, victim):
        """Evict ``victim`` (recompute-on-resume): free its pages and push it
        to the front of the waiting queue with generated tokens preserved."""
        self.cache.release(victim.slot)
        victim.slot = -1
        victim.cache_len = 0
        victim.state = PREFILL
        victim.n_preempted += 1
        victim._prefix_match = None   # tokens grew since the last match
        self.running.remove(victim)
        self.waiting.appendleft(victim)
        self.n_preemptions += 1
        self.n_preemptions_by_class[victim.req.priority] += 1

    @staticmethod
    def _past_point_of_no_return(seq, now: float) -> bool:
        """A deadlined sequence is past its point of no return once less
        than half its original deadline window remains: preempting it then
        (recompute-on-resume re-prefills everything generated so far) would
        all but guarantee a deadline miss, so the victim picker protects it
        while any unprotected candidate exists. Deadline-free sequences are
        never protected."""
        d = seq.req.deadline
        if d is None:
            return False
        sub = seq.req.submitted_at
        if sub is None or d <= sub:
            return now >= d
        return (d - now) < 0.5 * (d - sub)

    def _pick_victim(self, now: Optional[float] = None):
        """Lowest-class-youngest victim: among running sequences not past
        their deadline's point of no return, take the worst priority class,
        breaking ties by highest req_id (youngest). Protection is
        best-effort — if *every* running sequence is protected the pool
        still has to make progress, so the pick falls back to all of them."""
        now = time.monotonic() if now is None else now
        candidates = [s for s in self.running
                      if not self._past_point_of_no_return(s, now)]
        if not candidates:
            candidates = self.running
        return max(candidates,
                   key=lambda s: (CLASS_RANK[s.req.priority], s.req.req_id))

    def _reserve_or_preempt(self, seq, n_tokens) -> bool:
        """Reserve pages for ``seq``, evicting lowest-class-youngest-first
        until it fits. ``seq`` itself is evicted if it is the chosen victim
        (never steal pages from a better-ranked sequence); returns False in
        that case."""
        while True:
            try:
                self.cache.reserve(seq.slot, n_tokens)
                return True
            except OutOfPages:
                victim = self._pick_victim()
                self._preempt(victim)
                if victim is seq:
                    return False

    @property
    def effective_horizon(self) -> int:
        """Decode-horizon token budget per dispatch, after the brownout
        clamp. The *static* trace horizon never changes — a cap only lowers
        the dynamic ``n_left`` budget the device sees, so a capped dispatch
        runs the same compiled scan and just retires fewer tokens."""
        if self.horizon_cap is None:
            return self.decode_horizon
        return max(1, min(self.decode_horizon, int(self.horizon_cap)))

    def _decode_lease(self, seq) -> int:
        """Token positions the next decode dispatch may write for ``seq``:
        a horizon dispatch samples up to ``min(effective_horizon, remaining
        budget)`` tokens, writing K/V for each input token starting at
        ``n_total - 1``, so the lease covers ``n_total - 1 + h`` positions.
        Reserving the whole lease up front is what lets the device cross
        page boundaries mid-horizon with no host intervention (the block
        table already addresses every reserved page). ``decode_horizon=1``
        degenerates to the classic one-position reserve (``n_total``)."""
        h = min(self.effective_horizon,
                seq.req.max_new_tokens - len(seq.generated))
        return seq.n_total - 1 + max(h, 1)

    def _try_decode(self):
        decodes = [s for s in self.running if s.state == DECODE]
        for seq in list(decodes):
            if seq in self.running:        # a peer's reserve may evict it
                self._reserve_or_preempt(seq, self._decode_lease(seq))
        decodes = [s for s in decodes if s in self.running]
        if not decodes:
            return None
        self._last_was_prefill = False
        return ("decode", decodes)

    def _try_prefill(self):
        prefills = [s for s in self.running if s.state == PREFILL]
        if not prefills:
            return None
        seq = prefills[0]
        toks = seq.tokens
        start = seq.cache_len
        chunk = min(self.prefill_chunk, len(toks) - start)
        if not self._reserve_or_preempt(seq, start + chunk):
            return None                    # self-preempted; decode instead
        self._last_was_prefill = True
        return ("prefill", seq, toks[start:start + chunk], start)

    def _try_prefill_packed(self):
        """Pack every runnable PREFILL sequence's next chunk into one
        dispatch (DESIGN.md Sec. 16): segments are assigned in running
        (FIFO-admission) order under a token budget of the largest bucket,
        each segment resuming at its own ``cache_len`` — so prefix-cache
        adoptions pack at the matched boundary and a prompt longer than the
        largest bucket simply continues across successive waves (chunking
        falls out, no special case). Returns
        ``("prefill_packed", [(seq, start, n), ...], bucket)`` with
        ``bucket`` the smallest bucket covering the assigned tokens.

        Reservation order is planning order; a reservation that preempts
        evicts the *youngest* running sequence, which may be a segment
        planned earlier in this very wave — the final membership filter
        drops any segment preemption took back (its reservation was
        released with its slot, so nothing leaks)."""
        if not any(s.state == PREFILL for s in self.running):
            return None
        budget = self.prefill_buckets[-1]
        # crash isolation (set by the supervisor after a crash blamed on a
        # multi-segment packed dispatch): pack one segment per wave so
        # blame — and poison quarantine — stays per-request precise.
        # max_wave_segments is the brownout wave-width cap: fewer segments
        # per wave means a smaller covering bucket, and every bucket is
        # already warmed, so the cap never introduces a new trace.
        if self.isolate_prefill:
            max_segs = 1
        elif self.max_wave_segments is not None:
            max_segs = max(1, min(self.max_batch,
                                  int(self.max_wave_segments)))
        else:
            max_segs = self.max_batch
        segs: List[Tuple[Sequence, int, int]] = []
        used = 0
        for seq in list(self.running):
            if seq.state != PREFILL or seq not in self.running:
                continue
            if len(segs) >= max_segs or used >= budget:
                break
            start = seq.cache_len
            n = min(budget - used, len(seq.tokens) - start)
            if n <= 0:
                continue
            if not self._reserve_or_preempt(seq, start + n):
                continue                   # self-preempted mid-wave
            segs.append((seq, start, n))
            used += n
        segs = [s for s in segs if s[0] in self.running]
        if not segs:
            return None
        used = sum(n for _, _, n in segs)
        bucket = next(b for b in self.prefill_buckets if b >= used)
        self._last_was_prefill = True
        return ("prefill_packed", segs, bucket)

    # -- the policy ----------------------------------------------------------
    def schedule(self):
        """Return the next unit of work, or None when idle:
          ("prefill", seq, chunk_tokens (C,), start_pos)   — one chunk
          ("prefill_packed", [(seq, start, n)], bucket)    — packed wave
          ("decode", [seqs])                               — packed batch

        Alternates prefill/decode when both exist; whichever kind is tried
        first, the other is the fallback, so one failed reservation (which
        preempts the requester) never idles a step that has runnable work.
        """
        self._admit()
        has_decode = any(s.state == DECODE for s in self.running)
        has_prefill = any(s.state == PREFILL for s in self.running)
        prefer_decode = has_decode and (not has_prefill
                                        or self._last_was_prefill)
        prefill = (self._try_prefill_packed if self.prefill_buckets
                   else self._try_prefill)
        order = (self._try_decode, prefill)
        if not prefer_decode:
            order = order[::-1]
        for attempt in order:
            work = attempt()
            if work is not None:
                return work
        return None

    # -- completions ----------------------------------------------------------
    def finish(self, seq):
        seq.state = FINISHED
        self.cache.release(seq.slot)
        seq.slot = -1
        self.running.remove(seq)
