"""Deterministic synthetic data pipeline (no external datasets offline).

Two sources:
  * ``TokenStream`` — uniform-random tokens, fully deterministic in
    (seed, step, host): the dry-run/throughput workload.
  * ``MarkovStream`` — tokens from a fixed random Markov chain, so a model
    can actually *learn* (entropy-gap between chain and uniform). Used by
    the end-to-end example + quantization-quality benchmarks: eval loss on
    held-out Markov text measurably degrades when weights are quantized.

Batches are per-host shards of the global batch (shape (local_batch, seq)),
prefetched on a background thread.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class TokenStream:
    def __init__(self, vocab, seq_len, global_batch, seed=0, host=0, n_hosts=1):
        assert global_batch % n_hosts == 0
        self.vocab, self.seq = vocab, seq_len
        self.local_batch = global_batch // n_hosts
        self.seed, self.host = seed, host

    def batch(self, step):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host]))
        toks = rng.integers(0, self.vocab,
                            (self.local_batch, self.seq + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class MarkovStream(TokenStream):
    """Order-1 Markov chain with temperature-controlled transition rows."""

    def __init__(self, vocab, seq_len, global_batch, seed=0, host=0,
                 n_hosts=1, concentration=0.05):
        super().__init__(vocab, seq_len, global_batch, seed, host, n_hosts)
        rng = np.random.default_rng(seed)
        logits = rng.standard_normal((vocab, vocab)) / concentration
        logits -= logits.max(axis=1, keepdims=True)
        p = np.exp(logits)
        self.trans = p / p.sum(axis=1, keepdims=True)
        self.cum = np.cumsum(self.trans, axis=1)

    def batch(self, step):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 7, step, self.host]))
        b, s = self.local_batch, self.seq + 1
        toks = np.empty((b, s), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, b)
        u = rng.random((b, s))
        for t in range(1, s):
            toks[:, t] = np.argmax(
                self.cum[toks[:, t - 1]] > u[:, t:t + 1], axis=1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def entropy(self):
        """Per-token entropy of the chain (nats) — the loss floor."""
        from numpy import log
        stat = np.linalg.matrix_power(self.trans, 64)[0]
        h_rows = -(self.trans * np.log(self.trans + 1e-12)).sum(axis=1)
        return float((stat * h_rows).sum())


class Prefetcher:
    """Background-thread prefetch wrapper around any batch iterator."""

    def __init__(self, it, depth=2):
        self.q = queue.Queue(maxsize=depth)
        self.it = iter(it)
        self._stop = False
        self.t = threading.Thread(target=self._fill, daemon=True)
        self.t.start()

    def _fill(self):
        for item in self.it:
            if self._stop:
                return
            self.q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop = True
