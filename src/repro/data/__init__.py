from .synthetic import MarkovStream, Prefetcher, TokenStream
