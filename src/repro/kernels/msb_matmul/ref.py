"""Pure-jnp oracle for the msb_matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

BLOCK = 64
LEVELS = 8


def unpack_ref(packed, n):
    """uint8 (K, N//2) -> (level (K,N) int32, sign (K,N) f32)."""
    p32 = packed.astype(jnp.int32)
    lo = p32 & 0xF
    hi = (p32 >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], n)
    level = nib & 0x7
    sign = (1 - 2 * ((nib >> 3) & 1)).astype(jnp.float32)
    return level, sign


def dequant_ref(packed, scales):
    """Dequantize to (K, N) f32. scales: (K, N//64, 8)."""
    k, half = packed.shape
    n = half * 2
    level, sign = unpack_ref(packed, n)
    sc = scales.astype(jnp.float32)                          # (K, N//64, 8)
    mag = jnp.take_along_axis(
        sc, level.reshape(k, n // BLOCK, BLOCK), axis=2
    ).reshape(k, n)
    return sign * mag


def msb_matmul_ref(x, packed, scales):
    w = dequant_ref(packed, scales).astype(x.dtype)
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
