"""Pure-jnp oracle for the msb_matmul kernel.

The nibble decode and per-block scale gather are the *same* code the
packed execution path runs off-TPU (core.quantize), so the oracle cannot
drift from the storage format — only the in-kernel 8-way-select variant
(msb_matmul._dequant_tile) is a separate implementation, and it is what
these functions validate.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...core.quantize import (PACK_BLOCK as BLOCK, PACK_LEVELS as LEVELS,
                              PackedQTensor, _unpack_nibbles,
                              packed_dequantize)


def unpack_ref(packed, n):
    """uint8 (K, N//2) -> (level (K,N) int32, sign (K,N) f32)."""
    level, sign = _unpack_nibbles(packed)
    return level[:, :n], sign[:, :n]


def dequant_ref(packed, scales, kblocked=False):
    """Dequantize to (K, N) f32.

    scales: (K, N//64, 8) n-blocked, or (K//64, N, 8) k-blocked."""
    n = packed.shape[-1] * 2
    pq = PackedQTensor(packed, scales, 4, BLOCK, jnp.float32, n,
                       kblocked=kblocked)
    return packed_dequantize(pq)


def msb_matmul_ref(x, packed, scales, bias=None, kblocked=False):
    w = dequant_ref(packed, scales, kblocked=kblocked).astype(x.dtype)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.reshape(1, -1).astype(y.dtype)
    return y.astype(x.dtype)
