"""Pallas TPU kernel: fused MSB-dequantize + matmul.

Computes ``y = x @ dequant(Wq) (+ bias)`` where Wq is 4-bit MSB weight
storage:
  packed : uint8 (K, N//2) — two 4-bit codes per byte
           nibble = (sign_bit << 3) | level,  level in [0, 8)
  scales : bf16/f32, one 8-level codebook per 64-element block:
             n-blocked (dense weights): (K, N//64, 8) — blocks along N
             k-blocked (transposed unembedding): (K//64, N, 8) — blocks
             along K (``kblocked=True``)

TPU mapping (DESIGN.md Sec. 9): the kernel streams x tiles (bm, bk) and
packed-code tiles (bk, bn//2) HBM->VMEM, unpacks + dequantizes in VMEM
registers (3 bit-ops + an 8-way select — no gather), and feeds the MXU with
(bm, bk) x (bk, bn) bf16 tiles, accumulating f32 into the output tile. The
weight HBM traffic is ~6 bits/weight (codes + codebooks) instead of 16 —
the decode-shape memory-roofline win tracked in BENCH_matmul.json.

Serving shapes: tile sizes default to a (M, K, N)-keyed heuristic —
bucketed decode (M <= 8) takes a skinny-M/GEMV specialization (one
sublane-high x row tile, wide bn so the packed weight stream dominates
traffic); M is padded to the tile height inside this wrapper, so any
decode bucket shape works. An optional bias (1, N) is added to the output
tile on the last K step (fused — no separate bias pass over HBM).

Grid: (M/bm, N/bn, K/bk), K innermost for output-tile accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 64     # MSB block size along the quantization axis
LEVELS = 8     # 2^(4-1) scales per block


def _dequant_tile(packed, scales, *, kblocked, dot_dtype):
    """uint8 (bk, bn//2) + codebook tile -> bf16/f32 (bk, bn) weights."""
    bk, half = packed.shape
    bn = half * 2
    p32 = packed.astype(jnp.int32)
    lo = p32 & 0xF
    hi = (p32 >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=-1).reshape(bk, bn)
    level = nib & 0x7                            # (bk, bn)
    sign = (1 - 2 * ((nib >> 3) & 1)).astype(jnp.float32)

    # 8-way select instead of a gather: w = sum_z [level == z] * scales[.., z]
    sc = scales.astype(jnp.float32)
    mag = jnp.zeros((bk, bn), jnp.float32)
    for z in range(LEVELS):
        if kblocked:                             # sc (bk//64, bn, 8)
            sz = jnp.repeat(sc[:, :, z], BLOCK, axis=0)       # (bk, bn)
        else:                                    # sc (bk, bn//64, 8)
            sz = jnp.repeat(sc[:, :, z], BLOCK, axis=1)       # (bk, bn)
        mag = mag + jnp.where(level == z, sz, 0.0)
    return (sign * mag).astype(dot_dtype)


def _kernel(x_ref, packed_ref, scales_ref, o_ref, *, bk_steps, dot_dtype,
            kblocked):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(packed_ref[...], scales_ref[...],
                      kblocked=kblocked, dot_dtype=dot_dtype)
    acc = jnp.dot(x_ref[...].astype(dot_dtype), w,
                  preferred_element_type=jnp.float32)
    o_ref[...] += acc


def _kernel_bias(x_ref, packed_ref, scales_ref, bias_ref, o_ref, *,
                 bk_steps, dot_dtype, kblocked):
    _kernel(x_ref, packed_ref, scales_ref, o_ref, bk_steps=bk_steps,
            dot_dtype=dot_dtype, kblocked=kblocked)

    @pl.when(pl.program_id(2) == bk_steps - 1)
    def _add_bias():
        o_ref[...] += bias_ref[...].astype(jnp.float32)


def _largest_divisor(x, candidates):
    for c in candidates:
        if x % c == 0:
            return c
    return x


def pick_blocks(m, k, n):
    """(bm, bn, bk) heuristic keyed on the problem shape.

    Decode (M <= 8) is pure weight streaming: one sublane-high output tile,
    bn as wide as divisibility allows (amortizes the per-tile dequant and
    keeps the packed-code DMA long), deep bk. Prefill/training shapes use
    MXU-square 128s. All sizes divide their dim — callers pad M only."""
    if m <= 8:
        bm = 8
        bn = _largest_divisor(n, (512, 256, 128, 64))
        # bk capped at 256 so the in-register dequant tile (bk, bn) and its
        # unpack intermediates stay a small fraction of VMEM at bn=512
        bk = _largest_divisor(k, (256, 128, 64, 32, 16, 8))
    else:
        bm = _largest_divisor(m, (128, 64, 32, 16, 8))
        bn = _largest_divisor(n, (256, 128, 64))
        bk = _largest_divisor(k, (256, 128, 64, 32, 16, 8))
    return bm, bn, bk


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "kblocked",
                                             "interpret"))
def msb_matmul(x, packed, scales, bias=None, *, bm=None, bn=None, bk=None,
               kblocked=False, interpret=False):
    """x: (M, K); packed: (K, N//2) uint8; scales: (K, N//64, 8) n-blocked
    or (K//64, N, 8) k-blocked; bias: optional (N,) or (1, N).

    Returns (M, N) in x.dtype. Tile sizes must be multiples of the MSB
    block along the blocked axis; unset sizes come from ``pick_blocks``.
    M is padded to the tile height internally (serving buckets are 1..8).
    """
    m, k = x.shape
    n = packed.shape[1] * 2
    hm, hn, hk = pick_blocks(m, k, n)
    bm = hm if bm is None else bm
    bn = hn if bn is None else min(bn, n)
    bk = hk if bk is None else min(bk, k)
    if m % bm:
        x = jnp.pad(x, ((0, -m % bm), (0, 0)))
    mp = x.shape[0]
    assert mp % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bn % BLOCK == 0
    if kblocked:
        assert bk % BLOCK == 0, (bk, "k-blocked scales need 64-aligned bk")
    dot_dtype = x.dtype if x.dtype in (jnp.bfloat16, jnp.float32) else jnp.float32

    grid = (mp // bm, n // bn, k // bk)
    scales_spec = (
        pl.BlockSpec((bk // BLOCK, bn, LEVELS), lambda i, j, s: (s, j, 0))
        if kblocked else
        pl.BlockSpec((bk, bn // BLOCK, LEVELS), lambda i, j, s: (s, j, 0)))
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
        pl.BlockSpec((bk, bn // 2), lambda i, j, s: (s, j)),
        scales_spec,
    ]
    args = [x, packed, scales]
    if bias is not None:
        bias = bias.reshape(-1)
        if bias.shape[0] != n:    # logical N < padded storage width
            bias = jnp.pad(bias, (0, n - bias.shape[0]))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s: (0, j)))
        args.append(bias.reshape(1, n))
        kern = _kernel_bias
    else:
        kern = _kernel
    out = pl.pallas_call(
        functools.partial(kern, bk_steps=grid[2], dot_dtype=dot_dtype,
                          kblocked=kblocked),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:m].astype(x.dtype)
