"""Pallas TPU kernel: fused MSB-dequantize + matmul.

Computes ``y = x @ dequant(Wq)`` where Wq is 4-bit MSB weight storage:
  packed : uint8 (K, N//2) — two 4-bit codes per byte
           nibble = (sign_bit << 3) | level,  level in [0, 8)
  scales : bf16/f32 (K, N//64, 8) — one 8-level codebook per 64-element
           row-block (the paper's block-wise granularity)

TPU mapping (DESIGN.md Sec. 2): the kernel streams x tiles (bm, bk) and
packed-code tiles (bk, bn//2) HBM->VMEM, unpacks + dequantizes in VMEM
registers (3 bit-ops + an 8-way select — no gather), and feeds the MXU with
(bm, bk) x (bk, bn) bf16 tiles, accumulating f32 into the output tile. The
weight HBM traffic is 6 bits/weight (codes + codebooks) instead of 16 —
the decode-shape memory-roofline win measured in EXPERIMENTS.md §Perf.

Grid: (M/bm, N/bn, K/bk), K innermost for output-tile accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 64     # MSB block size along N
LEVELS = 8     # 2^(4-1) scales per block


def _kernel(x_ref, packed_ref, scales_ref, o_ref, *, bk_steps, dot_dtype):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                               # (bm, bk)
    packed = packed_ref[...]                     # (bk, bn//2) uint8
    scales = scales_ref[...]                     # (bk, bn//64, 8)

    bk, half = packed.shape
    bn = half * 2
    p32 = packed.astype(jnp.int32)
    lo = p32 & 0xF
    hi = (p32 >> 4) & 0xF
    nib = jnp.stack([lo, hi], axis=-1).reshape(bk, bn)
    level = nib & 0x7                            # (bk, bn)
    sign = (1 - 2 * ((nib >> 3) & 1)).astype(jnp.float32)

    # 8-way select instead of a gather: w = sum_z [level == z] * scales[.., z]
    sc = scales.astype(jnp.float32)              # (bk, bn//64, 8)
    mag = jnp.zeros((bk, bn), jnp.float32)
    for z in range(LEVELS):
        sz = jnp.repeat(sc[:, :, z], BLOCK, axis=1)   # (bk, bn)
        mag = mag + jnp.where(level == z, sz, 0.0)
    w = (sign * mag).astype(dot_dtype)

    acc = jnp.dot(x.astype(dot_dtype), w,
                  preferred_element_type=jnp.float32)
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def msb_matmul(x, packed, scales, *, bm=128, bn=256, bk=128, interpret=False):
    """x: (M, K); packed: (K, N//2) uint8; scales: (K, N//64, 8).

    Returns (M, N) in x.dtype. Tile sizes are MXU-aligned multiples of 128;
    bn must be a multiple of 64 (the MSB block).
    """
    m, k = x.shape
    n = packed.shape[1] * 2
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bn % BLOCK == 0
    dot_dtype = x.dtype if x.dtype in (jnp.bfloat16, jnp.float32) else jnp.float32

    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bk_steps=grid[2], dot_dtype=dot_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, s: (s, j)),
            pl.BlockSpec((bk, bn // BLOCK, LEVELS), lambda i, j, s: (s, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, packed, scales)
    return out.astype(x.dtype)
