"""jit'd wrapper: QTensor -> kernel storage layout + dispatch.

``qtensor_matmul(x, q)`` runs the Pallas kernel on TPU (or interpret mode on
CPU for validation) and the jnp reference elsewhere. ``to_kernel_layout``
converts the framework QTensor (codes int8 + (n_blocks, 8) scales) into the
kernel's packed/reshaped layout once at load time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.quantize import QTensor, pack_codes_int4
from .msb_matmul import BLOCK, LEVELS, msb_matmul
from .ref import msb_matmul_ref


def to_kernel_layout(q: QTensor):
    """QTensor (codes (K,N), scales (K*N/64, 8)) -> (packed, scales3d)."""
    assert q.bits == 4 and q.block == BLOCK, "kernel supports 4-bit block-64"
    k, n = q.codes.shape
    packed = pack_codes_int4(q.codes).reshape(k, n // 2)
    scales = q.scales.reshape(k, n // BLOCK, LEVELS)
    return packed, scales


def qtensor_matmul(x, q: QTensor, *, use_kernel=None, interpret=None):
    """y = x @ dequant(q). x: (..., K)."""
    packed, scales = to_kernel_layout(q)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_kernel:
        y = msb_matmul(x2, packed, scales, interpret=interpret)
    else:
        y = msb_matmul_ref(x2, packed, scales)
    return y.reshape(*lead, -1)
