"""Dispatch layer: packed-weight matmul for models and serving.

``packed_matmul(x, pq)`` is the hot-path entry point: its input is a
pre-packed ``PackedQTensor`` (produced once at load time by
``core.policy.pack_params``), so nothing is re-laid-out per call. On TPU it
runs the fused Pallas kernel; elsewhere it mirrors simulation-mode math
exactly (dequantize, then the same einsum ``dense()`` uses) so packed and
simulated execution are token-identical off-TPU by construction.

``qtensor_matmul`` / ``to_kernel_layout`` remain as test/bench conveniences
over the raw ``QTensor``; the packing they do is memoized on the concrete
codes/scales buffers, fixing the old per-invocation ``to_kernel_layout``
(int4 pack + scale reshape on every call).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.quantize import (PackedQTensor, QTensor, pack_codes_int4,
                              pack_qtensor)
from .msb_matmul import BLOCK, LEVELS, msb_matmul
from .ref import msb_matmul_ref


def _on_tpu():
    return jax.default_backend() == "tpu"


def packed_matmul(x, pq: PackedQTensor, bias=None, *, use_kernel=None,
                  interpret=None, psum_axis=None):
    """y = x @ dequant(pq) (+ bias). x: (..., K); returns (..., pq.n).

    ``pq`` must be 2-D storage (a scan-sliced or per-expert leaf). The
    kernel path fuses the bias add; the jnp path replays simulation-mode
    math on the dequantized weights.

    Tensor parallelism (DESIGN.md Sec. 10): under ``shard_map`` the local
    shard of an N- (column-) sharded weight needs nothing special — pass
    the local ``pq`` (after ``core.policy.tp_localize``) and the output is
    the local slice of features. For a K- (row-) sharded weight the local
    matmul yields *partial products*; pass ``psum_axis`` (the mesh axis
    name) and the dispatch psums them — the bias, if any, is then added
    once *after* the psum rather than fused per rank.
    """
    if pq.packed.ndim != 2:
        raise ValueError(f"packed_matmul wants 2-D storage, got "
                         f"{pq.packed.shape}; slice stacked params first")
    if use_kernel is None:
        use_kernel = _on_tpu()
    fused_bias = bias if psum_axis is None else None
    if use_kernel:
        if interpret is None:
            interpret = not _on_tpu()
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = msb_matmul(x2, pq.packed, pq.scales, fused_bias,
                       kblocked=pq.kblocked, interpret=interpret)
        y = y[:, : pq.n].reshape(*lead, pq.n).astype(x.dtype)
    else:
        w = pq.dequantize()                  # (K, n), exact simulation math
        y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
        if fused_bias is not None:
            y = y + fused_bias.astype(y.dtype)
    if psum_axis is not None:
        y = jax.lax.psum(y, psum_axis)
        if bias is not None:
            y = y + bias.astype(y.dtype)
    return y


# -- QTensor conveniences (tests / benchmarks) -------------------------------

def to_kernel_layout(q: QTensor):
    """QTensor (codes (K,N), scales (K*N/64, 8)) -> (packed, scales3d)."""
    assert q.bits == 4 and q.block == BLOCK, "kernel supports 4-bit block-64"
    k, n = q.codes.shape
    packed = pack_codes_int4(q.codes).reshape(k, n // 2)
    scales = q.scales.reshape(k, n // BLOCK, LEVELS)
    return packed, scales


_PACK_CACHE = {}


def _cached_pack(q: QTensor) -> PackedQTensor:
    """Memoize packing on the concrete buffers so repeated calls don't
    re-run the layout pass. Tracers (inside jit) are never cached — models
    should carry PackedQTensor params instead of packing under trace."""
    if isinstance(q.codes, jax.core.Tracer):
        return pack_qtensor(q)
    key = (id(q.codes), id(q.scales))
    hit = _PACK_CACHE.get(key)
    # retain BOTH buffers and identity-check both: an id() can be recycled
    # after gc, and a stale hit would silently pack the wrong scales
    if hit is None or hit[0] is not q.codes or hit[1] is not q.scales:
        if len(_PACK_CACHE) > 256:
            _PACK_CACHE.clear()
        hit = (q.codes, q.scales, pack_qtensor(q))
        _PACK_CACHE[key] = hit
    return hit[2]


def qtensor_matmul(x, q: QTensor, *, use_kernel=None, interpret=None):
    """y = x @ dequant(q). x: (..., K). Packs on first use (memoized)."""
    return packed_matmul(x, _cached_pack(q), use_kernel=use_kernel,
                         interpret=interpret)
