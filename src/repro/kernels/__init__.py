"""Pallas TPU kernels for the perf-critical compute layers.

msb_matmul       — fused MSB int4 dequantize + matmul (the paper's weights
                   executed without decode-to-bf16 materialization)
flash_attention  — tiled online-softmax attention forward with causal tile
                   skipping (prefill hot-spot)
paged_attention  — decode attention streaming KV pages via block tables
                   (continuous-batching serving hot-spot; serve/continuous)

Each kernel ships ops.py (jit'd dispatch) + ref.py (pure-jnp oracle) and is
validated in interpret mode over shape/dtype sweeps (tests/test_kernels.py).
EXAMPLE.md documents the kernel-authoring conventions.
"""
