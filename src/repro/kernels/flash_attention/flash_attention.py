"""Pallas TPU kernel: flash attention forward (causal/window/softcap, GQA).

Grid: (batch * kv_heads * rep, Sq/bq) outer; the kernel loops over KV tiles
with ``jax.lax.fori_loop`` keeping the online-softmax state (m, l, acc) in
VMEM. Unlike the pure-JAX path (models/attention.py — also the oracle),
fully-masked KV tiles ahead of the causal frontier are *skipped* via the
loop upper bound, recovering the ~2x causal-waste the dry-run roofline
charges the XLA path for (EXPERIMENTS.md §Perf).

q: (B, H, Sq, d), k/v: (B, KV, Skv, d) — head-major layout so each grid row
streams one head's tiles.

Segment-aware masking (packed ragged prefill, DESIGN.md Sec. 16): pass
``q_segs``/``kv_segs`` of shape (B, Sq)/(B, Skv) and attention is
additionally masked to ``kv_seg == q_seg`` — tokens of different packed
segments attend to each other with exactly zero weight. Segments must be
contiguous along the sequence axis for causal masking to stay per-segment
correct (global order equals local order then); a pad id of -1 on the q
side yields an all-masked row, which the softmax guard sends to zero
output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -2.0e38


def _kernel(*refs, bq, bkv, skv, causal, window, softcap, scale, segmented):
    if segmented:
        q_ref, k_ref, v_ref, qs_ref, ks_ref, o_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref = refs
        qs_ref = ks_ref = None
    iq = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # (bq, d)
    d = q.shape[-1]
    qpos = iq * bq + jax.lax.iota(jnp.int32, bq)
    qseg = qs_ref[...] if segmented else None           # (bq,)

    n_kv = skv // bkv
    if causal:
        # skip tiles strictly above the causal frontier
        hi = jnp.minimum(((iq + 1) * bq + bkv - 1) // bkv, n_kv)
    else:
        hi = n_kv

    def body(ik, carry):
        acc, m_i, l_i = carry
        kt = pl.load(k_ref, (pl.dslice(ik * bkv, bkv), slice(None)))
        vt = pl.load(v_ref, (pl.dslice(ik * bkv, bkv), slice(None)))
        s = jnp.dot(q, kt.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)  # (bq, bkv)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = ik * bkv + jax.lax.iota(jnp.int32, bkv)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        if segmented:
            kseg = pl.load(ks_ref, (pl.dslice(ik * bkv, bkv),))
            # same segment, and neither side a pad (-1): pads never attend
            mask &= (kseg[None, :] == qseg[:, None]) & (qseg[:, None] >= 0)
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        # an all-masked row (a pad segment) keeps m at _NEG_INF, making
        # every p term exp(0)=1; zero it so l stays 0 and the output 0
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=-1)
        pv = jnp.dot(p.astype(vt.dtype), vt,
                     preferred_element_type=jnp.float32)
        acc = acc * corr[:, None] + pv
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, hi, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bkv", "interpret"))
def flash_attention_fwd(q, k, v, q_segs=None, kv_segs=None, *, causal=True,
                        window=0, softcap=0.0, scale=None, bq=128, bkv=128,
                        interpret=False):
    """q: (B, H, Sq, d); k/v: (B, KV, Skv, d). Returns (B, H, Sq, d).
    ``q_segs``/``kv_segs``: optional (B, Sq)/(B, Skv) int32 segment ids —
    pass both or neither; cross-segment attention is masked out."""
    if (q_segs is None) != (kv_segs is None):
        raise ValueError("pass both q_segs and kv_segs, or neither")
    b, h, sq, d = q.shape
    kv, skv = k.shape[1], k.shape[2]
    rep = h // kv
    scale = float(scale if scale is not None else d ** -0.5)
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0
    segmented = q_segs is not None

    qr = q.reshape(b * h, sq, d)
    kr = jnp.repeat(k, rep, axis=1).reshape(b * h, skv, d)
    vr = jnp.repeat(v, rep, axis=1).reshape(b * h, skv, d)

    grid = (b * h, sq // bq)
    in_specs = [
        pl.BlockSpec((None, bq, d), lambda g, i: (g, i, 0)),
        pl.BlockSpec((None, skv, d), lambda g, i: (g, 0, 0)),
        pl.BlockSpec((None, skv, d), lambda g, i: (g, 0, 0)),
    ]
    args = [qr, kr, vr]
    if segmented:
        # one seg row per (batch, head) grid row, matching the q reshape
        args.append(jnp.repeat(jnp.asarray(q_segs, jnp.int32), h, axis=0))
        args.append(jnp.repeat(jnp.asarray(kv_segs, jnp.int32), h, axis=0))
        in_specs.append(pl.BlockSpec((None, bq), lambda g, i: (g, i)))
        in_specs.append(pl.BlockSpec((None, skv), lambda g, i: (g, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bkv=bkv, skv=skv, causal=causal,
                          window=int(window), softcap=float(softcap),
                          scale=scale, segmented=segmented),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, bq, d), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(*args)
    return out.reshape(b, h, sq, d)
