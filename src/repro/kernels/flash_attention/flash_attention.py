"""Pallas TPU kernel: flash attention forward (causal/window/softcap, GQA).

Grid: (batch * kv_heads * rep, Sq/bq) outer; the kernel loops over KV tiles
with ``jax.lax.fori_loop`` keeping the online-softmax state (m, l, acc) in
VMEM. Unlike the pure-JAX path (models/attention.py — also the oracle),
fully-masked KV tiles ahead of the causal frontier are *skipped* via the
loop upper bound, recovering the ~2x causal-waste the dry-run roofline
charges the XLA path for (EXPERIMENTS.md §Perf).

q: (B, H, Sq, d), k/v: (B, KV, Skv, d) — head-major layout so each grid row
streams one head's tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bkv, skv, causal, window,
            softcap, scale):
    iq = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # (bq, d)
    d = q.shape[-1]
    qpos = iq * bq + jax.lax.iota(jnp.int32, bq)

    n_kv = skv // bkv
    if causal:
        # skip tiles strictly above the causal frontier
        hi = jnp.minimum(((iq + 1) * bq + bkv - 1) // bkv, n_kv)
    else:
        hi = n_kv

    def body(ik, carry):
        acc, m_i, l_i = carry
        kt = pl.load(k_ref, (pl.dslice(ik * bkv, bkv), slice(None)))
        vt = pl.load(v_ref, (pl.dslice(ik * bkv, bkv), slice(None)))
        s = jnp.dot(q, kt.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)  # (bq, bkv)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = ik * bkv + jax.lax.iota(jnp.int32, bkv)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=-1)
        pv = jnp.dot(p.astype(vt.dtype), vt,
                     preferred_element_type=jnp.float32)
        acc = acc * corr[:, None] + pv
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, hi, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bkv", "interpret"))
def flash_attention_fwd(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale=None, bq=128, bkv=128, interpret=False):
    """q: (B, H, Sq, d); k/v: (B, KV, Skv, d). Returns (B, H, Sq, d)."""
    b, h, sq, d = q.shape
    kv, skv = k.shape[1], k.shape[2]
    rep = h // kv
    scale = float(scale if scale is not None else d ** -0.5)
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0

    qr = q.reshape(b * h, sq, d)
    kr = jnp.repeat(k, rep, axis=1).reshape(b * h, skv, d)
    vr = jnp.repeat(v, rep, axis=1).reshape(b * h, skv, d)

    grid = (b * h, sq // bq)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bkv=bkv, skv=skv, causal=causal,
                          window=int(window), softcap=float(softcap),
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda g, i: (g, i, 0)),
            pl.BlockSpec((None, skv, d), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((None, skv, d), lambda g, i: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)
