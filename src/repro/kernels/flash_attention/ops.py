"""Dispatch wrapper: Pallas kernel on TPU, jnp reference elsewhere."""
from __future__ import annotations

import jax

from .flash_attention import flash_attention_fwd
from .ref import flash_attention_ref


def attention(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
              use_kernel=None, interpret=None):
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_kernel:
        return flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   interpret=interpret)
    return flash_attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale)
