"""Dispatch wrapper: Pallas kernel on TPU, jnp reference elsewhere."""
from __future__ import annotations

import jax

from .flash_attention import flash_attention_fwd
from .ref import flash_attention_ref, flash_attention_segmented_ref


def attention(q, k, v, *, q_segs=None, kv_segs=None, causal=True, window=0,
              softcap=0.0, scale=None, use_kernel=None, interpret=None):
    if (q_segs is None) != (kv_segs is None):
        raise ValueError("pass both q_segs and kv_segs, or neither")
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_kernel:
        return flash_attention_fwd(q, k, v, q_segs, kv_segs, causal=causal,
                                   window=window, softcap=softcap,
                                   scale=scale, interpret=interpret)
    if q_segs is not None:
        return flash_attention_segmented_ref(q, k, v, q_segs, kv_segs,
                                             causal=causal, window=window,
                                             softcap=softcap, scale=scale)
    return flash_attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale)
