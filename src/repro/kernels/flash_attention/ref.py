"""Oracle for the Pallas flash-attention kernel: the pure-jnp chunked
implementation from models/attention.py (itself validated against naive
softmax attention in tests/test_attention.py), adapted to head-major layout.

The segmented variant (packed ragged prefill) is a separate forward-only
full-softmax oracle: the chunked custom-VJP path stays untouched, and a
naive masked softmax is the clearest possible statement of the semantics
the kernel must match — cross-segment weights exactly zero, all-masked
(pad) rows exactly zero output.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...models.attention import flash_attention as _fa

_NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale=None):
    """q: (B, H, Sq, d); k/v: (B, KV, Skv, d) — head-major like the kernel."""
    out = _fa(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
              jnp.moveaxis(v, 1, 2), causal=causal, window=window,
              softcap=softcap, scale=scale)
    return jnp.moveaxis(out, 1, 2)


def flash_attention_segmented_ref(q, k, v, q_segs, kv_segs, *, causal=True,
                                  window=0, softcap=0.0, scale=None):
    """Forward-only full-softmax oracle with segment masking.

    q: (B, H, Sq, d); k/v: (B, KV, Skv, d); q_segs/kv_segs: (B, Sq)/(B, Skv)
    int32 segment ids (use -1 for pads). Attention weight between tokens of
    different segments is exactly zero; a row with no eligible key (a pad)
    returns exactly zero.
    """
    b, h, sq, d = q.shape
    kv = k.shape[1]
    rep = h // kv
    scale = float(scale if scale is not None else d ** -0.5)
    kr = jnp.repeat(k, rep, axis=1)
    vr = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   kr.astype(jnp.float32))
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)
    kpos = jnp.arange(k.shape[2])
    qs = jnp.asarray(q_segs, jnp.int32)[:, None, :, None]
    ks = jnp.asarray(kv_segs, jnp.int32)[:, None, None, :]
    mask = (qs == ks) & (qs >= 0)    # pads (-1) never attend, even pads
    if causal:
        mask &= kpos[None, None, None, :] <= qpos[None, None, :, None]
    if window > 0:
        mask &= kpos[None, None, None, :] > qpos[None, None, :, None] - window
    s = jnp.where(mask, s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return (out / jnp.maximum(l, 1e-30)).astype(q.dtype)
