"""Oracle for the Pallas flash-attention kernel: the pure-jnp chunked
implementation from models/attention.py (itself validated against naive
softmax attention in tests/test_attention.py), adapted to head-major layout.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...models.attention import flash_attention as _fa


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale=None):
    """q: (B, H, Sq, d); k/v: (B, KV, Skv, d) — head-major like the kernel."""
    out = _fa(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
              jnp.moveaxis(v, 1, 2), causal=causal, window=window,
              softcap=softcap, scale=scale)
    return jnp.moveaxis(out, 1, 2)
