"""Oracle for the Pallas paged-attention kernel: the pure-jnp gather
implementation from models/attention.py (itself validated against dense
flash/decode attention in tests/test_paged_attention.py), restricted to the
kernel's single-query-token decode case.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...models.attention import paged_attention as _pa


def paged_attention_decode_ref(q, k_pool, v_pool, block_tables, kv_lens, *,
                               softcap=0.0, scale=None):
    """q: (B, H, d) — one decode token per sequence. Returns (B, H, d)."""
    q_pos = (kv_lens - 1).reshape(-1, 1).astype(jnp.int32)
    out = _pa(q[:, None], k_pool, v_pool, block_tables, q_pos,
              kv_lens.astype(jnp.int32), softcap=softcap, scale=scale)
    return out[:, 0]
