"""Oracle for the Pallas paged-attention kernel: the pure-jnp gather
implementation from models/attention.py (itself validated against dense
flash/decode attention in tests/test_paged_attention.py), restricted to the
kernel's single-query-token decode case.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...models.attention import paged_attention as _pa


def paged_attention_decode_ref(q, k_pool, v_pool, block_tables, kv_lens, *,
                               softcap=0.0, scale=None):
    """q: (B, H, d) — one decode token per sequence. Returns (B, H, d)."""
    q_pos = (kv_lens - 1).reshape(-1, 1).astype(jnp.int32)
    out = _pa(q[:, None], k_pool, v_pool, block_tables, q_pos,
              kv_lens.astype(jnp.int32), softcap=softcap, scale=scale)
    return out[:, 0]


def paged_attention_decode_quant_ref(q, k_codes, k_scales, v_codes, v_scales,
                                     k_hot, v_hot, block_tables, kv_lens,
                                     hot_rows, *, kv_bits, softcap=0.0,
                                     scale=None):
    """Oracle for the fused-dequant kernel: gather + dequantize via
    models.attention.paged_attention_quant (same codec math, materialized).
    ``hot_rows`` (B,) = slot + 1 (0 = scratch), matching the kernel."""
    from ...models.attention import paged_attention_quant as _paq
    cache = {"k_codes": k_codes, "k_scales": k_scales, "v_codes": v_codes,
             "v_scales": v_scales, "k_hot": k_hot, "v_hot": v_hot}
    q_pos = (kv_lens - 1).reshape(-1, 1).astype(jnp.int32)
    slots = hot_rows.astype(jnp.int32) - 1
    out = _paq(q[:, None], cache, block_tables, q_pos,
               kv_lens.astype(jnp.int32), slots, kv_bits,
               softcap=softcap, scale=scale)
    return out[:, 0]
