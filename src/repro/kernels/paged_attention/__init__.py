from .ops import paged_attention, paged_attention_kv_quant
from .paged_attention import (paged_attention_decode,
                              paged_attention_decode_quant)
from .ref import (paged_attention_decode_quant_ref,
                  paged_attention_decode_ref)

__all__ = ["paged_attention", "paged_attention_decode",
           "paged_attention_decode_ref", "paged_attention_kv_quant",
           "paged_attention_decode_quant", "paged_attention_decode_quant_ref"]
