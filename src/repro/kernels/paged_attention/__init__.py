from .ops import paged_attention
from .paged_attention import paged_attention_decode
from .ref import paged_attention_decode_ref

__all__ = ["paged_attention", "paged_attention_decode",
           "paged_attention_decode_ref"]
