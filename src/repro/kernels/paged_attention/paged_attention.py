"""Pallas TPU kernel: paged-attention decode (one query token per sequence).

Grid: (batch, kv_heads) — each cell handles one sequence's ``rep`` query
heads for one KV head. The kernel walks the sequence's block table with
``jax.lax.fori_loop`` (dynamic trip count = pages actually used, so short
sequences skip dead pool pages entirely), loading one (page_size, d) K/V
tile per iteration straight from the global pool with a dynamic page index
— the gather the pure-JAX reference (models.attention.paged_attention)
materializes as a (B, S_max, KV, d) copy never exists here.

Layout note: the pool keeps its natural (n_pages, page_size, KV, d) layout;
the BlockSpec collapses the KV dim per grid cell so each cell streams only
its own head's tiles. This is the serving-path stub: correctness-validated
in interpret mode on CPU (tests/test_paged_attention.py); real-TPU tile
tuning (page_size multiples of the 128-lane register, scalar-prefetched
block tables via ``pltpu.PrefetchScalarGridSpec``) is a recorded follow-up
in DESIGN.md §8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -2.0e38


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, *, ps, softcap,
            scale):
    q = q_ref[...].astype(jnp.float32) * scale            # (rep, d)
    rep, d = q.shape
    kv_len = len_ref[0]
    n_used = (kv_len + ps - 1) // ps                       # dynamic bound

    def body(j, carry):
        acc, m_i, l_i = carry
        page = bt_ref[j]
        kt = pl.load(k_ref, (pl.dslice(page, 1), slice(None),
                             slice(None)))[0]              # (ps, d)
        vt = pl.load(v_ref, (pl.dslice(page, 1), slice(None), slice(None)))[0]
        s = jnp.dot(q, kt.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)    # (rep, ps)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * ps + jax.lax.iota(jnp.int32, ps)
        s = jnp.where((kpos < kv_len)[None, :], s, _NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=-1)
        pv = jnp.dot(p.astype(vt.dtype), vt,
                     preferred_element_type=jnp.float32)
        acc = acc * corr[:, None] + pv
        return acc, m_new, l_new

    acc0 = jnp.zeros((rep, d), jnp.float32)
    m0 = jnp.full((rep,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((rep,), jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, n_used, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "scale", "interpret"))
def paged_attention_decode(q, k_pool, v_pool, block_tables, kv_lens, *,
                           softcap=0.0, scale=None, interpret=False):
    """q: (B, H, d); pools: (n_pages, page_size, KV, d); block_tables:
    (B, max_pages) int32; kv_lens: (B,) int32. Returns (B, H, d)."""
    b, h, d = q.shape
    n_pages, ps, kv, _ = k_pool.shape
    rep = h // kv
    scale = float(scale if scale is not None else d ** -0.5)
    qr = q.reshape(b, kv, rep, d)
    lens2d = kv_lens.reshape(b, 1).astype(jnp.int32)
    mp = block_tables.shape[1]

    grid = (b, kv)
    out = pl.pallas_call(
        functools.partial(_kernel, ps=ps, softcap=float(softcap),
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, mp), lambda i, j: (i, 0)),           # tables
            pl.BlockSpec((None, 1), lambda i, j: (i, 0)),            # lens
            pl.BlockSpec((None, None, rep, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((n_pages, ps, None, d), lambda i, j: (0, 0, j, 0)),
            pl.BlockSpec((n_pages, ps, None, d), lambda i, j: (0, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, rep, d),
                               lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, rep, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lens2d, qr, k_pool, v_pool)
    return out.reshape(b, h, d)
