"""Pallas TPU kernel: paged-attention decode (one query token per sequence).

Grid: (batch, kv_heads) — each cell handles one sequence's ``rep`` query
heads for one KV head. The kernel walks the sequence's block table with
``jax.lax.fori_loop`` (dynamic trip count = pages actually used, so short
sequences skip dead pool pages entirely), loading one (page_size, d) K/V
tile per iteration straight from the global pool with a dynamic page index
— the gather the pure-JAX reference (models.attention.paged_attention)
materializes as a (B, S_max, KV, d) copy never exists here.

Layout note: the pool keeps its natural (n_pages, page_size, KV, d) layout;
the BlockSpec collapses the KV dim per grid cell so each cell streams only
its own head's tiles. This is the serving-path stub: correctness-validated
in interpret mode on CPU (tests/test_paged_attention.py); real-TPU tile
tuning (page_size multiples of the 128-lane register, scalar-prefetched
block tables via ``pltpu.PrefetchScalarGridSpec``) is a recorded follow-up
in DESIGN.md §8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -2.0e38


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, *, ps, softcap,
            scale):
    q = q_ref[...].astype(jnp.float32) * scale            # (rep, d)
    rep, d = q.shape
    kv_len = len_ref[0]
    n_used = (kv_len + ps - 1) // ps                       # dynamic bound

    def body(j, carry):
        acc, m_i, l_i = carry
        page = bt_ref[j]
        kt = pl.load(k_ref, (pl.dslice(page, 1), slice(None),
                             slice(None)))[0]              # (ps, d)
        vt = pl.load(v_ref, (pl.dslice(page, 1), slice(None), slice(None)))[0]
        s = jnp.dot(q, kt.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)    # (rep, ps)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * ps + jax.lax.iota(jnp.int32, ps)
        s = jnp.where((kpos < kv_len)[None, :], s, _NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=-1)
        pv = jnp.dot(p.astype(vt.dtype), vt,
                     preferred_element_type=jnp.float32)
        acc = acc * corr[:, None] + pv
        return acc, m_new, l_new

    acc0 = jnp.zeros((rep, d), jnp.float32)
    m0 = jnp.full((rep,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((rep,), jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, n_used, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


def _dequant_tile(codes, scales, kv_bits, ps, hd):
    """In-register MSB dequant of one page tile.

    codes: (ps, hd) int8 (8-bit) or (ps, hd//2) uint8 packed nibbles
    (4-bit); scales: (n_blocks, G) — the page's codebook rows. Returns
    (ps, hd) f32. Mirrors core.quantize.kv_dequantize_pages exactly, so
    kernel and oracle agree bit-for-bit.
    """
    from ...core.quantize import _kv_tokens_per_block
    tpb = _kv_tokens_per_block(ps, hd)
    tok_block = jax.lax.iota(jnp.int32, ps) // tpb          # (ps,)
    srows = jnp.take(scales.astype(jnp.float32), tok_block, axis=0)
    if kv_bits == 8:
        return codes.astype(jnp.float32) * (srows / 127.0)  # srows (ps, 1)
    p32 = codes.astype(jnp.int32)
    nib = jnp.stack([p32 & 0xF, (p32 >> 4) & 0xF],
                    axis=-1).reshape(ps, hd)
    level = nib & 0x7
    sign = (1 - 2 * ((nib >> 3) & 1)).astype(jnp.float32)
    mag = jnp.take_along_axis(srows, level, axis=-1)        # (ps, hd)
    return sign * mag


def _quant_kernel(bt_ref, len_ref, row_ref, q_ref, kc_ref, ks_ref, vc_ref,
                  vs_ref, kh_ref, vh_ref, o_ref, *, ps, hd, kv_bits, softcap,
                  scale):
    q = q_ref[...].astype(jnp.float32) * scale               # (rep, d)
    rep, d = q.shape
    kv_len = len_ref[0]
    hot_row = row_ref[0]
    n_full = kv_len // ps                                    # committed pages
    n_used = (kv_len + ps - 1) // ps

    def body(j, carry):
        acc, m_i, l_i = carry
        page = bt_ref[j]
        kt_q = _dequant_tile(
            pl.load(kc_ref, (pl.dslice(page, 1), slice(None),
                             slice(None)))[0],
            pl.load(ks_ref, (pl.dslice(page, 1), slice(None),
                             slice(None)))[0], kv_bits, ps, hd)
        vt_q = _dequant_tile(
            pl.load(vc_ref, (pl.dslice(page, 1), slice(None),
                             slice(None)))[0],
            pl.load(vs_ref, (pl.dslice(page, 1), slice(None),
                             slice(None)))[0], kv_bits, ps, hd)
        kh = pl.load(kh_ref, (pl.dslice(hot_row, 1), slice(None),
                              slice(None)))[0].astype(jnp.float32)
        vh = pl.load(vh_ref, (pl.dslice(hot_row, 1), slice(None),
                              slice(None)))[0].astype(jnp.float32)
        # page j == n_full is the partial frontier page: full precision
        # from the hot row; committed pages stream dequantized
        is_tail = j >= n_full
        kt = jnp.where(is_tail, kh, kt_q)
        vt = jnp.where(is_tail, vh, vt_q)
        s = jnp.dot(q, kt.T, preferred_element_type=jnp.float32)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * ps + jax.lax.iota(jnp.int32, ps)
        s = jnp.where((kpos < kv_len)[None, :], s, _NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=-1)
        pv = jnp.dot(p, vt, preferred_element_type=jnp.float32)
        acc = acc * corr[:, None] + pv
        return acc, m_new, l_new

    acc0 = jnp.zeros((rep, d), jnp.float32)
    m0 = jnp.full((rep,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((rep,), jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, n_used, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kv_bits", "softcap", "scale",
                                             "interpret"))
def paged_attention_decode_quant(q, k_codes, k_scales, v_codes, v_scales,
                                 k_hot, v_hot, block_tables, kv_lens,
                                 hot_rows, *, kv_bits, softcap=0.0,
                                 scale=None, interpret=False):
    """Fused dequant paged-attention decode over quantized pools.

    q: (B, H, d); k_codes/v_codes: (n_pages, ps, KV, hd or hd//2);
    k_scales/v_scales: (n_pages, KV, n_blocks, G); k_hot/v_hot:
    (n_hot, ps, KV, hd) full-precision partial pages; block_tables:
    (B, max_pages) int32; kv_lens: (B,) int32; hot_rows: (B,) int32 — the
    row of the hot pool holding each sequence's partial page (slot + 1;
    0 = scratch). Returns (B, H, d).

    Committed pages stream through the MSB dequant *in-kernel* — the bf16
    copy of the pool the jnp oracle materializes never exists here. The
    frontier page (j == kv_len // ps) reads the hot row instead.
    """
    b, h, d = q.shape
    n_pages, ps, kv = k_codes.shape[:3]
    nb, g = k_scales.shape[2], k_scales.shape[3]
    n_hot = k_hot.shape[0]
    rep = h // kv
    scale = float(scale if scale is not None else d ** -0.5)
    qr = q.reshape(b, kv, rep, d)
    lens2d = kv_lens.reshape(b, 1).astype(jnp.int32)
    rows2d = hot_rows.reshape(b, 1).astype(jnp.int32)
    mp = block_tables.shape[1]
    hdc = k_codes.shape[3]

    grid = (b, kv)
    out = pl.pallas_call(
        functools.partial(_quant_kernel, ps=ps, hd=d, kv_bits=kv_bits,
                          softcap=float(softcap), scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, mp), lambda i, j: (i, 0)),           # tables
            pl.BlockSpec((None, 1), lambda i, j: (i, 0)),            # lens
            pl.BlockSpec((None, 1), lambda i, j: (i, 0)),            # hot rows
            pl.BlockSpec((None, None, rep, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((n_pages, ps, None, hdc), lambda i, j: (0, 0, j, 0)),
            pl.BlockSpec((n_pages, None, nb, g), lambda i, j: (0, j, 0, 0)),
            pl.BlockSpec((n_pages, ps, None, hdc), lambda i, j: (0, 0, j, 0)),
            pl.BlockSpec((n_pages, None, nb, g), lambda i, j: (0, j, 0, 0)),
            pl.BlockSpec((n_hot, ps, None, d), lambda i, j: (0, 0, j, 0)),
            pl.BlockSpec((n_hot, ps, None, d), lambda i, j: (0, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, rep, d),
                               lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, rep, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lens2d, rows2d, qr, k_codes, k_scales,
      v_codes, v_scales, k_hot, v_hot)
    return out.reshape(b, h, d)


@functools.partial(jax.jit, static_argnames=("softcap", "scale", "interpret"))
def paged_attention_decode(q, k_pool, v_pool, block_tables, kv_lens, *,
                           softcap=0.0, scale=None, interpret=False):
    """q: (B, H, d); pools: (n_pages, page_size, KV, d); block_tables:
    (B, max_pages) int32; kv_lens: (B,) int32. Returns (B, H, d)."""
    b, h, d = q.shape
    n_pages, ps, kv, _ = k_pool.shape
    rep = h // kv
    scale = float(scale if scale is not None else d ** -0.5)
    qr = q.reshape(b, kv, rep, d)
    lens2d = kv_lens.reshape(b, 1).astype(jnp.int32)
    mp = block_tables.shape[1]

    grid = (b, kv)
    out = pl.pallas_call(
        functools.partial(_kernel, ps=ps, softcap=float(softcap),
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, mp), lambda i, j: (i, 0)),           # tables
            pl.BlockSpec((None, 1), lambda i, j: (i, 0)),            # lens
            pl.BlockSpec((None, None, rep, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((n_pages, ps, None, d), lambda i, j: (0, 0, j, 0)),
            pl.BlockSpec((n_pages, ps, None, d), lambda i, j: (0, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, rep, d),
                               lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, rep, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lens2d, qr, k_pool, v_pool)
    return out.reshape(b, h, d)
