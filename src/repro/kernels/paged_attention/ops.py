"""Dispatch wrapper: Pallas kernel on TPU, jnp reference elsewhere.

Shapes are shard-local by construction: under tensor parallelism the
caller passes q with H/tp heads and pools with KV/tp heads (the page dim
and block tables are replicated), and both the kernel and the reference
compute exactly the local heads' output — paged attention needs no
collectives, the surrounding projections do (DESIGN.md Sec. 10).
"""
from __future__ import annotations

import jax

from .paged_attention import (paged_attention_decode,
                              paged_attention_decode_quant)
from .ref import (paged_attention_decode_quant_ref,
                  paged_attention_decode_ref)


def paged_attention(q, k_pool, v_pool, block_tables, kv_lens, *, softcap=0.0,
                    scale=None, use_kernel=None, interpret=None):
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_kernel:
        return paged_attention_decode(q, k_pool, v_pool, block_tables,
                                      kv_lens, softcap=softcap, scale=scale,
                                      interpret=interpret)
    return paged_attention_decode_ref(q, k_pool, v_pool, block_tables,
                                      kv_lens, softcap=softcap, scale=scale)


def paged_attention_kv_quant(q, k_codes, k_scales, v_codes, v_scales, k_hot,
                             v_hot, block_tables, kv_lens, hot_rows, *,
                             kv_bits, softcap=0.0, scale=None,
                             use_kernel=None, interpret=None):
    """Fused-dequant paged-attention decode over MSB-quantized pools
    (kv_bits 8|4): Pallas kernel on TPU, jnp gather+dequant oracle
    elsewhere. See paged_attention_decode_quant for the argument layout."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_kernel:
        return paged_attention_decode_quant(
            q, k_codes, k_scales, v_codes, v_scales, k_hot, v_hot,
            block_tables, kv_lens, hot_rows, kv_bits=kv_bits,
            softcap=softcap, scale=scale, interpret=interpret)
    return paged_attention_decode_quant_ref(
        q, k_codes, k_scales, v_codes, v_scales, k_hot, v_hot, block_tables,
        kv_lens, hot_rows, kv_bits=kv_bits, softcap=softcap, scale=scale)
