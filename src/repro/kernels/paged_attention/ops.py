"""Dispatch wrapper: Pallas kernel on TPU, jnp reference elsewhere.

Shapes are shard-local by construction: under tensor parallelism the
caller passes q with H/tp heads and pools with KV/tp heads (the page dim
and block tables are replicated), and both the kernel and the reference
compute exactly the local heads' output — paged attention needs no
collectives, the surrounding projections do (DESIGN.md Sec. 10).
"""
from __future__ import annotations

import jax

from .paged_attention import paged_attention_decode
from .ref import paged_attention_decode_ref


def paged_attention(q, k_pool, v_pool, block_tables, kv_lens, *, softcap=0.0,
                    scale=None, use_kernel=None, interpret=None):
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_kernel:
        return paged_attention_decode(q, k_pool, v_pool, block_tables,
                                      kv_lens, softcap=softcap, scale=scale,
                                      interpret=interpret)
    return paged_attention_decode_ref(q, k_pool, v_pool, block_tables,
                                      kv_lens, softcap=softcap, scale=scale)
