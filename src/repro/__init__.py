"""repro — MSB dynamic-grouping quantization at pod scale (see README.md)."""

__version__ = "1.0.0"
