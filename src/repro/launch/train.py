"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --shape train_4k --steps 100 [--smoke] [--mesh host|single|multi]

--smoke uses the reduced config + a host mesh so the launcher is runnable
on CPU; the production meshes need real hardware (the dry-run proves the
program compiles for them). Fault tolerance is on: periodic checkpoints,
auto-resume, SIGTERM drain, straggler monitoring.
"""
import argparse

import jax

from ..configs import SHAPES, get_config, smoke_config
from ..data import MarkovStream, Prefetcher
from ..models import Model
from ..parallel import from_mesh, tree_shardings
from ..train import (AdamW, Checkpointer, OptConfig, PreemptionHandler,
                     StragglerMonitor, train_loop)
from .mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = SHAPES[args.shape]
    model = Model(cfg)
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    ctx = from_mesh(mesh)

    batch = min(shape.global_batch, 8) if args.smoke else shape.global_batch
    seq = min(shape.seq_len, 64) if args.smoke else shape.seq_len
    data = Prefetcher(iter(MarkovStream(cfg.vocab_size, seq, batch, seed=0)))

    opt = AdamW(OptConfig(
        lr=args.lr, total_steps=args.steps,
        moment_dtype="int8" if cfg.n_params() > 100e9 else "f32"))
    handler = PreemptionHandler()
    mon = StragglerMonitor()
    with mesh:
        state, metrics = train_loop(
            model, opt, data, steps=args.steps, rng=jax.random.PRNGKey(0),
            parallel=ctx, checkpointer=Checkpointer(args.ckpt_dir),
            checkpoint_every=args.checkpoint_every,
            straggler_monitor=mon, should_stop=handler.should_stop)
    print(f"[launch.train] done; final loss {float(metrics['loss']):.4f}, "
          f"median step {mon.median * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
