"""Loop-aware cost model over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts every computation **once** — a
``while`` body executed L times (our scan-over-layers, chunked attention,
chunked CE, SSM chunk scans) is charged 1/L of its true cost. This module
re-derives FLOPs / HBM bytes / collective wire-bytes from the compiled HLO
*with loop multipliers*:

  1. parse the module into computations + instructions (result type, opcode,
     operands, called computations, dot dims, replica groups);
  2. read each while loop's trip count from its recorded
     ``backend_config.known_trip_count`` (fallback: the constant bound in
     the condition computation);
  3. fold costs bottom-up: cost(comp) = sum(inst) + trip * cost(body).

FLOPs: dot/convolution terms (2 * prod(result) * contracted) + elementwise
(1 flop/output element). Bytes: operands + results of materialized (top-
level, non-fusion-internal) instructions — post-fusion HLO means each such
instruction is an HBM round trip. Collectives: per-op wire factors as in
roofline.py. Validated against analytic 6ND in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([\d,]*)\]")
_INST_RE = re.compile(
    r"^\s*(?P<root>ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^)]*\)|[a-z]+\d*\[[\d,]*\]"
    r"(?:\{[^}]*\})?)\s*(?P<op>[\w\-]+)\((?P<rest>.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"')
_CALLED_RE = re.compile(
    r"(?:to_apply=|body=|condition=|calls=|called_computations=\{)"
    r"%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=(?:\[(\d+),(\d+)\]|\{\{([\d,]+)\})")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_info(type_str):
    """(total_elems, total_bytes, dims_of_first_array)."""
    elems, byts, first = 0, 0, None
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = math.prod(dims) if dims else 1
        elems += n
        byts += n * _DTYPE_BYTES[dt]
        if first is None:
            first = dims
    return elems, byts, first if first is not None else []


@dataclasses.dataclass
class Inst:
    name: str
    op: str
    out_elems: int
    out_bytes: int
    out_dims: list
    operands: List[str]
    called: List[str]
    flops: float
    group_size: int
    line: str
    is_root: bool = False


@dataclasses.dataclass
class Comp:
    name: str
    insts: List[Inst]
    is_fusion_body: bool = False


_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "tanh", "negate", "abs", "compare", "select", "and", "or", "xor", "power",
    "log", "rsqrt", "sqrt", "convert", "sign", "floor", "clamp",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_FLOP_REDUCE = {"reduce", "reduce-window"}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_module(text: str) -> Dict[str, Comp]:
    comps: Dict[str, Comp] = {}
    cur: Optional[Comp] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line.strip()) if line.strip().endswith("{") else None
        if mc:
            cur = Comp(mc.group(1), [])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INST_RE.match(line)
        if not mi:
            continue
        name, type_str, op = mi.group("name"), mi.group("type"), mi.group("op")
        rest = mi.group("rest")
        out_elems, out_bytes, out_dims = _shape_info(type_str)
        close = rest.find(")")
        operands = re.findall(r"%([\w.\-]+)",
                              rest[:close] if close >= 0 else rest)
        called = _CALLED_RE.findall(rest)
        gm = _GROUPS_RE.search(rest)
        if gm:
            gsize = int(gm.group(2)) if gm.group(2) else \
                len(gm.group(3).split(","))
        else:
            gsize = 0
        flops = 0.0
        if op in ("dot", "convolution"):
            # flops = 2 * prod(result dims) * contracted-dim product
            dd = _DOT_DIMS_RE.search(rest)
            contracted = 1
            if dd is not None and dd.group(1):
                # operand shapes resolved in the fold pass (need symbol table)
                contracted = -1   # marker: resolve later
            flops = -1.0 if contracted == -1 else 2.0 * out_elems
        elif op in _ELEMENTWISE or op in _FLOP_REDUCE:
            flops = float(out_elems)
        cur.insts.append(Inst(name, op, out_elems, out_bytes, out_dims,
                              operands, called, flops, gsize, line,
                              is_root=bool(mi.group("root"))))
    return comps


def _resolve_dot_flops(comp: Comp, symtab: Dict[str, Inst]):
    for inst in comp.insts:
        if inst.flops == -1.0:
            dd = _DOT_DIMS_RE.search(inst.line)
            contracted = 1
            if dd and dd.group(1) and inst.operands:
                lhs = symtab.get(inst.operands[0])
                if lhs is not None and lhs.out_dims:
                    for ax in (int(a) for a in dd.group(1).split(",") if a):
                        if ax < len(lhs.out_dims):
                            contracted *= lhs.out_dims[ax]
            inst.flops = 2.0 * inst.out_elems * max(contracted, 1)


def _trip_count(cond: Comp) -> int:
    """Largest integer constant in the loop condition ~ the trip bound."""
    best = 1
    for inst in cond.insts:
        if inst.op == "constant":
            m = re.search(r"constant\((\d+)\)", inst.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, dict] = dataclasses.field(default_factory=dict)

    def add(self, other, mult=1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for op, rec in other.coll.items():
            mine = self.coll.setdefault(
                op, {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0})
            for k in mine:
                mine[k] += rec[k] * mult

    @property
    def wire_bytes(self):
        return sum(r["wire_bytes"] for r in self.coll.values())


def _wire(op, size, n):
    n = max(n, 2)
    if op == "all-reduce":
        return 2.0 * size * (n - 1) / n
    if op == "all-gather":
        return size * (n - 1) / n
    if op == "reduce-scatter":
        return float(size) * (n - 1)
    if op == "all-to-all":
        return size * (n - 1) / n
    return float(size)


def analyze(text: str) -> Cost:
    comps = parse_module(text)
    symtab: Dict[str, Inst] = {}
    for c in comps.values():
        for inst in c.insts:
            symtab[inst.name] = inst
    for c in comps.values():
        _resolve_dot_flops(c, symtab)

    # identify fusion bodies (called via `fusion` op kind=...) — their
    # interior doesn't touch HBM; flops still count, bytes don't
    fusion_bodies = set()
    for c in comps.values():
        for inst in c.insts:
            if inst.op == "fusion":
                fusion_bodies.update(inst.called)

    # in-place accumulator fusions: root is a dynamic-update-slice writing a
    # slice into a loop-carried buffer. Real HBM traffic = the update slice,
    # not the (aliased) full buffer the HLO type shows.
    dus_update_bytes: Dict[str, int] = {}
    for c in comps.values():
        if not c.insts:
            continue
        local = {i.name: i for i in c.insts}
        root = next((i for i in c.insts if i.is_root), c.insts[-1])

        def _dus_bytes(inst):
            if inst.op == "dynamic-update-slice" and len(inst.operands) >= 2:
                upd = local.get(inst.operands[1])
                return upd.out_bytes if upd is not None else inst.out_bytes
            return None

        b = _dus_bytes(root)
        if b is not None:
            dus_update_bytes[c.name] = b
        elif root.op == "tuple":
            # multi-output accumulator fusion: sum DUS update sizes +
            # full sizes of the non-DUS outputs
            total, any_dus = 0, False
            for oname in root.operands:
                oin = local.get(oname)
                if oin is None:
                    continue
                ob = _dus_bytes(oin)
                if ob is not None:
                    any_dus = True
                    total += ob
                else:
                    total += oin.out_bytes
            if any_dus:
                dus_update_bytes[c.name] = total

    memo: Dict[Tuple[str, bool], Cost] = {}

    def fold(name: str, in_fusion: bool) -> Cost:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        cost = Cost()
        memo[key] = cost
        if comp is None:
            return cost
        for inst in comp.insts:
            if inst.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", inst.line)
                mt = _TRIP_RE.search(inst.line)
                if mt:
                    trip = int(mt.group(1))
                else:
                    mcnd = re.search(r"condition=%?([\w.\-]+)", inst.line)
                    trip = (_trip_count(comps[mcnd.group(1)])
                            if mcnd and mcnd.group(1) in comps else 1)
                if mb:
                    cost.add(fold(mb.group(1), in_fusion), mult=trip)
                continue
            if inst.op in ("fusion", "call", "custom-call", "map",
                           "conditional", "sort", "reduce", "scatter",
                           "select-and-scatter", "reduce-window"):
                for sub in inst.called:
                    cost.add(fold(sub, in_fusion or inst.op == "fusion"))
            if inst.op in _COLLECTIVES or any(
                    inst.op == c + "-start" for c in _COLLECTIVES):
                base = inst.op.replace("-start", "")
                size = inst.out_bytes
                rec = cost.coll.setdefault(
                    base, {"count": 0.0, "result_bytes": 0.0,
                           "wire_bytes": 0.0})
                rec["count"] += 1
                rec["result_bytes"] += size
                rec["wire_bytes"] += _wire(base, size, inst.group_size)
            cost.flops += max(inst.flops, 0.0)
            if not in_fusion and inst.op not in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast"):
                out_bytes = inst.out_bytes
                if inst.op in ("fusion", "dynamic-update-slice"):
                    upd = (dus_update_bytes.get(inst.called[0])
                           if inst.op == "fusion" and inst.called else None)
                    if inst.op == "dynamic-update-slice" and \
                            len(inst.operands) >= 2:
                        upd = symtab[inst.operands[1]].out_bytes \
                            if inst.operands[1] in symtab else None
                    if upd is not None:
                        out_bytes = upd   # in-place slice write
                opnd_bytes = sum(symtab[o].out_bytes for o in inst.operands
                                 if o in symtab)
                if inst.op in ("fusion", "dynamic-update-slice",
                               "dynamic-slice"):
                    # fusions typically *slice* big operands (loop-carried
                    # buffers) — charge the streamed volume, not the buffer
                    opnd_bytes = min(opnd_bytes, 3 * out_bytes)
                cost.bytes += out_bytes + opnd_bytes
        return cost

    # the ENTRY computation is conventionally named *main*; fall back to the
    # last computation in the module
    names = list(comps)
    entry = next((n for n in names if "main" in n), names[-1])
    return fold(entry, False)
