"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (assignment formulas):

    compute    = HLO_FLOPs / (chips * 197e12)
    memory     = HLO_bytes / (chips * 819e9)
    collective = sum_op collective_bytes(op) / (chips * 50e9)

``compiled.cost_analysis()`` is per-partition (verified empirically), so the
per-chip terms use it directly. Collective bytes are parsed from the
compiled HLO text with per-op wire factors:

    all-reduce          2 (n-1)/n * size
    all-gather          (n-1)/n * output size
    reduce-scatter      (n-1)   * output size     (input = n * output)
    all-to-all          (n-1)/n * size
    collective-permute  1       * size
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?P<types>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+\d*)\[(?P<dims>[\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(?:\[(\d+),(\d+)\]|\{\{([\d,]+)\})")


def _type_bytes(types: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(types):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, dict]:
    """Sum per-op result bytes and wire bytes from compiled HLO."""
    out: Dict[str, dict] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "-done" in line:
            continue
        op = m.group("op")
        size = _type_bytes(m.group("types"))
        gm = _GROUPS_RE.search(line)
        if gm:
            n = int(gm.group(2)) if gm.group(2) else len(gm.group(3).split(","))
        else:
            n = 2
        n = max(n, 2)
        if op == "all-reduce":
            wire = 2.0 * size * (n - 1) / n
        elif op == "all-gather":
            wire = size * (n - 1) / n
        elif op == "reduce-scatter":
            wire = float(size) * (n - 1)
        elif op == "all-to-all":
            wire = size * (n - 1) / n
        else:  # collective-permute
            wire = float(size)
        rec = out.setdefault(op, {"count": 0, "result_bytes": 0,
                                  "wire_bytes": 0.0})
        rec["count"] += 1
        rec["result_bytes"] += size
        rec["wire_bytes"] += wire
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    collectives: Dict[str, dict]
    model_flops_total: float
    chips: int
    xla_flops_per_chip: float = 0.0
    xla_bytes_per_chip: float = 0.0

    @property
    def compute_s(self):
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def memory_s(self):
        return self.bytes_per_chip / HBM_BW

    @property
    def collective_s(self):
        return self.wire_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self):
        """MODEL_FLOPS / HLO_FLOPs — remat/recompute/dispatch waste."""
        hlo_total = self.flops_per_chip * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self):
        """max-term time assuming perfect overlap: useful compute time /
        bound time — the score we hillclimb."""
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        ideal = (self.model_flops_total / self.chips) / PEAK_FLOPS_BF16
        return ideal / bound if bound else 0.0

    def to_dict(self):
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "collectives": self.collectives,
            "model_flops_total": self.model_flops_total,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "xla_flops_per_chip": self.xla_flops_per_chip,
            "xla_bytes_per_chip": self.xla_bytes_per_chip,
        }


def build(compiled, model_flops_total, chips):
    """Loop-aware costs from the compiled HLO (launch/hlo_cost.py).

    XLA's own cost_analysis() charges each while body once (a scan over L
    layers is undercounted Lx); hlo_cost multiplies through the recorded
    known_trip_counts. The raw XLA numbers are kept as `xla_*` cross-checks.
    """
    from . import hlo_cost
    text = compiled.as_text()
    cost = hlo_cost.analyze(text)
    ca = compiled.cost_analysis() or {}
    rl = Roofline(flops_per_chip=cost.flops, bytes_per_chip=cost.bytes,
                  wire_bytes_per_chip=cost.wire_bytes, collectives=cost.coll,
                  model_flops_total=model_flops_total, chips=chips)
    rl.xla_flops_per_chip = float(ca.get("flops", 0.0))
    rl.xla_bytes_per_chip = float(ca.get("bytes accessed", 0.0))
    return rl
