"""Serving launcher: init (or restore) -> MSB-quantize-on-load -> serve.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        [--bits 4] [--ckpt-dir DIR] [--requests 4 --tokens 16]
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import get_config, smoke_config
from ..core import QuantPolicy, param_bits, quantize_params
from ..models import Model
from ..serve import ServeEngine
from ..train import Checkpointer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        restored = ck.restore_latest(like=jax.tree_util.tree_map(
            np.asarray, {"params": params}))
        if restored is not None:
            params = jax.tree_util.tree_map(jnp.asarray,
                                            restored[0]["params"])
            print(f"[launch.serve] restored step {restored[1]}")

    if not args.no_quant:
        bits_before = param_bits(params)
        params, report = quantize_params(params, QuantPolicy(
            bits=args.bits, block=64, solver="dp", min_size=4096))
        print(f"[launch.serve] MSB-{args.bits}b quantized {len(report)} "
              f"tensors: {bits_before / 8e6:.1f} -> "
              f"{param_bits(params) / 8e6:.1f} MB")

    engine = ServeEngine(model, params, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.requests, 8)), jnp.int32)
    out = engine.generate(prompts, n_tokens=args.tokens, temperature=0.8)
    for i, row in enumerate(np.asarray(out)):
        print(f"[launch.serve] request {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
