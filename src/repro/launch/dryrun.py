import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this AOT-compiles the real entry point (train_step /
prefill / decode_step) against ShapeDtypeStruct stand-ins on the production
mesh, prints ``memory_analysis()`` (fits-per-device proof) and
``cost_analysis()`` (FLOPs/bytes), parses the collective schedule out of the
compiled HLO, and writes a JSON artifact consumed by the roofline report
(EXPERIMENTS.md is generated from these artifacts).

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all --skip-existing
  python -m repro.launch.dryrun --arch arctic-480b --shape decode_32k \
      --quantized      # MSB int8-code weights (the paper's technique)
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import SHAPES, cell_is_skipped, get_config, list_archs
from ..core.quantize import QTensor
from ..models import Model
from ..parallel import ParallelContext, from_mesh, tree_shardings
from ..train import AdamW, OptConfig, make_train_step
from . import roofline
from .mesh import make_production_mesh

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

# embed/unembed excluded in *simulation* mode: row-gather doesn't benefit
# from a full-table dequant (the Pallas fused path quantizes them too)
_QUANT_EXCLUDE = ("norm", "bias", "router", "conv_w", "dt_bias", "a_log",
                  "d_skip", "f_bias", "w_rec", "embed")


def _bf16(tree):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 and len(s.shape) >= 2 else s, tree)


def quantize_shapes(shapes, axes, bits=4, block=64):
    """Rewrite weight SDS leaves as QTensor stand-ins (+ matching axes)."""
    def visit(path, sds, ax):
        pstr = "/".join(str(getattr(p, "key", p)) for p in path)
        shape = sds.shape
        if (len(shape) < 2 or shape[-1] % block
                or any(t in pstr for t in _QUANT_EXCLUDE)):
            return sds, ax
        g = 2 ** (bits - 1)
        codes = jax.ShapeDtypeStruct(shape, jnp.int8)
        scales = jax.ShapeDtypeStruct(
            (*shape[:-1], shape[-1] // block, g), jnp.bfloat16)
        q_sds = QTensor(codes, scales, bits, block, jnp.bfloat16)
        q_ax = QTensor(tuple(ax), tuple(ax) + (None,), bits, block,
                       jnp.bfloat16)
        return q_sds, q_ax

    flat_s, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    flat_a = jax.tree_util.tree_flatten(axes, is_leaf=is_axes)[0]
    out_s, out_a = [], []
    for (path, sds), ax in zip(flat_s, flat_a):
        s, a = visit(path, sds, ax)
        out_s.append(s)
        out_a.append(a)
    return (jax.tree_util.tree_unflatten(treedef, out_s),
            jax.tree_util.tree_unflatten(treedef, out_a))


def run_cell(arch, shape_name, multi_pod=False, quantized=False,
             out_dir=ARTIFACT_DIR, verbose=True):
    mesh_name = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + ("__msb4" if quantized else "")
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, tag + ".json")

    skip = cell_is_skipped(arch, shape_name)
    if skip:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": skip}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(f"[dryrun] {tag}: SKIP ({skip})")
        return rec

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = from_mesh(mesh)
    chips = ctx.n_devices
    t0 = time.time()

    pshapes = model.param_shapes()
    paxes = model.param_axes()
    batch_sds, batch_axes = model.input_specs(shape)
    batch_sh = tree_shardings(ctx, batch_axes, batch_sds)
    n_act = cfg.n_active_params()

    with mesh:
        if shape.kind == "train":
            big = cfg.n_params() > 100e9
            opt = AdamW(OptConfig(moment_dtype="int8" if big else "f32"))
            param_dtype = jnp.bfloat16 if big else jnp.float32
            pshapes = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, param_dtype), pshapes)
            opt_sds = jax.eval_shape(opt.init, pshapes)
            state_sds = {"params": pshapes, "opt": opt_sds}
            state_axes_ = {"params": paxes,
                           "opt": opt.moment_axes(paxes, pshapes)}
            state_sh = tree_shardings(ctx, state_axes_, state_sds)
            step_fn = make_train_step(model, opt, ctx)
            jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                             donate_argnums=0)
            lowered = jitted.lower(state_sds, batch_sds)
            model_flops = 6.0 * n_act * shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            pshapes = _bf16(pshapes)
            p_sh = tree_shardings(ctx, paxes, pshapes)
            fn = lambda p, b: model.prefill(p, b, ctx)
            jitted = jax.jit(fn, in_shardings=(p_sh, batch_sh))
            lowered = jitted.lower(pshapes, batch_sds)
            model_flops = 2.0 * n_act * shape.global_batch * shape.seq_len
        else:  # decode
            pshapes = _bf16(pshapes)
            if quantized:
                pshapes, paxes = quantize_shapes(pshapes, paxes)
            p_sh = tree_shardings(ctx, paxes, pshapes)
            fn = lambda p, c, t, pos: model.decode_step(p, c, t, pos, ctx)
            jitted = jax.jit(fn, in_shardings=(
                p_sh, batch_sh["cache"], batch_sh["tokens"],
                batch_sh["cur_pos"]), donate_argnums=1)
            lowered = jitted.lower(pshapes, batch_sds["cache"],
                                   batch_sds["tokens"], batch_sds["cur_pos"])
            model_flops = 2.0 * n_act * shape.global_batch

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    rl = roofline.build(compiled, model_flops, chips)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "quantized": quantized, "chips": chips,
        "n_params": cfg.n_params(), "n_active_params": n_act,
        "compile_s": time.time() - t0,
        "memory_analysis": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "alias_bytes_per_dev": mem.alias_size_in_bytes,
            "peak_bytes_per_dev": (mem.argument_size_in_bytes
                                   + mem.output_size_in_bytes
                                   + mem.temp_size_in_bytes
                                   - mem.alias_size_in_bytes),
        },
        "roofline": rl.to_dict(),
    }
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        peak = rec["memory_analysis"]["peak_bytes_per_dev"] / 2**30
        print(f"[dryrun] {tag}: OK compile={rec['compile_s']:.1f}s "
              f"peak={peak:.2f}GiB/dev "
              f"terms(c/m/n)={rl.compute_s:.4f}/{rl.memory_s:.4f}/"
              f"{rl.collective_s:.4f}s bound={rl.bottleneck} "
              f"frac={rl.roofline_fraction:.3f}")
        print(f"  memory_analysis: {mem}")
        ca = compiled.cost_analysis() or {}
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quantized", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    if args.all:
        cells = [(a, s, mp) for a in list_archs() for s in SHAPES
                 for mp in (False, True)]
    else:
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    failures = []
    for arch, shape, mp in cells:
        mesh_name = "multi_pod_2x16x16" if mp else "single_pod_16x16"
        tag = f"{arch}__{shape}__{mesh_name}" + ("__msb4" if args.quantized else "")
        if args.skip_existing and os.path.exists(
                os.path.join(args.out, tag + ".json")):
            print(f"[dryrun] {tag}: cached")
            continue
        try:
            run_cell(arch, shape, multi_pod=mp, quantized=args.quantized,
                     out_dir=args.out)
        except Exception as e:  # noqa: BLE001 — report all failures at end
            failures.append((tag, repr(e)))
            print(f"[dryrun] {tag}: FAIL {e}")
            traceback.print_exc()
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("\n[dryrun] all cells OK")


if __name__ == "__main__":
    main()
