"""Production mesh construction (assignment spec).

single-pod : (16, 16)     ("data", "model")          = 256 chips
multi-pod  : (2, 16, 16)  ("pod", "data", "model")   = 512 chips

Defined as a function so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_tp_mesh(tp_size):
    """1-D ``("model",)`` mesh over the first ``tp_size`` local devices.

    This is the serving engines' tensor-parallel mesh (no data axis — the
    continuous-batching engine is one replica; scale-out is by running more
    engine replicas). On CPU, force multiple host devices first:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    import numpy as np
    devs = jax.devices()
    if tp_size > len(devs):
        raise ValueError(f"tp={tp_size} needs {tp_size} devices, "
                         f"have {len(devs)}")
    return jax.sharding.Mesh(np.asarray(devs[:tp_size]), ("model",))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    if shape is None:
        d = 1
        while d * d * 4 <= n:
            d *= 2
        shape = (max(n // 2, 1), 2) if n >= 2 else (1, 1)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


# v5e hardware constants used by the roofline (assignment spec)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
