"""Production mesh construction (assignment spec).

single-pod : (16, 16)     ("data", "model")          = 256 chips
multi-pod  : (2, 16, 16)  ("pod", "data", "model")   = 512 chips

Defined as a function so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    if shape is None:
        d = 1
        while d * d * 4 <= n:
            d *= 2
        shape = (max(n // 2, 1), 2) if n >= 2 else (1, 1)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


# v5e hardware constants used by the roofline (assignment spec)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
