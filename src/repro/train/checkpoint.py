"""Sharded, atomic, resumable checkpointing with elastic restore.

Layout:  <dir>/step_<n>/  with one .npy per leaf + MANIFEST.json
  * atomic: written to step_<n>.tmp, fsynced, then renamed
  * integrity: per-leaf crc32 recorded in the manifest and verified on load
  * elastic: restore() takes target shardings for *any* mesh — leaves are
    loaded as host arrays and device_put to the new layout, so a job saved
    on 512 chips restores on 256 (or on CPU) unchanged
  * async: save() can hand the host-side write to a background thread
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory, keep=3, async_save=True):
        self.dir = str(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(self.dir, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, state, step, block=False):
        host_state = jax.tree_util.tree_map(np.asarray, state)
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(host_state, step), daemon=True)
            self._thread.start()
        else:
            self._write(host_state, step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host_state, step):
        flat, _ = _flatten(host_state)
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": int(step), "leaves": {}}
        for i, (key, leaf) in enumerate(sorted(flat.items())):
            arr = np.asarray(leaf)
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][key] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
            }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "MANIFEST.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore(self, step, like=None, shardings=None, verify=True):
        """Load step. ``like``: template pytree for structure; ``shardings``:
        optional pytree of NamedShardings for elastic placement."""
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        flat = {}
        for key, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(path, meta["file"]))
            if verify:
                crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
                if crc != meta["crc32"]:
                    raise IOError(f"checkpoint corruption in {key}")
            flat[key] = arr
        if like is None:
            return flat, manifest["step"]
        ref_flat, treedef = _flatten(like)
        tree = jax.tree_util.tree_unflatten(
            treedef, [flat[k] for k in ref_flat])
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest["step"]

    def restore_latest(self, like=None, shardings=None):
        steps = self.steps()
        if not steps:
            return None
        return self.restore(steps[-1], like=like, shardings=shardings)
