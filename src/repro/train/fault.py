"""Fault-tolerance utilities: preemption capture + straggler monitoring.

Production mapping (documented for the 1000+-node deployment):
  * PreemptionHandler — SIGTERM/SIGINT from the cluster scheduler sets a
    flag the train loop polls; the loop checkpoints and exits cleanly. On
    TPU pods the same hook is driven by the maintenance-event notification.
  * StragglerMonitor — per-step wall-time ring buffer; a host whose step
    time exceeds ``threshold`` x running median is flagged. In multi-host
    deployments the flags are aggregated through a tiny all-gather each
    ``report_every`` steps and the controller can evict/replace the host
    (restart-from-checkpoint covers the membership change — the elastic
    restore path reshards to the new mesh).
"""
from __future__ import annotations

import collections
import signal
import statistics
import threading


class PreemptionHandler:
    """Installs signal handlers; ``should_stop()`` is loop-pollable."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._stop = threading.Event()
        self._prev = {}
        for sig in signals:
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except ValueError:  # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._stop.set()

    def should_stop(self):
        return self._stop.is_set()

    def trigger(self):  # for tests / manual drain
        self._stop.set()

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


class StragglerMonitor:
    def __init__(self, window=50, threshold=2.0):
        self.window = window
        self.threshold = threshold
        self.times = collections.deque(maxlen=window)
        self.flagged = []

    def record(self, step, dt):
        if len(self.times) >= 10:
            med = statistics.median(self.times)
            if dt > self.threshold * med:
                self.flagged.append((step, dt, med))
        self.times.append(dt)

    @property
    def median(self):
        return statistics.median(self.times) if self.times else 0.0
