"""Training step + loop with fault tolerance hooks."""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .optim import AdamW, OptConfig, clip_by_global_norm, lr_schedule


def make_train_step(model, opt: AdamW, parallel=None):
    """Pure (state, batch) -> (state, metrics). state = {params, opt}."""

    def train_step(state, batch):
        def loss_fn(p):
            return model.loss(p, batch, parallel)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        grads, gnorm = clip_by_global_norm(grads, opt.cfg.grad_clip)
        params, opt_state = opt.update(grads, state["opt"], state["params"])
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       lr=lr_schedule(opt.cfg, opt_state["step"]))
        return {"params": params, "opt": opt_state}, metrics

    return train_step


def init_state(model, opt: AdamW, rng, param_dtype=jnp.float32):
    params = model.init(rng)
    params = jax.tree_util.tree_map(lambda p: p.astype(param_dtype), params)
    return {"params": params, "opt": opt.init(params)}


def state_axes(model, opt: AdamW):
    """Logical axes for the whole train state (sharding resolver input)."""
    pax = model.param_axes()
    pshapes = model.param_shapes()
    return {"params": pax, "opt": opt.moment_axes(pax, pshapes)}


def train_loop(model, opt, data_iter, *, steps, state=None, rng=None,
               parallel=None, checkpointer=None, checkpoint_every=0,
               log_every=10, straggler_monitor=None, should_stop=None,
               log_fn=print):
    """Run the training loop with checkpoint/restart + preemption handling.

    - resumes from ``checkpointer.latest()`` if available
    - saves every ``checkpoint_every`` steps and on preemption signal
    - ``straggler_monitor`` records per-step wall times
    """
    step_fn = jax.jit(make_train_step(model, opt, parallel), donate_argnums=0)
    start_step = 0
    if state is None:
        state = init_state(model, opt, rng)
    if checkpointer is not None:
        host_like = jax.tree_util.tree_map(
            lambda x: jax.numpy.asarray(x), state)
        restored = checkpointer.restore_latest(like=host_like)
        if restored is not None:
            tree, start_step = restored
            state = jax.tree_util.tree_map(jnp.asarray, tree)
            log_fn(f"[train] resumed from step {start_step}")

    metrics = {}
    for step in range(start_step, steps):
        t0 = time.perf_counter()
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if straggler_monitor is not None:
            jax.block_until_ready(metrics["loss"])
            straggler_monitor.record(step, time.perf_counter() - t0)
        if log_every and (step % log_every == 0 or step == steps - 1):
            log_fn(f"[train] step {step} loss {float(metrics['loss']):.4f} "
                   f"gnorm {float(metrics['grad_norm']):.3f}")
        preempted = should_stop is not None and should_stop()
        if checkpointer is not None and (
                preempted or (checkpoint_every
                              and (step + 1) % checkpoint_every == 0)):
            checkpointer.save(state, step + 1)
        if preempted:
            log_fn(f"[train] preemption: checkpointed at step {step + 1}")
            break
    return state, metrics
