"""Gradient compression for cross-pod (DCN) data parallelism.

The pod axis carries only gradient all-reduces (DESIGN.md Sec. 4). Two
compressors:

  * bf16: cast-before-reduce — halves DCN bytes; achieved in-graph simply by
    keeping grads bf16 (XLA all-reduces in tensor dtype).
  * int8 + error feedback: classic EF-SGD compressor for the manual
    (shard_map) pod-reduce: q = round(g/s) int8 with per-block-256 absmax
    scale; the quantization residual is carried to the next step so the
    compression error telescopes instead of accumulating.

``psum_compressed`` is the shard_map building block; ``ef_compress`` /
``ef_decompress`` are pure and unit-tested.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_compress(grad, residual, block=256):
    """(grad + residual) -> (int8 codes, scales, new_residual)."""
    g = grad.astype(jnp.float32) + residual
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % block
    fp = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)]).reshape(-1, block)
    scale = jnp.max(jnp.abs(fp), axis=1) / 127.0
    q = jnp.round(fp / jnp.maximum(scale, 1e-20)[:, None])
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale[:, None]
    new_residual = (fp - deq).reshape(-1)[: flat.shape[0]].reshape(g.shape)
    return q, scale, new_residual


def ef_decompress(q, scale, shape):
    deq = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return deq.reshape(-1)[:n].reshape(shape)


def psum_compressed(grad, residual, axis_name, block=256):
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    int8 codes are summed in int32 (exact for <= 2^23 summands), then
    rescaled by the max scale across the axis — a standard 1-scale EF
    approximation that keeps the wire format at 1 byte/element.
    """
    q, scale, new_residual = ef_compress(grad, residual, block)
    smax = jax.lax.pmax(scale, axis_name)
    # renormalize local codes to the shared scale before the integer psum
    ratio = scale / jnp.maximum(smax, 1e-20)
    qr = jnp.round(q.astype(jnp.float32) * ratio[:, None]).astype(jnp.int32)
    tot = jax.lax.psum(qr, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    avg = (tot.astype(jnp.float32) * smax[:, None]) / n
    size = 1
    for s in grad.shape:
        size *= s
    return avg.reshape(-1)[:size].reshape(grad.shape), new_residual
