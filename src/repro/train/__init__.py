from .optim import AdamW, OptConfig, clip_by_global_norm, global_norm, lr_schedule
from .train_loop import init_state, make_train_step, state_axes, train_loop
from .checkpoint import Checkpointer
from .fault import PreemptionHandler, StragglerMonitor
from . import compression
