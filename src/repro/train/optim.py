"""AdamW with optionally int8-quantized moments (blockwise absmax).

The quantized-moments mode is the memory enabler for arctic-480b on the
single-pod mesh (DESIGN.md Sec. 4): params bf16 + int8 m/v = ~4.05 B/param
fully sharded. Moment quantization reuses the framework's blockwise-absmax
machinery (per-256 block scales, bf16).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    moment_dtype: str = "f32"       # f32 | int8
    q_block: int = 256
    min_quant_size: int = 1 << 14   # small leaves keep f32 moments


def lr_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.minimum(warm, cos)


# -- blockwise int8 state --------------------------------------------------
#
# m (signed): linear absmax per block — small entries rounding to 0 only
#   zeroes their update contribution (safe).
# v (non-negative): LOG-domain uint8 per block — linear absmax collapses
#   the many-decade dynamic range of squared gradients to 0, and
#   m/(sqrt(0)+eps) blows the step up (observed: loss 3.2 -> 164). The log
#   codec holds ~9% relative error over 12 decades (bitsandbytes' dynamic-
#   exponent trick, simplified).

# Blocks run along the LAST dim only — a full flatten would mix sharded
# dims and force XLA to all-gather the whole (e.g. arctic: 625 GB) moment
# tensor just to reshape it (measured; §Perf). Leaves with last dim not
# divisible by q_block keep f32 moments (they are tiny).

def _lastdim_blocks(x, block):
    return x.reshape(*x.shape[:-1], x.shape[-1] // block, block)


def _q8(x, block):
    fp = _lastdim_blocks(x, block)
    scale = jnp.max(jnp.abs(fp), axis=-1) / 127.0
    q = jnp.round(fp / jnp.maximum(scale, 1e-20)[..., None]).astype(jnp.int8)
    return {"q": q.reshape(x.shape), "s": scale.astype(jnp.bfloat16)}


def _dq8(st, block, shape):
    fp = _lastdim_blocks(st["q"].astype(jnp.float32), block)
    out = fp * st["s"].astype(jnp.float32)[..., None]
    return out.reshape(shape)


_LOG_DECADES = 12.0 * math.log(10.0)   # dynamic range below block max


def _q8_log(x, block):
    """Non-negative x -> uint8 log codes + per-block f32 log-max."""
    fp = _lastdim_blocks(x, block)
    vmax = jnp.maximum(jnp.max(fp, axis=-1), 1e-38)
    logv = jnp.log(jnp.maximum(fp, 1e-38)) - jnp.log(vmax)[..., None]
    code = jnp.clip(jnp.round(255.0 * (1.0 + logv / _LOG_DECADES)), 0, 255)
    # code 0 reserved for exact zero
    code = jnp.where(fp > 0, jnp.maximum(code, 1), 0).astype(jnp.uint8)
    return {"q": code.reshape(x.shape), "s": jnp.log(vmax)}


def _dq8_log(st, block, shape):
    fp = _lastdim_blocks(st["q"].astype(jnp.float32), block)
    logv = (fp / 255.0 - 1.0) * _LOG_DECADES + st["s"][..., None]
    out = jnp.where(fp > 0, jnp.exp(logv), 0.0)
    return out.reshape(shape)


def _is_q(leaf):
    return isinstance(leaf, dict) and set(leaf) == {"q", "s"}


class AdamW:
    def __init__(self, cfg: OptConfig):
        self.cfg = cfg

    def _quantize_leaf(self, p):
        return (self.cfg.moment_dtype == "int8"
                and p.size >= self.cfg.min_quant_size
                and p.shape[-1] % self.cfg.q_block == 0)

    def init(self, params):
        def zero_m(p):
            if self._quantize_leaf(p):
                return _q8(jnp.zeros(p.shape, jnp.float32), self.cfg.q_block)
            return jnp.zeros(p.shape, jnp.float32)

        def zero_v(p):
            if self._quantize_leaf(p):
                return _q8_log(jnp.zeros(p.shape, jnp.float32),
                               self.cfg.q_block)
            return jnp.zeros(p.shape, jnp.float32)

        m = jax.tree_util.tree_map(zero_m, params)
        v = jax.tree_util.tree_map(zero_v, params)
        return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}

    def moment_axes(self, params_axes, params_shapes):
        """Logical axes for the opt state, mirroring init()'s structure.

        Scales have the param's shape with last dim / q_block — same
        logical axes apply (the resolver drops the last axis when the
        shrunken dim stops dividing the mesh axis)."""
        def one(axes, shp):
            shape = shp.shape if hasattr(shp, "shape") else shp
            if (self.cfg.moment_dtype == "int8"
                    and math.prod(shape) >= self.cfg.min_quant_size
                    and shape[-1] % self.cfg.q_block == 0):
                return {"q": tuple(axes), "s": tuple(axes)}
            return tuple(axes)
        is_axes = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        t = jax.tree_util.tree_map(one, params_axes, params_shapes,
                                   is_leaf=is_axes)
        return {"m": t, "v": t, "step": ()}

    def update(self, grads, state, params):
        cfg = self.cfg
        step = state["step"] + 1
        lr = lr_schedule(cfg, step)
        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            mf = _dq8(m, cfg.q_block, p.shape) if _is_q(m) else m
            vf = _dq8_log(v, cfg.q_block, p.shape) if _is_q(v) else v
            mf = cfg.b1 * mf + (1 - cfg.b1) * g
            vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
            u = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
            if p.ndim >= 2:
                u = u + cfg.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            m_out = _q8(mf, cfg.q_block) if _is_q(m) else mf
            v_out = _q8_log(vf, cfg.q_block) if _is_q(v) else vf
            return newp, m_out, v_out

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = jax.tree_util.tree_flatten(state["m"], is_leaf=_is_q)[0]
        flat_v = jax.tree_util.tree_flatten(state["v"], is_leaf=_is_q)[0]
        flat_p = jax.tree_util.tree_flatten(params)[0]
        out = [upd(g, m, v, p) for g, m, v, p
               in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm):
    n = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree_util.tree_map(
        lambda l: (l.astype(jnp.float32) * factor).astype(l.dtype), tree), n
