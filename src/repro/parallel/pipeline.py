"""GPipe-style pipeline parallelism over a dedicated `stage` mesh axis.

Not required by the assigned meshes (2-axis pods), but the scan-over-layers
structure makes staging natural: the layer stack splits into
``n_stages`` contiguous groups, microbatches flow stage-to-stage via
``ppermute`` inside a ``shard_map``, and every stage computes each tick
(classic GPipe fill/drain bubble = (S-1)/(M+S-1)).

``pipeline_forward`` is the schedule skeleton; tests/test_pipeline.py
verifies it equals sequential layer application on a host mesh. Wiring it
under ``Model`` means adding a "stage" axis to ``make_production_mesh`` and
stacking params (n_stages, layers_per_stage, ...) — the param layout
already supports an extra leading dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

shard_map = getattr(jax, "shard_map", None)
if shard_map is None:  # pragma: no cover
    from jax.experimental.shard_map import shard_map


def pipeline_forward(layer_fn, params, x_micro, mesh, stage_axis="stage"):
    """Run microbatches through pipeline stages.

    layer_fn(stage_params, x) -> x : applies one stage's layer group
    params: pytree, leaves (n_stages, ...) — sharded over `stage`
    x_micro: (n_micro, mb, ...) microbatched input (replicated)
    Returns (n_micro, mb, ...) outputs after all stages.
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stages - 1
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def stage_prog(params_local, xs):
        params_local = jax.tree_util.tree_map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(stage_axis)
        zero = jnp.zeros_like(xs[0])

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (while available)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = jnp.where(t < n_micro, xs[mb_idx], zero)
            x_in = jnp.where(stage == 0, inject, buf)
            y = layer_fn(params_local, x_in)
            # last stage emits microbatch (t - (n_stages - 1)) at this tick
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (stage == n_stages - 1) & (t >= n_stages - 1)
            outs = jnp.where(
                emit,
                jax.lax.dynamic_update_index_in_dim(outs, y, out_idx, 0),
                outs)
            buf_next = jax.lax.ppermute(y, stage_axis, fwd_perm)
            return (buf_next, outs), None

        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(tick, (zero, outs0),
                                    jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast via masked psum
        outs = jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, stage_axis)

    return shard_map(
        stage_prog, mesh=mesh,
        in_specs=(P(stage_axis), P()), out_specs=P(),
        check_vma=False)(params, x_micro)
