from .sharding import (ParallelContext, constraint, from_mesh, resolve_spec,
                       tree_shardings)
