from .sharding import (ParallelContext, TPShard, constraint, from_mesh,
                       resolve_spec, shard_map_compat, tree_shardings)
