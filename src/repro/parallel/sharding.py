"""Sharding policy: logical axis names -> mesh PartitionSpecs.

Every param/cache/activation leaf carries a tuple of logical axis names; the
resolver maps them to mesh axes with two production rules:
  1. divisibility — an axis is only assigned if the dim divides by the mesh
     axis size (uneven shardings are rejected by jax.jit on inputs);
  2. exclusivity — each mesh axis is used at most once per leaf, in dim
     order, so fallback names ("kv_seq" after "batch") pick up idle axes
     (e.g. long_500k batch=1 -> the KV-cache sequence dim takes `data`).

Logical vocabulary:
  fsdp/embed       -> data            (ZeRO-3 weight shard)
  tp/mlp/heads/kv/vocab/head_dim/experts -> model  (tensor/expert parallel)
  batch, kv_seq    -> (pod, data)     (data parallel; seq as fallback)
  layers/None      -> replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class TPShard:
    """Manual tensor-parallel context, live only *inside* ``shard_map``.

    Where ``ParallelContext`` drives GSPMD (sharding constraints on global
    arrays, the compiler inserts collectives), ``TPShard`` drives the
    *manual* serving data plane: the engines split params/caches per rank
    with ``shard_map`` and the model code issues its own collectives —
    ``psum`` after row-parallel (K-sharded) matmuls, ``all_gather`` over
    heads or vocab shards. Model functions distinguish the two by type:
    a ``TPShard`` ``parallel=`` argument means "you are running on the
    local shard of a mesh axis named ``axis`` of size ``size``".

    Multi-iteration bodies compose: the decode-horizon scan
    (``Model.paged_decode_horizon``, DESIGN.md Sec. 12) runs *inside* the
    ``shard_map`` region, so H fused decode iterations — per-iteration
    psums, logit all_gathers and on-device argmax included — are still one
    dispatch per mesh, with sampling replicated across ranks because every
    collective completes before the argmax reads the logits.
    """
    axis: str = "model"
    size: int = 1


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions, replication checking off.

    The manual-TP step produces replicated outputs by construction (psum /
    all_gather); the rep/vma checker of some jax versions cannot prove
    that through ``axis_index``-based head slicing, so it is disabled.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:  # pragma: no cover - older keyword
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Mesh
    dp_axes: Tuple[str, ...]
    tp_axis: str = "model"
    fsdp_axis: Optional[str] = "data"

    @property
    def dp_size(self):
        return math.prod(self.mesh.shape[a] for a in self.dp_axes)

    @property
    def tp_size(self):
        return self.mesh.shape[self.tp_axis]

    @property
    def n_devices(self):
        return math.prod(self.mesh.shape.values())

    def logical_map(self):
        """logical name -> candidate mesh-axis tuples, tried in order."""
        fsdp = [(self.fsdp_axis,)] if self.fsdp_axis else [()]
        tp = [(self.tp_axis,)]
        dp = tuple(self.dp_axes)
        return {
            "fsdp": fsdp, "embed": fsdp,
            "tp": tp, "mlp": tp, "heads": tp, "kv": tp, "vocab": tp,
            "head_dim": tp, "experts": tp, "sp": tp,
            "batch": [dp],
            # KV-cache sequence: grab every idle axis (long_500k batch=1 ->
            # all 512 ways), else whatever dp/tp remains free
            "kv_seq": [dp + (self.tp_axis,), dp, (self.tp_axis,)],
            "layers": [()], None: [()],
        }


def from_mesh(mesh: Mesh) -> ParallelContext:
    names = mesh.axis_names
    dp = tuple(a for a in names if a in ("pod", "data"))
    # a pure tensor-parallel mesh (("model",) only — the serving engines'
    # default) has no data axis at all: dp_size == 1
    if not dp and "model" in names and len(names) == 1:
        dp = ()
    elif not dp:
        dp = (names[0],)
    return ParallelContext(mesh=mesh, dp_axes=dp,
                           tp_axis="model" if "model" in names else names[-1],
                           fsdp_axis="data" if "data" in names else None)


def resolve_spec(axes, shape, ctx: ParallelContext) -> P:
    """Map one leaf's logical axes to a PartitionSpec (see module doc)."""
    lm = ctx.logical_map()
    used = set()
    parts = []
    for dim, name in zip(shape, axes):
        cands = lm.get(name, [()])
        if isinstance(cands, tuple):
            cands = [cands]
        chosen = None
        for mesh_axes in cands:
            size = (math.prod(ctx.mesh.shape[a] for a in mesh_axes)
                    if mesh_axes else 1)
            if (mesh_axes and not (set(mesh_axes) & used)
                    and size > 1 and dim % size == 0):
                chosen = mesh_axes
                break
        if chosen:
            parts.append(chosen if len(chosen) > 1 else chosen[0])
            used.update(chosen)
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(ctx: ParallelContext, axes_tree, shape_tree):
    """NamedSharding pytree for (axes, shapes) trees of identical structure."""
    def one(axes, shp):
        shape = shp.shape if hasattr(shp, "shape") else shp
        return NamedSharding(ctx.mesh, resolve_spec(axes, shape, ctx))
    return jax.tree_util.tree_map(
        one, axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def constraint(x, axes, ctx: Optional[ParallelContext]):
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, resolve_spec(axes, x.shape, ctx)))
