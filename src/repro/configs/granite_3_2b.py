"""Assigned architecture config: granite-3-2b (see archs.py for the full table)."""
from .archs import GRANITE3_2B as CONFIG  # noqa: F401
