from .base import (SHAPES, LONG_CONTEXT_OK, ModelConfig, ShapeConfig,
                   cell_is_skipped, get_config, list_archs, smoke_config)
from . import archs  # populate registry

__all__ = ["SHAPES", "LONG_CONTEXT_OK", "ModelConfig", "ShapeConfig",
           "archs", "cell_is_skipped", "get_config", "list_archs",
           "smoke_config"]
