"""Assigned architecture config: qwen1.5-0.5b (see archs.py for the full table)."""
from .archs import QWEN15_05B as CONFIG  # noqa: F401
