"""Model/shape configuration system + registry for the assigned archs."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | audio | vlm | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # attention features
    qkv_bias: bool = False
    use_rope: bool = True
    rope_theta: float = 10000.0
    sliding_window: int = 0        # gemma2 local layers
    local_every: int = 0           # window on layers with i % local_every == 0
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    query_scale: float = 0.0       # 0 -> head_dim**-0.5
    post_norm: bool = False        # gemma2 pre+post norms
    embed_scale: float = 1.0       # gemma: sqrt(d_model)

    # MoE
    n_experts: int = 0
    n_experts_active: int = 0
    moe_every: int = 1             # layer i is MoE if i % moe_every == moe_offset
    moe_offset: int = 0
    dense_residual: bool = False   # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # layer pattern
    layer_pattern: str = "dense"   # dense | jamba | xlstm | encdec
    attn_every: int = 0            # jamba: attention if i % attn_every == attn_offset
    attn_offset: int = 4

    # mamba (jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_chunk: int = 128

    # enc-dec (whisper)
    n_enc_layers: int = 0

    # frontend stubs
    frontend: str = "none"         # none | audio | vision
    n_frontend_tokens: int = 0     # internvl: patch embeddings prepended

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: object = jnp.bfloat16
    attn_chunk: int = 512
    decode_chunk: int = 2048
    remat: bool = True
    vocab_round: int = 256

    source: str = ""               # provenance note

    @property
    def head_dim_(self):
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self):
        r = self.vocab_round
        return -(-self.vocab_size // r) * r

    @property
    def n_experts_padded(self):
        if not self.n_experts:
            return 0
        return -(-self.n_experts // 16) * 16 if self.n_experts % 16 else self.n_experts

    @property
    def mamba_d_inner(self):
        return self.mamba_expand * self.d_model

    @property
    def mamba_dt_rank(self):
        return -(-self.d_model // 16)

    @property
    def is_encdec(self):
        return self.layer_pattern == "encdec"

    def n_params(self) -> int:
        """Analytic parameter count (for 6*N*D model-FLOPs accounting)."""
        d, hd = self.d_model, self.head_dim_
        v = self.vocab_padded
        attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        dense_ffn = 3 * d * self.d_ff
        n = v * d * (1 if self.tie_embeddings else 2)
        if self.layer_pattern == "xlstm":
            per_m = 4 * d * self.n_heads * hd + d * 2 * self.n_heads + \
                self.n_heads * hd * d
            per_s = 4 * d * self.n_heads * hd + self.n_heads * hd * hd + \
                self.n_heads * hd * d
            return n + (self.n_layers // 2) * (per_m + per_s)
        if self.layer_pattern == "jamba":
            di = self.mamba_d_inner
            mamba = d * 2 * di + di * (self.mamba_dt_rank + 2 * self.mamba_d_state) \
                + self.mamba_dt_rank * di + di * d + 4 * di
            n_attn = self.n_layers // self.attn_every
            n_moe = self.n_layers // self.moe_every
            moe = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            n += n_attn * attn + (self.n_layers - n_attn) * mamba
            n += n_moe * moe + (self.n_layers - n_moe) * dense_ffn
            return n
        layers = self.n_layers + self.n_enc_layers
        if self.n_experts:
            moe = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            per = attn + moe + (dense_ffn if self.dense_residual else 0)
            return n + layers * per
        per = attn + dense_ffn
        if self.is_encdec:
            per_dec = attn * 2 + dense_ffn  # + cross-attention
            return n + self.n_enc_layers * per + self.n_layers * per_dec
        return n + layers * per

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.n_experts:
            return self.n_params()
        full = self.n_params()
        d = self.d_model
        n_moe = self.n_layers // self.moe_every
        dead = n_moe * (self.n_experts - self.n_experts_active) * 3 * d * self.d_ff
        return full - dead


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic / bounded attention;
# DESIGN.md Sec. 5) — everything else documents a skip.
LONG_CONTEXT_OK = {"gemma2-2b", "jamba-v0.1-52b", "xlstm-125m"}

_REGISTRY = {}


def register(cfg: ModelConfig):
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import archs  # noqa: F401  (populates the registry)
    return _REGISTRY[name]


def list_archs():
    from . import archs  # noqa: F401
    return sorted(_REGISTRY)


def cell_is_skipped(arch: str, shape: str) -> Optional[str]:
    """Return a skip reason for an (arch, shape) cell, or None if it runs."""
    cfg = get_config(arch)
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return "pure full-attention arch: 500k decode excluded per assignment"
    return None


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(name)
    repl = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=503, attn_chunk=32, decode_chunk=32,
        dtype=jnp.float32, vocab_round=64,
    )
    if cfg.n_experts:
        repl.update(n_experts=8, n_experts_active=2)
    if cfg.layer_pattern == "jamba":
        repl.update(n_layers=8, attn_every=8, moe_every=2)
    if cfg.layer_pattern == "xlstm":
        repl.update(n_layers=2, n_kv_heads=4)
    if cfg.is_encdec:
        repl.update(n_enc_layers=2, n_kv_heads=4)
    if cfg.n_kv_heads == cfg.n_heads:
        repl.update(n_kv_heads=4)
    if cfg.frontend == "vision":
        repl.update(n_frontend_tokens=4)
    return dataclasses.replace(cfg, **repl)
