"""Assigned architecture config: whisper-large-v3 (see archs.py for the full table)."""
from .archs import WHISPER_LARGE_V3 as CONFIG  # noqa: F401
