"""Assigned architecture config: internlm2-1.8b (see archs.py for the full table)."""
from .archs import INTERNLM2_18B as CONFIG  # noqa: F401
