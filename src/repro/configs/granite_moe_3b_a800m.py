"""Assigned architecture config: granite-moe-3b-a800m (see archs.py for the full table)."""
from .archs import GRANITE_MOE_3B as CONFIG  # noqa: F401
