"""Assigned architecture config: jamba-v0.1-52b (see archs.py for the full table)."""
from .archs import JAMBA_52B as CONFIG  # noqa: F401
