"""Assigned architecture config: arctic-480b (see archs.py for the full table)."""
from .archs import ARCTIC_480B as CONFIG  # noqa: F401
