"""Assigned architecture config: xlstm-125m (see archs.py for the full table)."""
from .archs import XLSTM_125M as CONFIG  # noqa: F401
