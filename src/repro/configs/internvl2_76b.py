"""Assigned architecture config: internvl2-76b (see archs.py for the full table)."""
from .archs import INTERNVL2_76B as CONFIG  # noqa: F401
