"""Assigned architecture config: gemma2-2b (see archs.py for the full table)."""
from .archs import GEMMA2_2B as CONFIG  # noqa: F401
