"""The 10 assigned architectures — exact configs from the assignment table.

Each also exists as its own module (src/repro/configs/<id>.py) re-exporting
the config, so ``--arch <id>`` resolves either way.
"""
from .base import ModelConfig, register

GEMMA2_2B = register(ModelConfig(
    name="gemma2-2b", family="dense", n_layers=26, d_model=2304, n_heads=8,
    n_kv_heads=4, head_dim=256, d_ff=9216, vocab_size=256000,
    sliding_window=4096, local_every=2, logit_softcap=30.0, attn_softcap=50.0,
    post_norm=True, embed_scale=2304 ** 0.5, tie_embeddings=True,
    source="arXiv:2408.00118; hf",
))

QWEN15_05B = register(ModelConfig(
    name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=2816, vocab_size=151936, qkv_bias=True,
    rope_theta=1_000_000.0, tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
))

INTERNLM2_18B = register(ModelConfig(
    name="internlm2-1.8b", family="dense", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92544,
    rope_theta=1_000_000.0, tie_embeddings=False,
    source="arXiv:2403.17297; hf",
))

GRANITE3_2B = register(ModelConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab_size=49155,
    tie_embeddings=True, source="hf:ibm-granite/granite-3.0-2b-base; hf",
))

GRANITE_MOE_3B = register(ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab_size=49155, n_experts=40,
    n_experts_active=8, tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
))

ARCTIC_480B = register(ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168, n_heads=56,
    n_kv_heads=8, d_ff=4864, vocab_size=32000, n_experts=128,
    n_experts_active=2, dense_residual=True, tie_embeddings=False,
    source="hf:Snowflake/snowflake-arctic-base; hf",
))

JAMBA_52B = register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=65536, n_experts=16,
    n_experts_active=2, moe_every=2, moe_offset=1, layer_pattern="jamba",
    attn_every=8, attn_offset=4, use_rope=False, tie_embeddings=False,
    source="arXiv:2403.19887; hf",
))

WHISPER_LARGE_V3 = register(ModelConfig(
    name="whisper-large-v3", family="audio", n_layers=32, n_enc_layers=32,
    d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120, vocab_size=51866,
    layer_pattern="encdec", use_rope=False, frontend="audio",
    tie_embeddings=True, source="arXiv:2212.04356; unverified",
))

INTERNVL2_76B = register(ModelConfig(
    name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28672, vocab_size=128256,
    rope_theta=500_000.0, frontend="vision", n_frontend_tokens=1024,
    tie_embeddings=False, source="arXiv:2404.16821; unverified",
))

XLSTM_125M = register(ModelConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv_heads=4, head_dim=192, d_ff=0, vocab_size=50304,
    layer_pattern="xlstm", use_rope=False, tie_embeddings=True,
    source="arXiv:2405.04517; unverified",
))

ALL = [GEMMA2_2B, QWEN15_05B, INTERNLM2_18B, GRANITE3_2B, GRANITE_MOE_3B,
       ARCTIC_480B, JAMBA_52B, WHISPER_LARGE_V3, INTERNVL2_76B, XLSTM_125M]
