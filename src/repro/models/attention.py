"""Chunked (flash-style) attention in pure JAX with a custom VJP.

This is the reference implementation the Pallas kernel
(kernels/flash_attention) is validated against, and the path used for the
multi-pod dry-run (XLA cost analysis must see the real FLOPs — a Pallas
custom-call would hide them; see DESIGN.md Sec. 7).

Features needed by the assigned architectures:
  * GQA (n_kv_heads <= n_heads)
  * causal masking, non-causal (whisper encoder / cross-attention)
  * sliding-window (gemma2 local layers) with a *traced* window size so the
    alternating local/global stack stays a single scan body
  * attention logit soft-capping (gemma2)

Both q and kv are chunked; the backward pass recomputes scores per chunk
pair (FlashAttention-2 style), so live memory is O(chunk^2), never O(S^2).
The causal variant processes the full chunk grid with masking (~2x waste);
the Pallas kernel prunes fully-masked tiles on real TPUs — recorded as a
perf-iteration item in EXPERIMENTS.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_NEG_INF = -2.0e38


def _softcap(s, cap):
    return jnp.where(cap > 0, cap * jnp.tanh(s / jnp.maximum(cap, 1e-6)), s)


def _mask(qpos, kpos, causal, window):
    # window is a traced scalar; 0 => no window (global layer)
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    m &= (window <= 0) | (kpos[None, :] > qpos[:, None] - window)
    return m


def _chunk_scores(qc, kc, qpos, kpos, *, causal, window, softcap, scale):
    # qc: (B, cq, H, d)  kc: (B, ck, KV, d); H = KV * rep
    b, cq, h, d = qc.shape
    kv = kc.shape[2]
    rep = h // kv
    qh = qc.reshape(b, cq, kv, rep, d)
    s = jnp.einsum("bqkrd,bskd->bqkrs", qh.astype(jnp.float32),
                   kc.astype(jnp.float32)) * scale
    s = _softcap(s, softcap)
    m = _mask(qpos, kpos, causal, window)          # (cq, ck)
    return jnp.where(m[None, :, None, None, :], s, _NEG_INF)


def _fa_fwd_impl(q, k, v, *, causal, window, q_offset, softcap, scale,
                 chunk_q, chunk_kv):
    b, sq, h, d = q.shape
    skv, kv = k.shape[1], k.shape[2]
    nq, nk = sq // chunk_q, skv // chunk_kv
    rep = h // kv

    qr = q.reshape(b, nq, chunk_q, h, d)

    def q_step(_, iq):
        qc = qr[:, iq]
        qpos = q_offset + iq * chunk_q + jnp.arange(chunk_q)

        def kv_step(carry, ik):
            acc, m_i, l_i = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ik * chunk_kv, chunk_kv, 1)
            vc = jax.lax.dynamic_slice_in_dim(v, ik * chunk_kv, chunk_kv, 1)
            kpos = ik * chunk_kv + jnp.arange(chunk_kv)
            s = _chunk_scores(qc, kc, qpos, kpos, causal=causal,
                              window=window, softcap=softcap, scale=scale)
            m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_i - m_new)
            l_new = l_i * corr + jnp.sum(p, axis=-1)
            # p in the value dtype for the PV dot (f32 accumulation) —
            # halves the probability-matrix traffic, same as the kernel
            pv = jnp.einsum("bqkrs,bskd->bqkrd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, chunk_q, kv, rep, d), jnp.float32)
        m0 = jnp.full((b, chunk_q, kv, rep), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, chunk_q, kv, rep), jnp.float32)
        (acc, m_i, l_i), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                          jnp.arange(nk))
        l_safe = jnp.maximum(l_i, 1e-30)
        out = (acc / l_safe[..., None]).reshape(b, chunk_q, h, d)
        lse = (m_i + jnp.log(l_safe)).reshape(b, chunk_q, h)
        return None, (out, lse)

    _, (out, lse) = jax.lax.scan(q_step, None, jnp.arange(nq))
    # out: (nq, B, cq, H, d) -> (B, Sq, H, d)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, d).astype(q.dtype)
    lse = jnp.moveaxis(lse, 0, 1).reshape(b, sq, h)
    return out, lse


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, q_offset, softcap, scale, chunk_q,
           chunk_kv):
    out, _ = _fa_fwd_impl(q, k, v, causal=causal, window=window,
                          q_offset=q_offset, softcap=softcap, scale=scale,
                          chunk_q=chunk_q, chunk_kv=chunk_kv)
    return out


def _flash_fwd(q, k, v, causal, window, q_offset, softcap, scale, chunk_q,
               chunk_kv):
    out, lse = _fa_fwd_impl(q, k, v, causal=causal, window=window,
                            q_offset=q_offset, softcap=softcap, scale=scale,
                            chunk_q=chunk_q, chunk_kv=chunk_kv)
    return out, (q, k, v, out, lse, window, q_offset)


def _flash_bwd(causal, softcap, scale, chunk_q, chunk_kv, res, dout):
    q, k, v, out, lse, window, q_offset = res
    b, sq, h, d = q.shape
    skv, kv = k.shape[1], k.shape[2]
    nq, nk = sq // chunk_q, skv // chunk_kv
    rep = h // kv

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                    # (B, Sq, H)
    qr = q.reshape(b, nq, chunk_q, h, d)
    dor = dout.reshape(b, nq, chunk_q, h, d).astype(jnp.float32)
    lser = lse.reshape(b, nq, chunk_q, kv, rep)
    deltar = delta.reshape(b, nq, chunk_q, kv, rep)

    def q_step(carry, iq):
        dk_acc, dv_acc = carry
        qc = qr[:, iq]
        doc = dor[:, iq].reshape(b, chunk_q, kv, rep, d)
        lsec, deltac = lser[:, iq], deltar[:, iq]
        qpos = q_offset + iq * chunk_q + jnp.arange(chunk_q)

        def kv_step(carry2, ik):
            dq_c, dk_a, dv_a = carry2
            kc = jax.lax.dynamic_slice_in_dim(k, ik * chunk_kv, chunk_kv, 1)
            vc = jax.lax.dynamic_slice_in_dim(v, ik * chunk_kv, chunk_kv, 1)
            kpos = ik * chunk_kv + jnp.arange(chunk_kv)
            qh = qc.reshape(b, chunk_q, kv, rep, d).astype(jnp.float32)
            s_raw = jnp.einsum("bqkrd,bskd->bqkrs", qh,
                               kc.astype(jnp.float32)) * scale
            s = _softcap(s_raw, softcap)
            m = _mask(qpos, kpos, causal, window)
            s = jnp.where(m[None, :, None, None, :], s, _NEG_INF)
            p = jnp.exp(s - lsec[..., None])                    # (b,cq,kv,rep,ck)
            dp = jnp.einsum("bqkrd,bskd->bqkrs", doc, vc.astype(jnp.float32))
            ds = p * (dp - deltac[..., None])
            if True:  # softcap gradient (no-op when softcap == 0)
                cap_grad = jnp.where(
                    softcap > 0,
                    1.0 - jnp.tanh(s_raw / jnp.maximum(softcap, 1e-6)) ** 2,
                    1.0)
                ds = ds * cap_grad
            ds = jnp.where(m[None, :, None, None, :], ds, 0.0)
            dq_c = dq_c + scale * jnp.einsum("bqkrs,bskd->bqkrd", ds,
                                             kc.astype(jnp.float32))
            dk_c = scale * jnp.einsum("bqkrs,bqkrd->bskd", ds, qh)
            dv_c = jnp.einsum("bqkrs,bqkrd->bskd", p, doc)
            dk_a = jax.lax.dynamic_update_slice_in_dim(
                dk_a, jax.lax.dynamic_slice_in_dim(dk_a, ik * chunk_kv,
                                                   chunk_kv, 1) + dk_c,
                ik * chunk_kv, 1)
            dv_a = jax.lax.dynamic_update_slice_in_dim(
                dv_a, jax.lax.dynamic_slice_in_dim(dv_a, ik * chunk_kv,
                                                   chunk_kv, 1) + dv_c,
                ik * chunk_kv, 1)
            return (dq_c, dk_a, dv_a), None

        dq0 = jnp.zeros((b, chunk_q, kv, rep, d), jnp.float32)
        (dq_c, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_c.reshape(b, chunk_q, h, d)

    dk0 = jnp.zeros((b, skv, kv, d), jnp.float32)
    dv0 = jnp.zeros((b, skv, kv, d), jnp.float32)
    (dk, dv), dq = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dq, 0, 1).reshape(b, sq, h, d).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype), None, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, chunk_q=512, chunk_kv=512, q_offset=0):
    """Chunked attention. q: (B,Sq,H,d), k/v: (B,Skv,KV,d) -> (B,Sq,H,d).

    ``window`` and ``q_offset`` may be traced scalars (context parallelism
    passes the rank's global query offset). Sequence lengths are padded
    internally to chunk multiples.
    """
    def _divisor_chunk(n, target):
        c = min(target, n)
        while n % c:
            c -= 1
        return c

    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    # chunk sizes must divide the (padded) lengths; padded q rows are dropped
    # at the end, and padded *keys* are hidden by the causal mask (sq == skv
    # there). Non-causal (cross-attention) picks an exactly-dividing chunk.
    if causal:
        cq = min(chunk_q, sq)
        ck = min(chunk_kv, skv)
        pq, pk = (-sq) % cq, (-skv) % ck
        if pq:
            q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        if pk:
            k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    else:
        cq = _divisor_chunk(sq, chunk_q)
        ck = _divisor_chunk(skv, chunk_kv)
    window = jnp.asarray(window, jnp.int32)
    q_offset = jnp.asarray(q_offset, jnp.int32)
    out = _flash(q, k, v, causal, window, q_offset, float(softcap),
                 float(scale), cq, ck)
    return out[:, :sq]


def decode_attention(q, k_cache, v_cache, positions, cur_pos, *, window=0,
                     softcap=0.0, scale=None, chunk_kv=None):
    """Single-token attention against a (possibly huge) KV cache.

    q: (B, 1, H, d); caches: (B, S, KV, d); positions: (B, S) int32 position
    of each cache entry (ring-buffer layout, -1 = empty); cur_pos: (B,).

    Written as plain einsums over the full cache: the score tensor for one
    query token is only (B, H, S) — tiny per chip once the cache's seq dim
    is sharded (kv_seq takes every idle mesh axis; long_500k shards it
    512-way). XLA turns the softmax + PV reductions over the sharded S into
    the flash-decode psum combine automatically.
    """
    del chunk_kv
    b, s, kv, d = k_cache.shape
    h = q.shape[2]
    rep = h // kv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    window = jnp.asarray(window, jnp.int32)
    # keep einsum INPUTS in the cache dtype with f32 accumulation — an
    # .astype(f32) on the cache makes XLA materialize a full f32 cache copy
    # (+ convert back) every layer (measured: 80x 2.7 GB/step; §Perf)
    qh = q.reshape(b, kv, rep, d).astype(k_cache.dtype)
    sc_ = jnp.einsum("bkrd,bskd->bkrs", qh, k_cache,
                     preferred_element_type=jnp.float32) * scale
    sc_ = _softcap(sc_, softcap)
    valid = (positions <= cur_pos[:, None]) & (positions >= 0)
    valid &= (window <= 0) | (positions > cur_pos[:, None] - window)
    sc_ = jnp.where(valid[:, None, None, :], sc_, _NEG_INF)
    m = jnp.max(sc_, axis=-1, keepdims=True)
    p = jnp.exp(sc_ - m)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bkrs,bskd->bkrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, h, d).astype(q.dtype)


def paged_attention(q, k_pool, v_pool, block_tables, q_pos, kv_lens, *,
                    window=0, softcap=0.0, scale=None):
    """Attention against a paged KV pool, gathering pages via block tables.

    q: (B, T, H, d) — T >= 1 query tokens per sequence (decode T=1, chunked
    prefill T=chunk); k_pool/v_pool: (n_pages, page_size, KV, d) global page
    pool; block_tables: (B, max_pages) int32 page ids, position i of sequence
    b lives at (block_tables[b, i // page_size], i % page_size); q_pos:
    (B, T) absolute positions of the query tokens (-1 = padding row);
    kv_lens: (B,) valid cache length *including* the current chunk.

    This is the pure-JAX reference for the Pallas paged-attention kernel
    (kernels/paged_attention): it materializes the gathered (B, S_max, KV, d)
    K/V, which the kernel avoids by streaming pages. Causality is enforced by
    absolute position (kpos <= q_pos), so intra-chunk causal masking in
    chunked prefill falls out for free.

    Head sharding (DESIGN.md Sec. 10): every head attends independently, so
    under tensor parallelism this function simply runs on the local shard —
    q with H/tp heads against pools holding KV/tp heads (same GQA ratio) —
    with no collective; block tables, positions and lengths are replicated.
    """
    k = k_pool[block_tables].reshape(q.shape[0], -1, k_pool.shape[2],
                                     k_pool.shape[3])
    v = v_pool[block_tables].reshape(*k.shape)
    return _attend_gathered(q, k, v, q_pos, kv_lens, window=window,
                            softcap=softcap, scale=scale)


def _attend_gathered(q, k, v, q_pos, kv_lens, *, window=0, softcap=0.0,
                     scale=None):
    """Masked softmax attention over already-materialized per-sequence K/V.

    q: (B, T, H, d); k/v: (B, S, KV, d) — the gathered (and, on the
    quantized path, dequantized) cache with absolute position ``kpos = s``.
    Shared core of ``paged_attention`` and ``paged_attention_quant``.
    """
    b, t, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    window = jnp.asarray(window, jnp.int32)
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    qh = q.reshape(b, t, kv, rep, d).astype(k.dtype)
    s = jnp.einsum("btkrd,bskd->btkrs", qh, k,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)
    valid = (kpos[None, None, :] <= q_pos[:, :, None])
    valid &= kpos[None, None, :] < kv_lens[:, None, None]
    valid &= (window <= 0) | (kpos[None, None, :] > q_pos[:, :, None] - window)
    s = jnp.where(valid[:, :, None, None, :], s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("btkrs,bskd->btkrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, t, h, d).astype(q.dtype)


def paged_write(k_pool, v_pool, k_new, v_new, block_tables, q_pos):
    """Scatter new K/V rows into the page pools via block tables.

    k_new/v_new: (B, T, KV, d); q_pos (B, T) absolute positions (-1 = pad).
    Padding rows write to page 0, which the allocator reserves as scratch
    (never handed to a sequence), so duplicate pad writes are harmless.
    """
    n_pages, ps, kv, d = k_pool.shape
    page = jnp.take_along_axis(
        block_tables, jnp.maximum(q_pos, 0) // ps, axis=1)
    flat = jnp.where(q_pos >= 0, page * ps + jnp.maximum(q_pos, 0) % ps, 0)
    flat = flat.reshape(-1)
    k_pool = k_pool.reshape(n_pages * ps, kv, d).at[flat].set(
        k_new.reshape(-1, kv, d).astype(k_pool.dtype)).reshape(
            n_pages, ps, kv, d)
    v_pool = v_pool.reshape(n_pages * ps, kv, d).at[flat].set(
        v_new.reshape(-1, kv, d).astype(v_pool.dtype)).reshape(
            n_pages, ps, kv, d)
    return k_pool, v_pool


# ---------------------------------------------------------------------------
# Packed ragged prefill (DESIGN.md Sec. 16)
#
# A packed prefill dispatch carries the concatenated chunks of up to S
# sequences in one (1, T) token row. Per-token segment ids select each
# token's own block-table row, so the gathered K/V for token i contains
# *only* segment seg_ids[i]'s pages — cross-segment attention is
# structurally zero, not masked-to-zero. Pad tokens carry seg_ids = -1 and
# q_pos = -1 and land on the reserved scratch page/hot-row, exactly like
# pad rows on the unpacked path.
# ---------------------------------------------------------------------------


def paged_write_packed(k_pool, v_pool, k_new, v_new, block_tables, seg_ids,
                       q_pos):
    """Scatter packed K/V rows into the pools via per-token segment ids.

    k_new/v_new: (1, T, KV, d); seg_ids/q_pos: (1, T) (-1 = pad);
    block_tables: (S, max_pages) — one row per packable segment. Each
    token writes page ``block_tables[seg_ids[i], q_pos[i] // ps]``; pads
    write scratch page 0.
    """
    n_pages, ps, kv, d = k_pool.shape
    seg = jnp.maximum(seg_ids[0], 0)
    pos = jnp.maximum(q_pos[0], 0)
    page = block_tables[seg, pos // ps]
    valid = (seg_ids[0] >= 0) & (q_pos[0] >= 0)
    flat = jnp.where(valid, page * ps + pos % ps, 0)
    k_pool = k_pool.reshape(n_pages * ps, kv, d).at[flat].set(
        k_new.reshape(-1, kv, d).astype(k_pool.dtype)).reshape(
            n_pages, ps, kv, d)
    v_pool = v_pool.reshape(n_pages * ps, kv, d).at[flat].set(
        v_new.reshape(-1, kv, d).astype(v_pool.dtype)).reshape(
            n_pages, ps, kv, d)
    return k_pool, v_pool


def paged_attention_packed(q, k_pool, v_pool, block_tables, seg_ids, q_pos,
                           kv_lens, *, window=0, softcap=0.0, scale=None):
    """Packed ragged attention: per-token gather of the token's own segment.

    q: (1, T, H, d); block_tables: (S, max_pages); seg_ids/q_pos: (1, T);
    kv_lens: (S,) per-segment lengths incl. this dispatch. Re-expresses the
    packed row as T single-token "sequences" — token i gathers
    ``block_tables[seg_ids[i]]`` — and reuses ``paged_attention``, so
    causality-by-absolute-position and the zero cross-segment guarantee
    both fall out of the existing masking.
    """
    seg = jnp.maximum(seg_ids[0], 0)
    valid = seg_ids[0] >= 0
    bt_tok = block_tables[seg]                             # (T, max_pages)
    lens_tok = jnp.where(valid, kv_lens[seg], 0)
    out = paged_attention(q[0][:, None], k_pool, v_pool, bt_tok,
                          q_pos[0][:, None], lens_tok, window=window,
                          softcap=softcap, scale=scale)
    return out[:, 0][None]                                 # (1, T, H, d)


def paged_write_quant_packed(cache, k_new, v_new, block_tables, seg_ids,
                             q_pos, kv_lens, slots, seg_off, kv_bits):
    """Packed variant of ``paged_write_quant``.

    Re-views the (1, T) packed row as an (S, T) per-segment batch: row s
    keeps q_pos where ``seg_ids == s`` and -1 elsewhere, so the unpacked
    hot-write/commit-quantize machinery applies unchanged. ``seg_off``
    (S,) is each segment's first index in the packed row — threaded
    through as ``tok_base`` so commit-quantize gathers chunk content from
    the right packed offsets.
    """
    s = block_tables.shape[0]
    seg_q = jnp.where(seg_ids[0][None, :] == jnp.arange(s)[:, None],
                      q_pos[0][None, :], -1)               # (S, T)
    k_b = jnp.broadcast_to(k_new[0][None], (s,) + k_new[0].shape)
    v_b = jnp.broadcast_to(v_new[0][None], (s,) + v_new[0].shape)
    return paged_write_quant(cache, k_b, v_b, block_tables, seg_q, kv_lens,
                             slots, kv_bits, tok_base=seg_off)


def paged_attention_quant_packed(q, cache, block_tables, seg_ids, q_pos,
                                 kv_lens, slots, kv_bits, *, window=0,
                                 softcap=0.0, scale=None):
    """Packed attention over quantized pools: per-token segment views of
    block tables / lengths / slots, then ``paged_attention_quant`` verbatim
    (the hot-row frontier overlay indexes per token, so each token reads
    its own segment's partial page at full precision)."""
    seg = jnp.maximum(seg_ids[0], 0)
    valid = seg_ids[0] >= 0
    bt_tok = block_tables[seg]
    lens_tok = jnp.where(valid, kv_lens[seg], 0)
    slots_tok = jnp.where(valid, slots[seg], -1)
    out = paged_attention_quant(q[0][:, None], cache, bt_tok,
                                q_pos[0][:, None], lens_tok, slots_tok,
                                kv_bits, window=window, softcap=softcap,
                                scale=scale)
    return out[:, 0][None]


# ---------------------------------------------------------------------------
# Quantized page pools (kv_bits < 16; DESIGN.md Sec. 15)
#
# Dual-pool layout per layer period:
#   k_codes/v_codes   (n_pages, ps, KV, hd or hd//2)  packed committed pages
#   k_scales/v_scales (n_pages, KV, n_blocks, G)      per-page codebooks
#   k_hot/v_hot       (max_seqs+1, ps, KV, hd)        bf16 hot partial pages
#
# Each live slot owns exactly one partial (hot) page — its last — kept
# full-precision in hot row ``slot + 1`` (row 0 is the pad-row scratch
# mirror of pool page 0). Writes land in the hot row; any page the chunk
# *completes* is quantized device-side in the same dispatch and scattered
# into the packed pools, so a decode-horizon scan crosses page boundaries
# with no host round trip and every committed page is quantized by
# construction (the invariant PagedKVCache.check_invariants audits).
# ---------------------------------------------------------------------------


def paged_write_quant(cache, k_new, v_new, block_tables, q_pos, kv_lens,
                      slots, kv_bits, tok_base=None):
    """Hot-page write + commit-time quantization (quantize-on-commit).

    cache: dict(k_codes, v_codes, k_scales, v_scales, k_hot, v_hot) — one
    layer period's leaves; k_new/v_new: (B, T, KV, hd) roped; q_pos (B, T)
    absolute positions (-1 = pad); kv_lens (B,) length incl. this chunk;
    slots (B,) engine slot ids (-1 = pad row); kv_bits: static 4 or 8.
    ``tok_base`` (B,) offsets the chunk-content gather along T: row b's
    valid tokens start at packed index tok_base[b] instead of 0 (packed
    ragged prefill passes each segment's offset; None = 0 everywhere).

    New positions in a row's *final* page go to its hot row; every page
    this chunk completes (up to T // ps + 1 of them) is gathered from (old
    hot partial content, this chunk's rows), quantized with the MSB KV
    codec, and scattered into the packed pools. Pad rows and non-completed
    candidates write hot row 0 / packed page 0 (the reserved scratch).
    """
    from ..core.quantize import kv_quantize_pages
    k_hot, v_hot = cache["k_hot"], cache["v_hot"]
    n_hot, ps, kv, hd = k_hot.shape
    b, t = q_pos.shape
    mp = block_tables.shape[1]
    dtype = k_hot.dtype
    row = jnp.where(slots >= 0, slots + 1, 0)                     # (B,)

    # -- hot write: only the final (still-partial-capable) page's positions
    frontier = kv_lens // ps
    in_final = (q_pos >= 0) & (q_pos // ps == frontier[:, None])
    wrow = jnp.where(in_final, row[:, None], 0)
    flat = jnp.where(in_final,
                     wrow * ps + jnp.maximum(q_pos, 0) % ps, 0).reshape(-1)
    k_hot_new = k_hot.reshape(n_hot * ps, kv, hd).at[flat].set(
        k_new.reshape(-1, kv, hd).astype(dtype)).reshape(n_hot, ps, kv, hd)
    v_hot_new = v_hot.reshape(n_hot * ps, kv, hd).at[flat].set(
        v_new.reshape(-1, kv, hd).astype(dtype)).reshape(n_hot, ps, kv, hd)

    # -- commit-quantize every page this chunk completes
    n_valid = jnp.sum((q_pos >= 0).astype(jnp.int32), axis=1)     # (B,)
    start = kv_lens - n_valid                  # first position of the chunk
    n_cand = t // ps + 1
    i = jnp.arange(n_cand, dtype=jnp.int32)
    jp = start[:, None] // ps + i[None, :]                        # (B, nc)
    completed = ((jp + 1) * ps <= kv_lens[:, None]) & (n_valid[:, None] > 0)
    gp = jp[:, :, None] * ps + jnp.arange(ps, dtype=jnp.int32)    # (B, nc, ps)
    base = jnp.zeros((b,), jnp.int32) if tok_base is None else tok_base
    tidx = jnp.clip(base[:, None, None] + gp - start[:, None, None], 0, t - 1)
    bidx = jnp.arange(b)[:, None, None]
    from_new = (gp >= start[:, None, None])[..., None, None]
    # page content: positions >= start from this chunk, earlier positions
    # from the *old* hot row (the partial content being completed; the page
    # offset is gp % ps == the hot-row offset by alignment)
    k_content = jnp.where(from_new, k_new[bidx, tidx].astype(dtype),
                          k_hot[row][:, None])
    v_content = jnp.where(from_new, v_new[bidx, tidx].astype(dtype),
                          v_hot[row][:, None])
    kq_codes, kq_scales = kv_quantize_pages(k_content, kv_bits)
    vq_codes, vq_scales = kv_quantize_pages(v_content, kv_bits)
    pidx = jnp.where(
        completed,
        jnp.take_along_axis(block_tables, jnp.clip(jp, 0, mp - 1), axis=1),
        0).reshape(-1)
    flat2 = lambda a: a.reshape((-1,) + a.shape[2:])
    return {
        "k_codes": cache["k_codes"].at[pidx].set(flat2(kq_codes)),
        "v_codes": cache["v_codes"].at[pidx].set(flat2(vq_codes)),
        "k_scales": cache["k_scales"].at[pidx].set(
            flat2(kq_scales).astype(cache["k_scales"].dtype)),
        "v_scales": cache["v_scales"].at[pidx].set(
            flat2(vq_scales).astype(cache["v_scales"].dtype)),
        "k_hot": k_hot_new,
        "v_hot": v_hot_new,
    }


def paged_attention_quant(q, cache, block_tables, q_pos, kv_lens, slots,
                          kv_bits, *, window=0, softcap=0.0, scale=None):
    """Attention over quantized page pools + the bf16 hot partial page.

    The jnp oracle of the fused-dequant gather: committed pages are
    gathered via block tables and dequantized with the MSB KV codec; the
    frontier (partial) page positions are overlaid from the row's hot
    buffer, so the hot tail is read at full precision. The Pallas kernel
    (kernels/paged_attention) fuses the dequant into the page stream and
    never materializes this (B, S, KV, hd) copy.
    """
    from ..core.quantize import kv_dequantize_pages
    k_hot = cache["k_hot"]
    n_hot, ps, kv, hd = k_hot.shape
    b = q.shape[0]
    mp = block_tables.shape[1]
    dtype = k_hot.dtype
    k_deq = kv_dequantize_pages(cache["k_codes"][block_tables],
                                cache["k_scales"][block_tables],
                                kv_bits, dtype).reshape(b, mp * ps, kv, hd)
    v_deq = kv_dequantize_pages(cache["v_codes"][block_tables],
                                cache["v_scales"][block_tables],
                                kv_bits, dtype).reshape(b, mp * ps, kv, hd)
    row = jnp.where(slots >= 0, slots + 1, 0)
    frontier = kv_lens // ps
    kpos = jnp.arange(mp * ps, dtype=jnp.int32)
    in_hot = ((kpos[None, :] // ps) == frontier[:, None])[..., None, None]
    hot_k = cache["k_hot"][row][:, kpos % ps]         # (B, S, KV, hd)
    hot_v = cache["v_hot"][row][:, kpos % ps]
    k = jnp.where(in_hot, hot_k, k_deq)
    v = jnp.where(in_hot, hot_v, v_deq)
    return _attend_gathered(q, k, v, q_pos, kv_lens, window=window,
                            softcap=softcap, scale=scale)
