"""Mamba (S6 selective state-space) block for the jamba hybrid.

Training/prefill uses a parallel associative scan over the sequence
(log-depth on TPU); decode carries (conv_state, ssm_state) and runs the
single-step recurrence. The inner dim ``d_inner = expand * d_model`` is
TP-sharded over ``model`` (all channels are independent in the scan).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense


def _causal_conv1d(x, w, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    # sum_{t} x[s - (k-1) + t] * w[t]
    y = sum(xp[:, t:t + x.shape[1]] * w[t][None, None, :] for t in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros_like(pad)
    return y, new_state


def _ssm_scan(deltaA, deltaBx, h0=None):
    """h_t = deltaA_t * h_{t-1} + deltaBx_t via associative scan over S.

    deltaA/deltaBx: (B, S, C, N). Returns (all h (B,S,C,N), h_last).
    (Used by tests/reference; the layer itself uses the chunked sequential
    form below — the associative form's backward keeps all log-depth tree
    stages live, ~10x the memory.)
    """
    if h0 is not None:
        deltaBx = deltaBx.at[:, 0].add(deltaA[:, 0] * h0)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, b2 + a2 * b1

    _, h = jax.lax.associative_scan(combine, (deltaA, deltaBx), axis=1)
    return h, h[:, -1]


def _ssm_scan_seq(deltaA, deltaBx, h0):
    """Sequential recurrence over the (short) chunk axis: O(B*C*N) live."""
    def step(h, inp):
        da, db = inp
        h = da * h + db
        return h, h

    h_last, hs = jax.lax.scan(
        step, h0, (jnp.moveaxis(deltaA, 1, 0), jnp.moveaxis(deltaBx, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), h_last


def mamba_layer(p, x, cfg, cache=None, parallel=None):
    """x: (B, S, D) -> (B, S, D). cache = dict(conv, ssm) for decode."""
    from ..parallel.sharding import constraint
    b, s, d = x.shape
    d_in = cfg.mamba_d_inner
    n = cfg.mamba_d_state
    # two separate projections (never materialize a fused (B,S,2*d_in))
    xi = dense(x, p["in_proj_x"])                     # (B,S,d_in)
    z = dense(x, p["in_proj_z"])
    # anchor TP on the inner channels (the scan runs over full S per shard)
    xi = constraint(xi, ("batch", None, "mlp"), parallel)
    z = constraint(z, ("batch", None, "mlp"), parallel)
    conv_state = cache.get("conv") if cache else None
    xi, new_conv = _causal_conv1d(xi, p["conv_w"], conv_state)
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)

    # input-dependent dt, B, C
    proj = dense(xi, p["x_proj"])                     # (B,S,dt_rank+2N)
    dt_low, bmat, cmat = jnp.split(
        proj, [cfg.mamba_dt_rank, cfg.mamba_dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        dense(dt_low, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))           # (B,S,d_in) f32
    a = -jnp.exp(p["a_log"].astype(jnp.float32))      # (d_in, N)

    h0 = cache.get("ssm") if cache else jnp.zeros(
        (b, xi.shape[-1], n), jnp.float32)
    if cache is not None and s == 1:                  # single-step decode
        deltaA = jnp.exp(dt[:, 0, :, None] * a[None])
        deltaBx = (dt[:, 0] * xi[:, 0].astype(jnp.float32))[..., None] \
            * bmat[:, 0].astype(jnp.float32)[:, None, :]
        h_last = deltaA * cache["ssm"] + deltaBx
        y = jnp.einsum("bcn,bn->bc", h_last,
                       cmat[:, 0].astype(jnp.float32))[:, None]
    else:
        # chunked selective scan: the (B,chunk,C,N) discretized tensors are
        # materialized one chunk at a time (remat'd), never for the full S
        ck = min(cfg.mamba_chunk, s)
        while s % ck:
            ck -= 1
        nc = s // ck

        @jax.checkpoint
        def chunk_body(h, inp):
            dtc, xic, bc, cc = inp                    # (B,ck,...) bf16 streams
            dtf = dtc.astype(jnp.float32)
            deltaA = jnp.exp(dtf[..., None] * a[None, None])
            deltaBx = (dtf * xic.astype(jnp.float32))[..., None] \
                * bc.astype(jnp.float32)[:, :, None, :]
            hs, h_new = _ssm_scan_seq(deltaA, deltaBx, h)
            yc = jnp.einsum("bscn,bsn->bsc", hs, cc.astype(jnp.float32))
            return h_new, yc.astype(cfg.dtype)

        def split(t):
            return jnp.moveaxis(t.reshape(b, nc, ck, *t.shape[2:]), 1, 0)

        h_last, ys = jax.lax.scan(
            chunk_body, h0,
            (split(dt.astype(cfg.dtype)), split(xi), split(bmat), split(cmat)))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s, -1).astype(jnp.float32)

    y = y + p["d_skip"].astype(jnp.float32)[None, None] * xi.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = dense(y, p["out_proj"])
    # always return the recurrent state: prefill collects it as the cache
    return out, {"conv": new_conv, "ssm": h_last}


def mamba_param_defs(cfg, prefix):
    """(shape, logical_axes, init) declarations — consumed by model.init."""
    d, d_in = cfg.d_model, cfg.mamba_d_inner
    n, k, r = cfg.mamba_d_state, cfg.mamba_d_conv, cfg.mamba_dt_rank
    return {
        f"{prefix}/in_proj_x": ((d, d_in), ("embed", "mlp"), "fan_in"),
        f"{prefix}/in_proj_z": ((d, d_in), ("embed", "mlp"), "fan_in"),
        f"{prefix}/conv_w": ((k, d_in), (None, "mlp"), "one"),
        f"{prefix}/x_proj": ((d_in, r + 2 * n), ("mlp", None), "fan_in"),
        f"{prefix}/dt_proj": ((r, d_in), (None, "mlp"), "fan_in"),
        f"{prefix}/dt_bias": ((d_in,), ("mlp",), "dt_bias"),
        f"{prefix}/a_log": ((d_in, n), ("mlp", None), "a_log"),
        f"{prefix}/d_skip": ((d_in,), ("mlp",), "one"),
        f"{prefix}/out_proj": ((d_in, d), ("mlp", "embed"), "fan_in"),
    }


def mamba_cache_shapes(cfg, batch):
    d_in, n, k = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {"conv": ((batch, k - 1, d_in), cfg.dtype),
            "ssm": ((batch, d_in, n), jnp.float32)}
