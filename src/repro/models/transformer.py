"""Unified model covering all 10 assigned architectures.

One scan-over-layers decoder parameterized by ModelConfig:
  * dense GQA transformers (gemma2 local/global + softcaps, qwen QKV-bias,
    internlm2, granite)
  * MoE (granite-moe, arctic dense-residual) via models.moe (EP shard_map)
  * jamba hybrid (period-8 slot plan: 7x mamba + 1x attn, alternating MoE)
  * xlstm (period-2: sLSTM / mLSTM, no FFN)
  * whisper enc-dec (audio-frame stub frontend, cross-attention decoder)
  * internvl VLM (patch-embedding stub frontend prepended to tokens)

Layer stacks are stored as one param subtree per period-slot, stacked over
periods, and executed with ``lax.scan`` (+ optional remat) so compile time
and HLO size are depth-independent — required for the 80-cell dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ssm, xlstm
from .attention import flash_attention
from .layers import attention_layer, chunked_softmax_xent, dense, mlp_layer, rms_norm
from .moe import moe_layer


# ---------------------------------------------------------------------------
# layer plan: (mixer, ffn) per period slot
# ---------------------------------------------------------------------------

def layer_plan(cfg, stack="dec"):
    if stack == "enc":
        return [("enc_attn", "dense")]
    if cfg.layer_pattern == "xlstm":
        return [("slstm", "none"), ("mlstm", "none")]
    if cfg.layer_pattern == "jamba":
        plan = []
        for s in range(cfg.attn_every):
            mixer = "attn" if s % cfg.attn_every == cfg.attn_offset else "mamba"
            ffn = ("moe" if cfg.n_experts and s % cfg.moe_every == cfg.moe_offset
                   else "dense")
            plan.append((mixer, ffn))
        return plan
    if cfg.layer_pattern == "encdec":
        return [("attn", "dense")]          # + cross-attn params added below
    ffn = "dense"
    if cfg.n_experts:
        ffn = "moe+dense" if cfg.dense_residual else "moe"
    return [("attn", ffn)]


def n_periods(cfg, stack="dec"):
    n_layers = cfg.n_enc_layers if stack == "enc" else cfg.n_layers
    p = len(layer_plan(cfg, stack))
    assert n_layers % p == 0, (cfg.name, stack, n_layers, p)
    return n_layers // p


# ---------------------------------------------------------------------------
# parameter declarations:  path -> (shape, logical_axes, init_kind)
# logical axes: "fsdp" -> data, "tp"/"experts"/"vocab" -> model, None -> repl
# ---------------------------------------------------------------------------

def _attn_defs(cfg, prefix, cross=False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    defs = {
        f"{prefix}/wq": ((d, h * hd), ("fsdp", "tp"), "fan_in"),
        f"{prefix}/wk": ((d, kv * hd), ("fsdp", "tp"), "fan_in"),
        f"{prefix}/wv": ((d, kv * hd), ("fsdp", "tp"), "fan_in"),
        f"{prefix}/wo": ((h * hd, d), ("tp", "fsdp"), "fan_out"),
    }
    if cfg.qkv_bias and not cross:
        defs.update({
            f"{prefix}/bq": ((h * hd,), ("tp",), "zero"),
            f"{prefix}/bk": ((kv * hd,), ("tp",), "zero"),
            f"{prefix}/bv": ((kv * hd,), ("tp",), "zero"),
        })
    return defs


def _ffn_defs(cfg, prefix):
    d, f = cfg.d_model, cfg.d_ff
    return {
        f"{prefix}/wg": ((d, f), ("fsdp", "tp"), "fan_in"),
        f"{prefix}/wi": ((d, f), ("fsdp", "tp"), "fan_in"),
        f"{prefix}/wo": ((f, d), ("tp", "fsdp"), "fan_out"),
    }


def _moe_defs(cfg, prefix):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts_padded
    return {
        f"{prefix}/router": ((d, e), (None, None), "fan_in"),
        f"{prefix}/wg": ((e, d, f), ("experts", "fsdp", None), "fan_in"),
        f"{prefix}/wi": ((e, d, f), ("experts", "fsdp", None), "fan_in"),
        f"{prefix}/wo": ((e, f, d), ("experts", "fsdp", None), "fan_out"),
    }


def _slot_defs(cfg, slot_prefix, mixer, ffn, cross=False):
    d = cfg.d_model
    defs = {f"{slot_prefix}/norm1": ((d,), (None,), "zero")}
    if mixer in ("attn", "enc_attn"):
        defs.update(_attn_defs(cfg, f"{slot_prefix}/attn"))
    elif mixer == "mamba":
        defs.update(ssm.mamba_param_defs(cfg, f"{slot_prefix}/mamba"))
    elif mixer in ("slstm", "mlstm"):
        defs.update(xlstm.xlstm_param_defs(cfg, f"{slot_prefix}/{mixer}", mixer))
    if cfg.post_norm:
        defs[f"{slot_prefix}/norm1b"] = ((d,), (None,), "zero")
    if cross:
        defs[f"{slot_prefix}/normx"] = ((d,), (None,), "zero")
        defs.update(_attn_defs(cfg, f"{slot_prefix}/xattn", cross=True))
    if ffn != "none":
        defs[f"{slot_prefix}/norm2"] = ((d,), (None,), "zero")
        if cfg.post_norm:
            defs[f"{slot_prefix}/norm2b"] = ((d,), (None,), "zero")
    if ffn in ("dense", "moe+dense"):
        defs.update(_ffn_defs(cfg, f"{slot_prefix}/mlp"))
    if ffn in ("moe", "moe+dense"):
        defs.update(_moe_defs(cfg, f"{slot_prefix}/moe"))
    return defs


def param_defs(cfg):
    d, vp = cfg.d_model, cfg.vocab_padded
    defs = {
        "embed": ((vp, d), ("vocab", "fsdp"), "embed"),
        "final_norm": ((d,), (None,), "zero"),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ((vp, d), ("vocab", "fsdp"), "fan_out")
    for slot, (mixer, ffn) in enumerate(layer_plan(cfg, "dec")):
        defs.update(_slot_defs(cfg, f"dec/s{slot}", mixer, ffn,
                               cross=cfg.is_encdec))
    if cfg.is_encdec:
        defs["enc_final_norm"] = ((d,), (None,), "zero")
        for slot, (mixer, ffn) in enumerate(layer_plan(cfg, "enc")):
            defs.update(_slot_defs(cfg, f"enc/s{slot}", mixer, ffn))
    return defs


def _nest(flat: Dict[str, Any]) -> Dict[str, Any]:
    tree: Dict[str, Any] = {}
    for path, v in flat.items():
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _stack_shape(cfg, path, shape, stack_dim):
    # stack layer-slot params over periods
    if path.startswith(("dec/", "enc/")):
        return (stack_dim,) + shape
    return shape


def init_params(cfg, rng):
    defs = param_defs(cfg)
    flat = {}
    keys = jax.random.split(rng, len(defs))
    for key, (path, (shape, _axes, kind)) in zip(keys, sorted(defs.items())):
        stack = n_periods(cfg, "enc" if path.startswith("enc/") else "dec")
        full = _stack_shape(cfg, path, shape, stack)
        if kind == "zero":
            v = jnp.zeros(full, jnp.float32)
        elif kind == "one":
            v = jnp.ones(full, jnp.float32)
        elif kind == "embed":
            v = jax.random.normal(key, full, jnp.float32)
        elif kind == "dt_bias":
            v = jnp.log(jnp.expm1(
                jnp.exp(jax.random.uniform(key, full,
                                           minval=math.log(1e-3),
                                           maxval=math.log(1e-1)))))
        elif kind == "a_log":
            n = shape[-1]
            v = jnp.broadcast_to(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)),
                                 full).copy()
        elif kind == "f_bias":
            v = jnp.ones(full, jnp.float32) * 3.0
        elif kind == "orth":
            v = 0.1 * jax.random.normal(key, full, jnp.float32)
        elif kind == "fan_out":
            fan = shape[-2] if len(shape) >= 2 else shape[-1]
            v = jax.random.normal(key, full, jnp.float32) / math.sqrt(fan)
        else:  # fan_in
            fan = shape[-2] if len(shape) >= 2 else shape[-1]
            v = jax.random.normal(key, full, jnp.float32) / math.sqrt(fan)
        flat[path] = v
    return _nest(flat)


def param_axes(cfg):
    defs = param_defs(cfg)
    flat = {}
    for path, (shape, axes, _k) in defs.items():
        if path.startswith(("dec/", "enc/")):
            axes = (None,) + tuple(axes)
        flat[path] = tuple(axes)
    return _nest(flat)


def param_shapes(cfg):
    defs = param_defs(cfg)
    flat = {}
    for path, (shape, _a, _k) in defs.items():
        stack = n_periods(cfg, "enc" if path.startswith("enc/") else "dec")
        flat[path] = jax.ShapeDtypeStruct(_stack_shape(cfg, path, shape, stack),
                                          jnp.float32)
    return _nest(flat)


# ---------------------------------------------------------------------------
# forward blocks
# ---------------------------------------------------------------------------

def _shard(x, spec, parallel):
    if parallel is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(parallel.mesh, spec))


def _block(x, lp, cfg, mixer, ffn, *, window, positions, cur_pos, cache,
           enc_out, parallel, cross, decode_positions=None, paged=None):
    """One (mixer + ffn) residual block. Returns (x, new_cache, aux)."""
    from ..parallel.sharding import ParallelContext, TPShard
    tp = parallel if isinstance(parallel, TPShard) else None
    aux = jnp.float32(0.0)
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if mixer in ("attn", "enc_attn"):
        y, new_mix_cache = attention_layer(
            lp["attn"], h, cfg, positions, window=window,
            cache=None if cache is None else cache.get("attn"),
            cur_pos=cur_pos, causal=(mixer == "attn"),
            decode_positions=decode_positions, parallel=parallel,
            paged=paged)
    elif mixer == "mamba":
        y, new_mix_cache = ssm.mamba_layer(
            lp["mamba"], h, cfg, None if cache is None else cache.get("mamba"),
            parallel=None if tp is not None else parallel)
    elif mixer == "slstm":
        y, new_mix_cache = xlstm.slstm_layer(
            lp["slstm"], h, cfg, None if cache is None else cache.get("slstm"))
    else:
        y, new_mix_cache = xlstm.mlstm_layer(
            lp["mlstm"], h, cfg, None if cache is None else cache.get("mlstm"))
    if cfg.post_norm:
        y = rms_norm(y, lp["norm1b"], cfg.norm_eps)
    x = x + y
    new_cache = {"attn" if mixer in ("attn", "enc_attn") else mixer:
                 new_mix_cache}

    if cross:
        h = rms_norm(x, lp["normx"], cfg.norm_eps)
        y, xc = attention_layer(
            lp["xattn"], h, cfg, positions,
            cache=None if cache is None else cache.get("xattn"),
            cur_pos=cur_pos, xattn_kv=enc_out, causal=False,
            cross=cache is not None, parallel=parallel)
        x = x + y
        new_cache["xattn"] = xc

    if ffn != "none":
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        y = jnp.zeros_like(x)
        if ffn in ("dense", "moe+dense"):
            y = y + mlp_layer(lp["mlp"], h, tp=tp)
        if ffn in ("moe", "moe+dense"):
            ym, aux = moe_layer(lp["moe"], h, cfg, parallel)
            y = y + ym
        if cfg.post_norm:
            y = rms_norm(y, lp["norm2b"], cfg.norm_eps)
        x = x + y
    if isinstance(parallel, ParallelContext):
        # sequence parallelism on the residual stream: the layer-boundary
        # activations the remat'd scan stores shrink by the tp size
        # (Megatron-SP; the resolver drops `sp` when S % tp != 0, e.g. decode)
        from ..parallel.sharding import resolve_spec
        spec = resolve_spec(("batch", "sp", None), x.shape, parallel)
        x = _shard(x, spec, parallel)
    return x, new_cache, aux


def _window_array(cfg, stack="dec"):
    """Per-slot per-period sliding-window sizes (gemma2 local/global)."""
    plan = layer_plan(cfg, stack)
    np_ = n_periods(cfg, stack)
    p = len(plan)
    wins = np.zeros((np_, p), np.int32)
    if cfg.sliding_window and cfg.local_every:
        for layer in range(np_ * p):
            if layer % cfg.local_every == 0:
                wins[layer // p, layer % p] = cfg.sliding_window
    return jnp.asarray(wins)


def forward_stack(params_stack, x, cfg, *, stack="dec", positions,
                  parallel=None, cache=None, cur_pos=None, enc_out=None,
                  collect_cache=False, decode_positions=None, paged=None):
    """Scan the layer stack. Returns (x, new_cache_stacked, aux_sum)."""
    plan = layer_plan(cfg, stack)
    cross = cfg.is_encdec and stack == "dec"
    wins = _window_array(cfg, stack)

    def period_fn(carry, xs):
        x = carry
        lps, win_row, cache_row = xs
        new_caches = {}
        aux_tot = jnp.float32(0.0)
        for slot, (mixer, ffn) in enumerate(plan):
            sl_cache = None if cache_row is None else cache_row.get(f"s{slot}")

            def block_fn(x_, lp_, win_, cache_, mixer=mixer, ffn=ffn):
                return _block(
                    x_, lp_, cfg, mixer, ffn, window=win_,
                    positions=positions, cur_pos=cur_pos, cache=cache_,
                    enc_out=enc_out, parallel=parallel, cross=cross,
                    decode_positions=decode_positions, paged=paged)

            if cfg.remat and len(plan) > 1:
                # nested remat: the period backward replays one block at a
                # time instead of holding all slots' internals live
                block_fn = jax.checkpoint(
                    block_fn, policy=jax.checkpoint_policies.nothing_saveable,
                    prevent_cse=False)
            x, nc, aux = block_fn(x, lps[f"s{slot}"], win_row[slot], sl_cache)
            aux_tot = aux_tot + aux
            if collect_cache or cache_row is not None:
                new_caches[f"s{slot}"] = nc
        return x, (new_caches, aux_tot)

    body = period_fn
    if cfg.remat:
        body = jax.checkpoint(
            period_fn, policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False)

    xs = (params_stack, wins, cache)
    x, (new_cache, auxs) = jax.lax.scan(body, x, xs)
    return x, new_cache, jnp.sum(auxs)
