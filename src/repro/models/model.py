"""Top-level Model API: init / loss / prefill / decode_step / input_specs.

The three entry points the launcher lowers, per shape kind:
  train_*   -> train_step (see train/) built on ``Model.loss``
  prefill_* -> ``Model.prefill`` (forward + cache collection)
  decode_*  -> ``Model.decode_step`` (one token against a seq_len cache)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from . import ssm, xlstm
from .layers import chunked_softmax_xent, rms_norm
from .transformer import (forward_stack, init_params, layer_plan, n_periods,
                          param_axes, param_shapes)


def _sinusoid(positions, d):
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _has_attn(cfg):
    return any(m in ("attn", "enc_attn")
               for m, _ in layer_plan(cfg, "dec"))


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- params ------------------------------------------------------------
    def init(self, rng):
        return init_params(self.cfg, rng)

    def param_axes(self):
        return param_axes(self.cfg)

    def param_shapes(self):
        return param_shapes(self.cfg)

    # -- shared pieces -----------------------------------------------------
    def _embed(self, params, tokens):
        from ..core.quantize import PackedQTensor, QTensor, packed_gather
        cfg = self.cfg
        table = params["embed"]
        if isinstance(table, PackedQTensor):
            # packed execution: unpack + dequantize only the gathered rows —
            # the full bf16 table never materializes (DESIGN.md Sec. 9)
            e = packed_gather(table, tokens).astype(cfg.dtype)
            return e * jnp.asarray(cfg.embed_scale, cfg.dtype)
        if isinstance(table, QTensor):   # quantize-on-load serving
            table = table.dequantize()
        e = jnp.take(table, tokens, axis=0).astype(cfg.dtype)
        return e * jnp.asarray(cfg.embed_scale, cfg.dtype)

    def _unembed_vd(self, params):
        from ..core.quantize import PackedQTensor, QTensor
        table = params.get("unembed", params["embed"])
        if isinstance(table, PackedQTensor):
            w = table.dequantize()       # matmul orientation (K, n)
            return w.T if table.kblocked else w
        if isinstance(table, QTensor):
            table = table.dequantize()
        return table

    def _assemble_inputs(self, params, batch):
        """Token/frontend fusion -> (x (B,S,D), labels_mask_extra)."""
        cfg = self.cfg
        if cfg.frontend == "vision":
            patches = batch["patch_embeds"].astype(cfg.dtype)
            toks = self._embed(params, batch["tokens"])
            x = jnp.concatenate([patches, toks], axis=1)
        elif cfg.frontend == "audio" and not cfg.is_encdec:
            x = batch["frames"].astype(cfg.dtype)
        else:
            x = self._embed(params, batch["tokens"])
        return x

    def _encoder(self, params, batch, parallel):
        cfg = self.cfg
        frames = batch["frames"].astype(cfg.dtype)
        s = frames.shape[1]
        pos = jnp.arange(s)
        x = frames + _sinusoid(pos, cfg.d_model)[None].astype(cfg.dtype)
        x, _, _ = forward_stack(params["enc"], x, cfg, stack="enc",
                                positions=pos, parallel=parallel)
        return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)

    # -- training ----------------------------------------------------------
    def loss(self, params, batch, parallel=None):
        cfg = self.cfg
        enc_out = self._encoder(params, batch, parallel) if cfg.is_encdec else None
        x = self._assemble_inputs(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s)
        if not cfg.use_rope and not cfg.is_encdec and _has_attn(cfg):
            x = x + _sinusoid(positions, cfg.d_model)[None].astype(cfg.dtype)
        if cfg.is_encdec:
            x = x + _sinusoid(positions, cfg.d_model)[None].astype(cfg.dtype)
        x, _, aux = forward_stack(params["dec"], x, cfg, positions=positions,
                                  parallel=parallel, enc_out=enc_out)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        ce = chunked_softmax_xent(x, self._unembed_vd(params),
                                  jnp.maximum(labels, 0), mask,
                                  softcap=cfg.logit_softcap,
                                  vocab_real=cfg.vocab_size)
        loss = ce + cfg.router_aux_weight * aux
        return loss, {"ce": ce, "router_aux": aux}

    # -- serving -----------------------------------------------------------
    def _logits(self, params, hidden, parallel=None):
        """Unembedding projection + softcap + vocab mask.

        Under a ``TPShard`` with a vocab-sharded table (shard "n"/"v",
        DESIGN.md Sec. 10) each rank computes its vocab slice column-
        parallel — a full-K local matmul, so every logit is produced whole
        on exactly one rank — and the slices are all-gathered back to the
        replicated (B, V) the samplers expect.
        """
        from ..core.quantize import PackedQTensor
        from ..parallel.sharding import TPShard
        tp = parallel if isinstance(parallel, TPShard) else None
        cfg = self.cfg
        table = params.get("unembed", params["embed"])
        if (isinstance(table, PackedQTensor) and table.kblocked
                and jax.default_backend() == "tpu"):
            # fused unembedding projection: hidden (B, D) @ table^T (D, V)
            # streams 4-bit codes through the kernel. Off-TPU the fallback
            # below replays the exact simulation einsum so packed and
            # simulated greedy decode stay token-identical.
            from ..kernels.msb_matmul.ops import packed_matmul
            logits = packed_matmul(hidden.astype(jnp.float32), table)
        else:
            logits = jnp.einsum("bd,vd->bv", hidden.astype(jnp.float32),
                                self._unembed_vd(params).astype(jnp.float32))
        if tp is not None and getattr(table, "shard", None) in ("n", "v"):
            logits = jax.lax.all_gather(logits, tp.axis, axis=-1, tiled=True)
        if cfg.logit_softcap > 0:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        vp = logits.shape[-1]
        if cfg.vocab_size < vp:
            logits = jnp.where(jnp.arange(vp) < cfg.vocab_size, logits, -jnp.inf)
        return logits

    def prefill(self, params, batch, parallel=None):
        """Forward + cache collection. Returns (last_logits, cache)."""
        cfg = self.cfg
        enc_out = self._encoder(params, batch, parallel) if cfg.is_encdec else None
        x = self._assemble_inputs(params, batch)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.arange(s)
        if (not cfg.use_rope or cfg.is_encdec) and _has_attn(cfg):
            x = x + _sinusoid(positions, cfg.d_model)[None].astype(cfg.dtype)
        x, layer_cache, _ = forward_stack(
            params["dec"], x, cfg, positions=positions, parallel=parallel,
            enc_out=enc_out, collect_cache=True)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x[:, -1], parallel)
        cache = {"layers": layer_cache}
        if _has_attn(cfg):
            cache["pos"] = jnp.broadcast_to(
                positions.astype(jnp.int32)[None], (b, s))
        return logits, cache

    def decode_step(self, params, cache, tokens, cur_pos, parallel=None):
        """One decode step. tokens (B,1), cur_pos (B,). Returns (logits, cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if (not cfg.use_rope or cfg.is_encdec) and _has_attn(cfg):
            x = x + _sinusoid(cur_pos[:, None], cfg.d_model).astype(cfg.dtype)
        new_cache = dict(cache)
        decode_positions = None
        if _has_attn(cfg):
            s = cache["pos"].shape[1]
            slot = (cur_pos % s)[0]
            decode_positions = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], cur_pos[:, None].astype(jnp.int32), slot, 1)
            new_cache["pos"] = decode_positions
        x, layer_cache, _ = forward_stack(
            params["dec"], x, cfg, positions=cur_pos[:, None],
            parallel=parallel, cache=cache["layers"], cur_pos=cur_pos,
            decode_positions=decode_positions)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x[:, -1], parallel)
        new_cache["layers"] = layer_cache
        return logits, new_cache

    # -- paged serving -------------------------------------------------------
    def supports_paged(self):
        """Paged serving covers decoder-only attention stacks (any FFN kind);
        ssm/xlstm/enc-dec caches are per-sequence state, not pages."""
        cfg = self.cfg
        return (not cfg.is_encdec and cfg.frontend == "none"
                and all(m == "attn" for m, _ in layer_plan(cfg, "dec")))

    def init_paged_pools(self, num_pages, page_size, kv_bits=16,
                         max_seqs=None):
        """Global K/V page pools, nested like the decode cache's ``layers``
        subtree. Page 0 is the allocator's reserved scratch page (pad-row
        writes).

        ``kv_bits=16`` (native): leaves (n_periods, num_pages, page_size,
        KV, head_dim) in ``cfg.dtype``. ``kv_bits=8|4``: the dual-pool
        layout of DESIGN.md Sec. 15 — packed ``k_codes/v_codes`` +
        per-page ``k_scales/v_scales`` codebooks for committed pages, and
        full-precision ``k_hot/v_hot`` partial-page rows, one per engine
        slot (+ scratch row 0), sized by ``max_seqs``.
        """
        cfg = self.cfg
        assert self.supports_paged(), f"{cfg.name}: not a paged-servable arch"
        p = n_periods(cfg, "dec")
        kv, hd = cfg.n_kv_heads, cfg.head_dim_
        if kv_bits == 16:
            shape = (p, num_pages, page_size, kv, hd)
            layers = {f"s{slot}": {"attn": {"k": jnp.zeros(shape, cfg.dtype),
                                            "v": jnp.zeros(shape, cfg.dtype)}}
                      for slot, _ in enumerate(layer_plan(cfg, "dec"))}
            return {"layers": layers}
        from ..core.quantize import KVQuantSpec
        if max_seqs is None:
            raise ValueError("quantized pools need max_seqs (hot rows)")
        spec = KVQuantSpec(kv_bits, page_size, kv, hd)
        code_dt = jnp.uint8 if kv_bits == 4 else jnp.int8
        codes = (p, num_pages) + spec.codes_tail
        scales = (p, num_pages) + spec.scales_tail
        hot = (p, int(max_seqs) + 1, page_size, kv, hd)
        leaves = {"k_codes": jnp.zeros(codes, code_dt),
                  "v_codes": jnp.zeros(codes, code_dt),
                  "k_scales": jnp.zeros(scales, spec.scale_dtype),
                  "v_scales": jnp.zeros(scales, spec.scale_dtype),
                  "k_hot": jnp.zeros(hot, cfg.dtype),
                  "v_hot": jnp.zeros(hot, cfg.dtype)}
        layers = {f"s{slot}": {"attn": dict(leaves)}
                  for slot, _ in enumerate(layer_plan(cfg, "dec"))}
        return {"layers": layers}

    def paged_step(self, params, pools, tokens, q_pos, kv_lens, block_tables,
                   parallel=None, kv_bits=16, slots=None):
        """One serving step over a packed batch with a paged KV cache.

        tokens: (B, T) int32 (T=1 decode, T=chunk chunked prefill); q_pos:
        (B, T) absolute position of each token, -1 for padding (inactive
        batch rows / chunk tail); kv_lens: (B,) cache length including this
        chunk; block_tables: (B, max_pages) int32.

        Writes the new K/V into the pools and returns (logits at each row's
        last valid token (B, V), new_pools). Padding rows produce garbage
        logits the caller discards.

        ``parallel``: None, a ``ParallelContext`` (GSPMD), or a ``TPShard``
        when the caller runs this under ``shard_map`` with per-rank param
        shards and head-sharded pools — inputs/logits are then replicated
        across the mesh's model axis and the layer stack issues its own
        psum/all_gather collectives (DESIGN.md Sec. 10).

        ``kv_bits`` (static 16|8|4) selects the pool representation the
        caller built with ``init_paged_pools``; ``slots`` (B,) int32 engine
        slot ids (-1 = pad row) address the hot partial-page rows and are
        required when ``kv_bits < 16`` (DESIGN.md Sec. 15).
        """
        cfg = self.cfg
        x = self._embed(params, jnp.maximum(tokens, 0))
        if not cfg.use_rope:
            x = x + _sinusoid(jnp.maximum(q_pos, 0),
                              cfg.d_model).astype(cfg.dtype)
        paged = {"block_tables": block_tables, "q_pos": q_pos,
                 "kv_lens": kv_lens, "kv_bits": int(kv_bits)}
        if kv_bits != 16:
            if slots is None:
                raise ValueError("kv_bits < 16 needs the slots array")
            paged["slots"] = jnp.asarray(slots, jnp.int32)
        x, layer_pools, _ = forward_stack(
            params["dec"], x, cfg, positions=q_pos, parallel=parallel,
            cache=pools["layers"], paged=paged)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        last = jnp.maximum(jnp.sum((q_pos >= 0).astype(jnp.int32), 1) - 1, 0)
        hidden = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        return self._logits(params, hidden, parallel), {"layers": layer_pools}

    def paged_prefill_packed(self, params, pools, tokens, seg_ids, q_pos,
                             kv_lens, block_tables, slots, last_idx, seg_off,
                             parallel=None, kv_bits=16):
        """Packed ragged prefill: several sequences' chunks in one dispatch.

        tokens/seg_ids/q_pos: (T,) int32 — the concatenation of up to S
        segments' prefill chunks, padded to the bucket length T. seg_ids[i]
        names token i's segment (-1 = pad, q_pos then -1 too); q_pos[i] is
        its absolute position in that segment, so prefix-cache-resumed
        prompts pack at their adopted boundary. Per-segment arrays (S,):
        kv_lens (cache length incl. this dispatch; 0 = pad segment),
        block_tables (S, max_pages), slots (engine slot ids, -1 = pad;
        required when kv_bits < 16), last_idx (packed index of the
        segment's last token this dispatch — where its next-token logits
        are read; 0 for pads), seg_off (packed index of the segment's first
        token — the quantized commit path's chunk-content base offset).

        Cross-segment attention is exactly zero by construction: each
        token's K/V gather walks only its own segment's block-table row
        (models/attention.py packed helpers). Returns
        ``(logits (S, vocab), new_pools)``; pad-segment logits are garbage
        the caller discards. Runs under the same three execution regimes as
        ``paged_step`` (plain jit / GSPMD ``parallel`` / ``TPShard`` inside
        shard_map).
        """
        cfg = self.cfg
        x = self._embed(params, jnp.maximum(tokens, 0)[None])      # (1, T, D)
        if not cfg.use_rope:
            x = x + _sinusoid(jnp.maximum(q_pos, 0)[None],
                              cfg.d_model).astype(cfg.dtype)
        paged = {"block_tables": block_tables, "q_pos": q_pos[None],
                 "kv_lens": kv_lens, "kv_bits": int(kv_bits),
                 "seg_ids": seg_ids[None]}
        if kv_bits != 16:
            if slots is None:
                raise ValueError("kv_bits < 16 needs the slots array")
            paged["slots"] = jnp.asarray(slots, jnp.int32)
            paged["seg_off"] = jnp.asarray(seg_off, jnp.int32)
        x, layer_pools, _ = forward_stack(
            params["dec"], x, cfg, positions=q_pos[None], parallel=parallel,
            cache=pools["layers"], paged=paged)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        hidden = x[0][jnp.maximum(last_idx, 0)]                    # (S, D)
        return self._logits(params, hidden, parallel), {"layers": layer_pools}

    def paged_decode_horizon(self, params, pools, tokens, start_pos,
                             block_tables, n_left, eos_ids, horizon,
                             parallel=None, kv_bits=16, slots=None):
        """Run ``horizon`` decode iterations as one ``lax.scan`` with greedy
        sampling *on device* (DESIGN.md Sec. 12).

        Each iteration is one ``paged_step`` over a (B, 1) token batch: it
        writes the fed token's K/V through the paged-write path, argmaxes
        its own logits, and feeds the sampled token back through the carry.
        Per-row stop masks retire rows mid-scan — once a row samples its
        ``eos_ids[b]`` or exhausts ``n_left[b]``, its remaining iterations
        carry ``q_pos = -1`` and are exact no-ops (the write lands in the
        reserved scratch page, the attention mask blanks the query), so a
        finished row costs nothing but already-paid padding math.

        tokens: (B,) int32 — the last sampled, not-yet-cached token per
        row; start_pos: (B,) int32 its absolute position (-1 = inactive pad
        row); block_tables: (B, max_pages) int32, covering the caller's
        whole decode lease so mid-horizon page-boundary crossings need no
        host help; n_left: (B,) int32 per-row remaining token budget;
        eos_ids: (B,) int32 (-1 = no eos); horizon: static int >= 1.

        Returns ``(out_tokens (B, H) int32, valid (B, H) bool, new_pools)``
        — only O(B*H) scalars cross back to host, never (B, vocab) logits.
        ``valid`` is a per-row prefix mask: row b sampled exactly
        ``valid[b].sum()`` real tokens, trailing entries are no-op garbage.
        Greedy outputs are token-identical to ``horizon=1`` host-side
        argmax (same f32 logits, same first-max tie-break). Under a
        ``TPShard`` the whole scan runs inside one ``shard_map`` dispatch:
        logits are replicated by the step's psum/all_gather before the
        argmax, so every rank samples the same token and writes consistent
        local K/V shards.
        """
        tokens = tokens.astype(jnp.int32)
        active0 = (start_pos >= 0) & (n_left > 0)

        def body(carry, i):
            pools, tok, pos, active = carry
            q_pos = jnp.where(active, pos, -1)[:, None]
            kv_lens = jnp.maximum(pos, 0) + 1
            logits, pools = self.paged_step(params, pools, tok[:, None],
                                            q_pos, kv_lens, block_tables,
                                            parallel, kv_bits=kv_bits,
                                            slots=slots)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            hit_eos = (eos_ids >= 0) & (nxt == eos_ids)
            valid = active
            active = active & ~hit_eos & (i + 1 < n_left)
            tok = jnp.where(valid, nxt, tok)
            pos = pos + valid.astype(jnp.int32)
            return (pools, tok, pos, active), (jnp.where(valid, nxt, 0),
                                               valid)

        (pools, _, _, _), (toks, valid) = jax.lax.scan(
            body, (pools, tokens, start_pos.astype(jnp.int32), active0),
            jnp.arange(horizon, dtype=jnp.int32))
        return toks.T, valid.T, pools

    # -- cache specs ---------------------------------------------------------
    def cache_defs(self, batch, seq_len):
        """(shape, dtype, logical_axes) per cache leaf, nested like the cache."""
        cfg = self.cfg
        p = n_periods(cfg, "dec")
        kv, hd = cfg.n_kv_heads, cfg.head_dim_
        layers = {}
        for slot, (mixer, _ffn) in enumerate(layer_plan(cfg, "dec")):
            sl = {}
            if mixer == "attn":
                kvshape = (p, batch, seq_len, kv, hd)
                ax = ("layers", "batch", "kv_seq", "heads", "head_dim")
                sl["attn"] = {"k": (kvshape, cfg.dtype, ax),
                              "v": (kvshape, cfg.dtype, ax)}
            elif mixer == "mamba":
                shapes = ssm.mamba_cache_shapes(cfg, batch)
                ax = {"conv": ("layers", "batch", None, "mlp"),
                      "ssm": ("layers", "batch", "mlp", None)}
                sl["mamba"] = {k: ((p,) + shp, dt, ax[k])
                               for k, (shp, dt) in shapes.items()}
            elif mixer in ("slstm", "mlstm"):
                shapes = xlstm.xlstm_cache_shapes(cfg, batch, mixer)
                sl[mixer] = {k: ((p,) + shp, dt,
                                 ("layers", "batch") + (None,) * (len(shp) - 1))
                             for k, (shp, dt) in shapes.items()}
            if cfg.is_encdec:
                xshape = (p, batch, seq_len, kv, hd)
                ax = ("layers", "batch", "kv_seq", "heads", "head_dim")
                sl["xattn"] = {"k": (xshape, cfg.dtype, ax),
                               "v": (xshape, cfg.dtype, ax)}
            layers[f"s{slot}"] = sl
        defs = {"layers": layers}
        if _has_attn(cfg):
            defs["pos"] = ((batch, seq_len), jnp.int32,
                           ("batch", "kv_seq"))
        return defs

    def init_cache(self, batch, seq_len):
        def build(d):
            if isinstance(d, dict):
                return {k: build(v) for k, v in d.items()}
            shape, dt, _ax = d
            if dt == jnp.int32:
                return jnp.broadcast_to(
                    jnp.arange(shape[-1], dtype=jnp.int32)[None], shape).copy()
            return jnp.zeros(shape, dt)
        return build(self.cache_defs(batch, seq_len))

    # -- dry-run input specs -------------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        """ShapeDtypeStruct stand-ins + logical axes for every entry-point arg."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32, dt = jnp.int32, cfg.dtype

        def sds(shp, dtype):
            return jax.ShapeDtypeStruct(shp, dtype)

        if shape.kind in ("train", "prefill"):
            batch, axes = {}, {}
            if cfg.frontend == "vision":
                p = cfg.n_frontend_tokens
                batch["tokens"] = sds((b, s - p), i32)
                axes["tokens"] = ("batch", None)
                batch["patch_embeds"] = sds((b, p, cfg.d_model), dt)
                axes["patch_embeds"] = ("batch", None, None)
            elif cfg.is_encdec:
                batch["tokens"] = sds((b, s), i32)
                axes["tokens"] = ("batch", None)
                batch["frames"] = sds((b, s, cfg.d_model), dt)
                axes["frames"] = ("batch", None, None)
            else:
                batch["tokens"] = sds((b, s), i32)
                axes["tokens"] = ("batch", None)
            if shape.kind == "train":
                batch["labels"] = sds((b, s), i32)
                axes["labels"] = ("batch", None)
            return batch, axes

        # decode: (cache, tokens, cur_pos)
        cache_defs = self.cache_defs(b, s)

        def to_sds(d):
            if isinstance(d, dict):
                return {k: to_sds(v) for k, v in d.items()}
            shp, dtp, _ = d
            return sds(shp, dtp)

        def to_axes(d):
            if isinstance(d, dict):
                return {k: to_axes(v) for k, v in d.items()}
            return d[2]

        batch = {"cache": to_sds(cache_defs), "tokens": sds((b, 1), i32),
                 "cur_pos": sds((b,), i32)}
        axes = {"cache": to_axes(cache_defs), "tokens": ("batch", None),
                "cur_pos": ("batch",)}
        return batch, axes
