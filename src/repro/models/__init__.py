from .model import Model
from .transformer import init_params, param_axes, param_shapes
from .attention import decode_attention, flash_attention
