"""xLSTM blocks (sLSTM + mLSTM, Beck et al. 2024) for the xlstm-125m arch.

Both are implemented as their exact stabilized recurrences via ``lax.scan``
over time (one HLO body regardless of sequence length; the chunked-parallel
mLSTM form is a recorded perf-iteration item). States are small —
long_500k decode carries only O(H*dh^2) per layer, no KV cache.

Stack layout: alternating sLSTM / mLSTM (period 2), no FFN (d_ff = 0): each
block has its own up/down projections per the xLSTM paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense, rms_norm


def _chunked_time_scan(step, state, xs, chunk=256):
    """scan-of-checkpointed-chunk-scans over the leading (time) axis.

    A flat S-step scan saves per-step residuals (for mLSTM that's the
    (B,H,dh,dh) matrix memory — 4096 x 9.4 MB = 38 GB/dev at train_4k);
    chunking + remat bounds saved state to per-chunk carries.
    """
    s = xs[0].shape[0]
    ck = min(chunk, s)
    while s % ck:
        ck -= 1
    if ck == s:
        return jax.lax.scan(step, state, xs)
    nc = s // ck

    @jax.checkpoint
    def chunk_body(st, xs_chunk):
        return jax.lax.scan(step, st, xs_chunk)

    xs_r = tuple(x.reshape(nc, ck, *x.shape[1:]) for x in xs)
    state, ys = jax.lax.scan(chunk_body, state, xs_r)
    ys = jax.tree_util.tree_map(
        lambda y: y.reshape(s, *y.shape[2:]), ys)
    return state, ys


# ---------------------------------------------------------------------------
# mLSTM: matrix memory C (B,H,dh,dh) with exponential gating
# ---------------------------------------------------------------------------

def _mlstm_step(state, inp):
    c, nrm, m = state                       # (B,H,d,d), (B,H,d), (B,H)
    q, k, v, logf, logi = inp               # (B,H,d) x3, (B,H), (B,H)
    m_new = jnp.maximum(logf + m, logi)
    f = jnp.exp(logf + m - m_new)[..., None]
    i = jnp.exp(logi - m_new)[..., None]
    c = f[..., None] * c + i[..., None] * (v[..., :, None] * k[..., None, :])
    nrm = f * nrm + i * k
    h_num = jnp.einsum("bhvk,bhk->bhv", c, q)
    h_den = jnp.abs(jnp.einsum("bhk,bhk->bh", nrm, q))
    h = h_num / jnp.maximum(h_den, jnp.exp(-m_new))[..., None]
    return (c, nrm, m_new), h


def mlstm_layer(p, x, cfg, cache=None):
    b, s, d = x.shape
    h_, dh = cfg.n_heads, cfg.head_dim
    q = dense(x, p["wq"]).reshape(b, s, h_, dh)
    k = dense(x, p["wk"]).reshape(b, s, h_, dh) * (dh ** -0.5)
    v = dense(x, p["wv"]).reshape(b, s, h_, dh)
    gates = dense(x, p["w_gates"]).astype(jnp.float32)   # (B,S,2H)
    logf = jax.nn.log_sigmoid(gates[..., :h_] + p["f_bias"].astype(jnp.float32))
    logi = gates[..., h_:]
    o = jax.nn.sigmoid(dense(x, p["w_o_gate"]).astype(jnp.float32))

    if cache is None:
        c0 = jnp.zeros((b, h_, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h_, dh), jnp.float32)
        m0 = jnp.full((b, h_), -1e30, jnp.float32)
    else:
        c0, n0, m0 = cache["c"], cache["n"], cache["m"]

    xs = (jnp.moveaxis(q, 1, 0).astype(jnp.float32),
          jnp.moveaxis(k, 1, 0).astype(jnp.float32),
          jnp.moveaxis(v, 1, 0).astype(jnp.float32),
          jnp.moveaxis(logf, 1, 0), jnp.moveaxis(logi, 1, 0))
    (c, n, m), hs = _chunked_time_scan(_mlstm_step, (c0, n0, m0), xs)
    hs = jnp.moveaxis(hs, 0, 1)                          # (B,S,H,dh)
    out = (hs.reshape(b, s, h_ * dh) * o).astype(x.dtype)
    return dense(out, p["w_out"]), {"c": c, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM: scalar memory with block-diagonal (per-head) recurrence
# ---------------------------------------------------------------------------

def _slstm_step(rw, state, inp):
    c, n, m, h_prev = state                 # (B,H,d) x3 + (B,H,d)
    zx, ix, fx, ox = inp                    # each (B,H,d)
    rec = jnp.einsum("bhd,hde->bhe", h_prev, rw)
    z = jnp.tanh(zx + rec)
    logi = ix + rec
    logf = jax.nn.log_sigmoid(fx + rec)
    m_new = jnp.maximum(logf + m, logi)
    i = jnp.exp(logi - m_new)
    f = jnp.exp(logf + m - m_new)
    c = f * c + i * z
    n = f * n + i
    h = jax.nn.sigmoid(ox) * c / jnp.maximum(n, 1e-6)
    return (c, n, m_new, h), h


def slstm_layer(p, x, cfg, cache=None):
    b, s, d = x.shape
    h_, dh = cfg.n_heads, cfg.head_dim
    pre = dense(x, p["w_in"]).astype(jnp.float32)        # (B,S,4*H*dh)
    zx, ix, fx, ox = [t.reshape(b, s, h_, dh)
                      for t in jnp.split(pre, 4, axis=-1)]
    if cache is None:
        zeros = jnp.zeros((b, h_, dh), jnp.float32)
        state = (zeros, zeros, jnp.full((b, h_, dh), -1e30, jnp.float32), zeros)
    else:
        state = (cache["c"], cache["n"], cache["m"], cache["h"])
    rw = p["w_rec"].astype(jnp.float32)                  # (H, dh, dh)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (zx, ix, fx, ox))
    step = lambda st, inp: _slstm_step(rw, st, inp)
    state, hs = _chunked_time_scan(step, state, xs)
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, h_ * dh).astype(x.dtype)
    out = dense(hs, p["w_out"])
    return out, {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}


def xlstm_param_defs(cfg, prefix, kind):
    d, h_, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    hd = h_ * dh
    if kind == "mlstm":
        return {
            f"{prefix}/wq": ((d, hd), ("embed", "heads"), "fan_in"),
            f"{prefix}/wk": ((d, hd), ("embed", "heads"), "fan_in"),
            f"{prefix}/wv": ((d, hd), ("embed", "heads"), "fan_in"),
            f"{prefix}/w_gates": ((d, 2 * h_), ("embed", None), "fan_in"),
            f"{prefix}/f_bias": ((h_,), (None,), "f_bias"),
            f"{prefix}/w_o_gate": ((d, hd), ("embed", "heads"), "fan_in"),
            f"{prefix}/w_out": ((hd, d), ("heads", "embed"), "fan_in"),
        }
    return {
        f"{prefix}/w_in": ((d, 4 * hd), ("embed", "heads"), "fan_in"),
        f"{prefix}/w_rec": ((h_, dh, dh), (None, None, None), "orth"),
        f"{prefix}/w_out": ((hd, d), ("heads", "embed"), "fan_in"),
    }


def xlstm_cache_shapes(cfg, batch, kind):
    h_, dh = cfg.n_heads, cfg.head_dim
    if kind == "mlstm":
        return {"c": ((batch, h_, dh, dh), jnp.float32),
                "n": ((batch, h_, dh), jnp.float32),
                "m": ((batch, h_), jnp.float32)}
    return {"c": ((batch, h_, dh), jnp.float32),
            "n": ((batch, h_, dh), jnp.float32),
            "m": ((batch, h_, dh), jnp.float32),
            "h": ((batch, h_, dh), jnp.float32)}
