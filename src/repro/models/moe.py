"""Mixture-of-Experts layer with all-to-all expert parallelism.

Sharding scheme (DESIGN.md Sec. 4): inside ``shard_map`` the token stream
arrives sharded over BOTH the data axes and the ``model`` axis (sequence-
parallel residual stream), so each rank routes only T/(dp*tp) tokens:

  route local tokens -> dispatch buffer (E, C, D)
    -> all-to-all over `model` (split experts, concat capacity)
    -> resident-expert FFN (E/tp experts, FSDP-gathered weights)
    -> all-to-all back -> combine with gates

The output stays sequence-parallel — no psum. Expert weights are stored
(E, D, F) sharded [experts -> model, D/F -> data]; the ZeRO-3 per-layer
bf16 all-gather happens inside the shard_map (its transpose is the
reduce-scatter of the expert grads).

Experts are zero-padded to a multiple of the tp size when needed
(granite-moe: 40 -> 48); padded experts are masked out of routing.

A mathematically identical single-device path (e_offset=0, no collectives)
serves smoke tests and the EP-vs-local equivalence test.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

try:  # jax >= 0.6 public API
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def _route(x2d, router_w, e_total, n_real, k, capacity):
    """Top-k routing + per-expert positions for local tokens.

    Returns (gates (T,k), eidx (T,k), pos (T,k), keep (T,k), aux_loss).
    Padded experts (id >= n_real) are masked out of the softmax.
    """
    t = x2d.shape[0]
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    if n_real < e_total:
        logits = jnp.where(jnp.arange(e_total) < n_real, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                      # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    flat_e = eidx.swapaxes(0, 1).reshape(-1)                   # (k*T,)
    onehot = jax.nn.one_hot(flat_e, e_total, dtype=jnp.int32)  # (k*T, E)
    pos_flat = jnp.cumsum(onehot, axis=0) - 1
    pos_flat = jnp.take_along_axis(pos_flat, flat_e[:, None], 1)[:, 0]
    pos = pos_flat.reshape(k, t).swapaxes(0, 1)                # (T, k)
    keep = pos < capacity
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], e_total, dtype=jnp.float32), 0)
    aux = n_real * jnp.sum(me * ce)
    return gates, eidx, pos, keep, aux


def _expert_ffn_quantized(xe, wg, wi, wo, counts):
    """Quantized-expert FFN: one expert at a time, router-gated.

    The old path dequantized all three (E, D, F) expert tensors eagerly on
    every call — full bf16 materialization even for experts the router
    never selected. Here ``lax.map`` streams one expert's weights at a
    time (dequantize / packed-dispatch just that slice) and ``lax.cond``
    on the router count skips the matmuls entirely for experts with no
    routed tokens — at decode (1 token, k of E experts active) most
    experts take the zero branch.
    """
    from ..core.quantize import PackedQTensor, QTensor
    from ..kernels.msb_matmul.ops import packed_matmul

    def mm(xc, w):
        if isinstance(w, PackedQTensor):
            return packed_matmul(xc, w)
        if isinstance(w, QTensor):
            w = w.dequantize()
        return jnp.einsum("cd,df->cf", xc, w.astype(xc.dtype))

    def one(args):
        xc, g_, i_, o_, cnt = args

        def compute(xc):
            g = mm(xc, g_)
            u = mm(xc, i_)
            h = jax.nn.silu(g.astype(jnp.float32)).astype(xc.dtype) * u
            return mm(h, o_)

        return jax.lax.cond(cnt > 0, compute, jnp.zeros_like, xc)

    return jax.lax.map(one, (xe, wg, wi, wo, counts))


def _expert_ffn(xe, wg, wi, wo, chunk=2048):
    """xe: (E_loc, C, D); weights (E_loc, D, F) / (E_loc, F, D).

    Chunked over capacity (remat'd) so the (E_loc, C, F) hidden activations
    never materialize for the full capacity at once.
    """
    e, c, d = xe.shape

    @jax.checkpoint
    def one(xc):
        g = jnp.einsum("ecd,edf->ecf", xc, wg.astype(xc.dtype))
        u = jnp.einsum("ecd,edf->ecf", xc, wi.astype(xc.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xc.dtype) * u
        return jnp.einsum("ecf,efd->ecd", h, wo.astype(xc.dtype))

    ck = min(chunk, c)
    while c % ck:
        ck -= 1
    if ck == c:
        return one(xe)
    xr = jnp.moveaxis(xe.reshape(e, c // ck, ck, d), 1, 0)
    ys = jax.lax.map(one, xr)
    return jnp.moveaxis(ys, 0, 1).reshape(e, c, d)


def _dispatch(x2d, gates, eidx, pos, keep, e_total, capacity):
    """Scatter tokens into the (E*C+1, D) dispatch buffer (last row = drop)."""
    t, d = x2d.shape
    k = eidx.shape[1]
    slot = jnp.where(keep, eidx * capacity + pos, e_total * capacity)
    flat_slot = slot.reshape(-1)
    xrep = jnp.broadcast_to(x2d[:, None], (t, k, d)).reshape(-1, d)
    buf = jnp.zeros((e_total * capacity + 1, d), x2d.dtype)
    return buf.at[flat_slot].set(xrep, mode="drop"), flat_slot


def _combine(ye_flat, flat_slot, gates, keep, t, k, d):
    yflat = jnp.concatenate([ye_flat, jnp.zeros((1, d), ye_flat.dtype)], 0)
    yk = yflat[flat_slot].reshape(t, k, d)
    w = (gates * keep).astype(yk.dtype)
    return jnp.sum(yk * w[..., None], axis=1)


def _moe_tp(p, x, cfg, tp):
    """Expert-sharded MoE under a manual-TP ``shard_map`` (DESIGN.md §10).

    Every rank routes the full (replicated) token stream with the
    replicated router — gates, expert assignment and per-expert positions
    are bit-identical across ranks and to the single-device path — then
    dispatches only the tokens bound for its ``E/tp`` resident experts,
    runs the streamed/router-gated quantized expert FFN on them, and
    combines with gates masked to local experts. The psum over the model
    axis adds disjoint per-rank partial sums (every token-expert term is
    computed on exactly one rank), so the result equals the single-device
    combine up to float-add reordering across ranks.
    """
    b, s, d = x.shape
    k = cfg.n_experts_active
    e_total = cfg.n_experts_padded
    e_loc = e_total // tp.size
    capacity = _capacity(b * s, k, e_total, cfg.capacity_factor)
    x2d = x.reshape(-1, d)
    gates, eidx, pos, keep, aux = _route(x2d, p["router"], e_total,
                                         cfg.n_experts, k, capacity)
    r = jax.lax.axis_index(tp.axis)
    local = (eidx >= r * e_loc) & (eidx < (r + 1) * e_loc)
    keep_l = keep & local
    eidx_l = jnp.where(local, eidx - r * e_loc, 0)
    buf, flat_slot = _dispatch(x2d, gates, eidx_l, pos, keep_l, e_loc,
                               capacity)
    xe = buf[:-1].reshape(e_loc, capacity, d)
    counts = jnp.zeros((e_loc,), jnp.int32).at[eidx_l.reshape(-1)].add(
        keep_l.reshape(-1).astype(jnp.int32))
    ye = _expert_ffn_quantized(xe, p["wg"], p["wi"], p["wo"], counts)
    y = _combine(ye.reshape(-1, d), flat_slot, gates, keep_l, b * s, k, d)
    y = jax.lax.psum(y, tp.axis)
    return y.reshape(b, s, d), aux


def moe_layer(p, x, cfg, parallel=None):
    """x: (B, S, D) -> (B, S, D).

    ``parallel``: None (single device), a ``ParallelContext`` (training-
    style all-to-all expert parallelism under its own shard_map), or a
    ``TPShard`` (serving: already inside shard_map — expert-sharded leaves
    take ``_moe_tp``, anything else falls back to the replicated local
    path, which is exact).
    """
    from ..core.quantize import PackedQTensor, QTensor
    from ..parallel.sharding import TPShard
    if isinstance(parallel, TPShard):
        if parallel.size > 1 and getattr(p.get("wg"), "shard", None) == "e":
            return _moe_tp(p, x, cfg, parallel)
        parallel = None
    quantized = isinstance(p.get("wg"), (QTensor, PackedQTensor))
    b, s, d = x.shape
    k = cfg.n_experts_active
    e_total = cfg.n_experts_padded
    n_real = cfg.n_experts

    ep_ok = (parallel is not None
             and e_total % parallel.tp_size == 0
             and (b * s) % (parallel.dp_size * parallel.tp_size) == 0)
    if not ep_ok:
        capacity = _capacity(b * s, k, e_total, cfg.capacity_factor)
        gates, eidx, pos, keep, aux = _route(
            x.reshape(-1, d), p["router"], e_total, n_real, k, capacity)
        buf, flat_slot = _dispatch(x.reshape(-1, d), gates, eidx, pos, keep,
                                   e_total, capacity)
        xe = buf[:-1].reshape(e_total, capacity, d)
        if quantized:
            counts = jnp.zeros((e_total,), jnp.int32).at[
                eidx.reshape(-1)].add(keep.reshape(-1).astype(jnp.int32))
            ye = _expert_ffn_quantized(xe, p["wg"], p["wi"], p["wo"], counts)
        else:
            ye = _expert_ffn(xe, p["wg"], p["wi"], p["wo"])
        y = _combine(ye.reshape(-1, d), flat_slot, gates, keep, b * s, k, d)
        return y.reshape(b, s, d), aux

    if quantized:    # EP collectives need dense bf16 expert weights
        p = dict(p, wg=p["wg"].dequantize(), wi=p["wi"].dequantize(),
                 wo=p["wo"].dequantize())

    mesh = parallel.mesh
    tp = parallel.tp_size
    tp_axis = parallel.tp_axis
    dp_axes = parallel.dp_axes
    fsdp = parallel.fsdp_axis
    e_loc = e_total // tp
    t_local = (b * s) // (parallel.dp_size * tp)
    capacity = _capacity(t_local, k, e_total, cfg.capacity_factor)
    P = jax.sharding.PartitionSpec

    def inner(xl, rw, wg, wi, wo):
        if fsdp is not None:  # ZeRO-3 per-layer bf16 gather
            wg = jax.lax.all_gather(wg.astype(cfg.dtype), fsdp, axis=1,
                                    tiled=True)
            wi = jax.lax.all_gather(wi.astype(cfg.dtype), fsdp, axis=1,
                                    tiled=True)
            wo = jax.lax.all_gather(wo.astype(cfg.dtype), fsdp, axis=1,
                                    tiled=True)
        x2d = xl.reshape(-1, d)
        gates, eidx, pos, keep, aux = _route(x2d, rw, e_total, n_real, k,
                                             capacity)
        buf, flat_slot = _dispatch(x2d, gates, eidx, pos, keep, e_total,
                                   capacity)
        # (E, C, D) -> a2a -> (E_loc, tp*C, D): resident experts gather their
        # tokens from every source rank
        send = buf[:-1].reshape(e_total, capacity, d)
        recv = jax.lax.all_to_all(send, tp_axis, split_axis=0, concat_axis=1,
                                  tiled=True)
        ye = _expert_ffn(recv, wg, wi, wo)
        back = jax.lax.all_to_all(ye, tp_axis, split_axis=1, concat_axis=0,
                                  tiled=True)
        y = _combine(back.reshape(-1, d), flat_slot, gates, keep,
                     t_local, k, d)
        aux = jax.lax.psum(aux, (tp_axis, *dp_axes)) / parallel.n_devices
        return y.reshape(xl.shape), aux

    wspec = P(tp_axis, fsdp, None)
    y, aux = _shard_map(
        inner, mesh=mesh,
        in_specs=(P(dp_axes, tp_axis, None), P(None, None),
                  wspec, wspec, wspec),
        out_specs=(P(dp_axes, tp_axis, None), P()),
    )(x, p["router"], p["wg"], p["wi"], p["wo"])
    return y, aux


def _capacity(tokens, k, e_total, cf):
    cap = int(cf * tokens * k / max(e_total, 1))
    return max(8, -(-cap // 8) * 8)
